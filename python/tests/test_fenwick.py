"""Fenwick partition invariants (paper §3.1) — Python twin of the Rust
property tests, plus the chunk-level correspondence Algorithm 1 relies on."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fenwick


@given(st.integers(min_value=0, max_value=5000))
@settings(max_examples=60, deadline=None)
def test_buckets_partition_prefix(t):
    bs = sorted(fenwick.buckets(t), key=lambda b: b[1])
    pos = 0
    for _, start, end in bs:
        assert start == pos
        pos = end
    assert pos == t + 1


@given(st.integers(min_value=0, max_value=5000))
@settings(max_examples=60, deadline=None)
def test_bucket_sizes_and_count(t):
    bs = fenwick.buckets(t)
    for level, start, end in bs:
        size = end - start
        assert size == (1 if level == 0 else 1 << (level - 1))
    assert len(bs) == bin(t).count("1") + 1


@given(st.integers(min_value=0, max_value=400))
@settings(max_examples=30, deadline=None)
def test_level_of_matches_buckets(t):
    bs = fenwick.buckets(t)
    for s in range(t + 1):
        l = fenwick.level_of(t, s)
        assert any(start <= s < end and level == l for level, start, end in bs)


def test_num_levels_covers_all_buckets():
    for T in (1, 8, 64, 256, 1000):
        nl = fenwick.num_levels(T)
        for t in range(T):
            for level, _, _ in fenwick.buckets(t):
                assert level < nl


def test_chunk_level_correspondence():
    """token level == log2(C) + chunk level for cross-chunk pairs."""
    C = 8
    lc = 3
    for t in range(0, 8 * C):
        for s in range(0, t + 1):
            tc, sc = t // C, s // C
            if tc != sc:
                assert fenwick.level_of(t, s) == lc + fenwick.level_of(tc, sc)


def test_level_masks_partition_lower_triangle():
    n = 32
    total = np.zeros((n, n), dtype=int)
    for level in range(fenwick.num_levels(n)):
        total += fenwick.level_mask(level, n).astype(int)
    expect = np.tril(np.ones((n, n), dtype=int))
    assert (total == expect).all()


def test_level_index_matrix_consistent():
    n = 24
    m = fenwick.level_index_matrix(n)
    for i in range(n):
        for j in range(n):
            if j > i:
                assert m[i, j] == -1
            else:
                assert m[i, j] == fenwick.level_of(i, j)


def test_lssb_traced_matches_host():
    import jax.numpy as jnp

    for t in range(1, 300):
        assert int(fenwick.lssb_traced(jnp.int32(t))) == fenwick.lssb(t)


def test_segsum_matches_numpy():
    import jax.numpy as jnp

    x = np.random.RandomState(0).randn(10).astype(np.float32)
    s = np.asarray(fenwick.segsum(jnp.asarray(x)))
    for i in range(10):
        for j in range(10):
            if j > i:
                assert s[i, j] == -np.inf
            else:
                assert abs(s[i, j] - x[j + 1: i + 1].sum()) < 1e-5
