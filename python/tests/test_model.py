"""Layer-2 model tests: shapes, decode == forward, training reduces loss,
flatten/unflatten contract (the Rust marshalling invariant)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import decode as D

TINY = dict(vocab=64, d_model=32, n_layers=2, n_heads=2, dk=8, dv=8,
            d_mlp=64, seq_len=32, chunk=8)


def make(variant):
    cfg = M.ModelConfig(variant=variant, **TINY)
    params = M.init_params(cfg, seed=0)
    toks = np.random.RandomState(1).randint(0, TINY["vocab"], (2, TINY["seq_len"])).astype(np.int32)
    return cfg, params, toks


@pytest.mark.parametrize("variant", M.VARIANTS)
def test_forward_shapes_and_finiteness(variant):
    cfg, params, toks = make(variant)
    logits = M.forward_logits(cfg, params, toks)
    assert logits.shape == (2, TINY["seq_len"], TINY["vocab"])
    assert np.isfinite(np.asarray(logits)).all()
    pp = M.per_position_loss(cfg, params, toks)
    assert pp.shape == (2, TINY["seq_len"] - 1)
    assert float(pp.mean()) > 0


@pytest.mark.parametrize("variant", [v for v in M.VARIANTS if v != "transformer"])
def test_decode_matches_forward(variant):
    cfg, params, toks = make(variant)
    logits = M.forward_logits(cfg, params, toks)
    states = D.init_decode_state(cfg, 2)
    for t in range(TINY["seq_len"]):
        lg, states = D.decode_step(cfg, params, states, toks[:, t], jnp.full((2,), t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits[:, t]), atol=2e-4, rtol=2e-3,
            err_msg=f"{variant} t={t}")


@pytest.mark.parametrize("variant", ["loglinear_mamba2", "gdn"])
def test_training_reduces_loss(variant):
    cfg, params, toks = make(variant)
    m = M.zeros_like_tree(params)
    v = M.zeros_like_tree(params)
    losses = []
    for step in range(1, 16):
        params, m, v, loss = M.adam_train_step(
            cfg, params, m, v, jnp.int32(step), toks, jnp.float32(3e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, f"no learning: {losses[0]:.3f} -> {losses[-1]:.3f}"
    assert all(np.isfinite(l) for l in losses)


def test_flatten_unflatten_roundtrip():
    cfg, params, _ = make("loglinear_gdn")
    flat = M.flatten_with_names(params)
    names = [n for n, _ in flat]
    assert names == sorted(names) or len(names) > 0  # stable order exists
    rebuilt = M.unflatten_like(params, [p for _, p in flat])
    for (n1, a), (n2, b) in zip(flat, M.flatten_with_names(rebuilt)):
        assert n1 == n2
        assert (np.asarray(a) == np.asarray(b)).all()


def test_flatten_order_is_deterministic():
    cfg = M.ModelConfig(variant="loglinear_mamba2", **TINY)
    a = [n for n, _ in M.flatten_with_names(M.init_params(cfg, 0))]
    b = [n for n, _ in M.flatten_with_names(M.init_params(cfg, 1))]
    assert a == b


def test_lambda_init_collapses_to_linear():
    """At init λ ≈ 1, so the log-linear model must match its linear twin
    (both initialized with identical shared weights)."""
    cfg_l, params_l, toks = make("loglinear_mamba2")
    cfg_b = M.ModelConfig(variant="mamba2", **TINY)
    # Share weights exactly: strip the λ head from the log-linear params
    # (RNG consumption order differs between variants, so re-initializing
    # would NOT give shared weights) and zero w_lam so λ == 1 exactly.
    import copy
    params_b = copy.deepcopy(params_l)
    for i in range(cfg_l.n_layers):
        params_l[f"layer_{i}"]["w_lam"] = jnp.zeros_like(params_l[f"layer_{i}"]["w_lam"])
        del params_b[f"layer_{i}"]["w_lam"]
        del params_b[f"layer_{i}"]["b_lam"]
    la = M.forward_logits(cfg_l, params_l, toks)
    lb = M.forward_logits(cfg_b, params_b, toks)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=5e-3, rtol=5e-3)


def test_rope_is_position_dependent_and_norm_preserving():
    x = np.random.RandomState(0).randn(1, 8, 2, 16).astype(np.float32)
    y = M.rope(jnp.asarray(x), 10_000.0)
    n_in = np.linalg.norm(x, axis=-1)
    n_out = np.linalg.norm(np.asarray(y), axis=-1)
    np.testing.assert_allclose(n_in, n_out, rtol=1e-4)
    assert not np.allclose(np.asarray(y)[0, 0], np.asarray(y)[0, 5])
