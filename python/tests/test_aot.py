"""AOT export contract tests: HLO text parses, manifests are consistent,
params.bin length matches the manifest, golden fixtures are stable."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = M.ModelConfig(variant="loglinear_mamba2", **aot.CONFIGS["tiny"])
    aot.export_variant(cfg, "tiny_loglinear_mamba2", out, batch=2, decode_batches=[1])
    aot.export_golden(out)
    return out, cfg


def test_hlo_text_looks_like_hlo(exported):
    out, _ = exported
    for name in ("eval", "train_step", "decode_step"):
        files = [f for f in os.listdir(out) if f.startswith(name) and f.endswith(".hlo.txt")]
        assert files, f"missing artifact {name}"
        text = open(os.path.join(out, files[0])).read()
        assert "HloModule" in text and "ENTRY" in text
        # 64-bit-id regression guard: text form is what makes 0.5.1 accept it
        assert len(text) > 1000


def test_manifest_consistent(exported):
    out, cfg = exported
    man = json.load(open(os.path.join(out, "manifest_tiny_loglinear_mamba2.json")))
    assert man["variant"] == "loglinear_mamba2"
    n_params = sum(int(np.prod(p["shape"])) for p in man["params"])
    assert n_params == man["param_count"]
    # params.bin holds exactly param_count f32s
    raw = open(os.path.join(out, "params_tiny_loglinear_mamba2.bin"), "rb").read()
    assert len(raw) == 4 * n_params
    # train step inputs = 3x params + step/tokens/lr
    ts = man["artifacts"]["train_step"]
    assert len(ts["inputs"]) == 3 * len(man["params"]) + 3
    assert len(ts["outputs"]) == 3 * len(man["params"]) + 1


def test_params_bin_matches_init(exported):
    out, cfg = exported
    params = M.init_params(cfg, seed=0)
    flat = M.flatten_with_names(params)
    raw = np.frombuffer(
        open(os.path.join(out, "params_tiny_loglinear_mamba2.bin"), "rb").read(),
        dtype=np.float32)
    offset = 0
    for name, p in flat:
        n = int(np.prod(p.shape))
        np.testing.assert_array_equal(
            raw[offset:offset + n], np.asarray(p).ravel(), err_msg=name)
        offset += n


def test_golden_fixture_values(exported):
    out, _ = exported
    g = json.load(open(os.path.join(out, "golden_kernels.json")))
    assert g["meta"]["T"] == 32
    for key in ("mamba2", "loglinear_mamba2", "gated_deltanet", "loglinear_gdn"):
        vals = np.array(g["out"][key])
        assert vals.shape == (32 * 8,)
        assert np.isfinite(vals).all()
    # regeneration is deterministic
    from compile.kernels import ref
    q, k, v, la, beta, lam = ref.make_inputs(32, 8, 8, seed=1234)
    again = np.asarray(ref.mamba2_parallel_ref(q, k, v, la)).ravel()
    np.testing.assert_allclose(again, np.array(g["out"]["mamba2"]), atol=1e-6)
