"""Layer-1 correctness: every chunkwise kernel against its pure-jnp
oracle, swept over shapes/chunk sizes/gate ranges with hypothesis.
This is the CORE correctness signal for the compiled artifacts — the
kernels tested here are exactly what lowers into the HLO the Rust
runtime executes."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import fenwick, ref
from compile.kernels.mamba2 import mamba2_chunkwise
from compile.kernels.loglinear_mamba2 import hattention_chunkwise
from compile.kernels.gdn import gdn_chunkwise
from compile.kernels.loglinear_gdn import loglinear_gdn_chunkwise

ATOL = 2e-4
RTOL = 2e-3


def make_batched(B, T, H, dk, dv, seed, alpha_lo=0.6):
    rng = np.random.RandomState(seed)
    q = (rng.randn(B, T, H, dk) / np.sqrt(dk)).astype(np.float32)
    k = rng.randn(B, T, H, dk).astype(np.float32)
    k /= np.maximum(np.linalg.norm(k, axis=-1, keepdims=True), 1e-6)
    v = rng.randn(B, T, H, dv).astype(np.float32)
    la = np.log(rng.uniform(alpha_lo, 1.0, (B, T, H))).astype(np.float32)
    beta = rng.uniform(0.05, 1.0, (B, T, H)).astype(np.float32)
    lam = rng.uniform(0.05, 1.0, (B, T, H, fenwick.num_levels(T))).astype(np.float32)
    return q, k, v, la, beta, lam


def assert_close(a, b, label):
    a, b = np.asarray(a), np.asarray(b)
    err = np.abs(a - b) - (ATOL + RTOL * np.abs(b))
    bad = err.max()
    assert bad <= 0, f"{label}: max excess {bad:.3e} at {np.unravel_index(err.argmax(), err.shape)}"


# shapes: (T, chunk) with chunk | T and chunk a power of two
SHAPES = st.sampled_from([(32, 8), (64, 16), (64, 64), (128, 32), (96, 16), (128, 8)])
DIMS = st.sampled_from([(4, 4), (8, 8), (8, 12), (16, 8)])


@given(SHAPES, DIMS, st.integers(0, 10_000), st.sampled_from([0.3, 0.6, 0.9]))
@settings(max_examples=15, deadline=None)
def test_mamba2_kernel_vs_ref(shape, dims, seed, alpha_lo):
    (T, C), (dk, dv) = shape, dims
    q, k, v, la, _, _ = make_batched(1, T, 2, dk, dv, seed, alpha_lo)
    out = mamba2_chunkwise(q, k, v, la, chunk=C)
    assert_close(out, ref.mamba2_ref_batched(q, k, v, la), "mamba2")


@given(SHAPES, DIMS, st.integers(0, 10_000), st.sampled_from([0.3, 0.6, 0.9]))
@settings(max_examples=15, deadline=None)
def test_hattention_kernel_vs_ref(shape, dims, seed, alpha_lo):
    (T, C), (dk, dv) = shape, dims
    q, k, v, la, _, lam = make_batched(1, T, 2, dk, dv, seed, alpha_lo)
    out = hattention_chunkwise(q, k, v, la, lam, chunk=C)
    assert_close(out, ref.loglinear_mamba2_ref_batched(q, k, v, la, lam), "hattention")


@given(SHAPES, DIMS, st.integers(0, 10_000))
@settings(max_examples=12, deadline=None)
def test_gdn_kernel_vs_ref(shape, dims, seed):
    (T, C), (dk, dv) = shape, dims
    q, k, v, la, beta, _ = make_batched(1, T, 2, dk, dv, seed)
    out = gdn_chunkwise(q, k, v, la, beta, chunk=C)
    assert_close(out, ref.gdn_ref_batched(q, k, v, la, beta), "gdn")


@given(SHAPES, DIMS, st.integers(0, 10_000))
@settings(max_examples=12, deadline=None)
def test_loglinear_gdn_kernel_vs_ref(shape, dims, seed):
    (T, C), (dk, dv) = shape, dims
    q, k, v, la, beta, lam = make_batched(1, T, 2, dk, dv, seed)
    out = loglinear_gdn_chunkwise(q, k, v, la, beta, lam, chunk=C)
    assert_close(out, ref.loglinear_gdn_ref_batched(q, k, v, la, beta, lam), "ll-gdn")


def test_pallas_equals_jnp_twin():
    """The Pallas path and its jnp twin (used for the backward pass) must
    agree exactly on the intra-chunk stage."""
    q, k, v, la, _, lam = make_batched(2, 64, 3, 8, 8, 7)
    a = hattention_chunkwise(q, k, v, la, lam, chunk=16, use_pallas=True)
    b = hattention_chunkwise(q, k, v, la, lam, chunk=16, use_pallas=False)
    assert_close(a, b, "pallas vs jnp twin")
    a = mamba2_chunkwise(q, k, v, la, chunk=16, use_pallas=True)
    b = mamba2_chunkwise(q, k, v, la, chunk=16, use_pallas=False)
    assert_close(a, b, "mamba2 pallas vs jnp twin")


def test_loglinear_collapses_to_linear_variant():
    """λ ≡ 1 ⇒ log-linear == linear counterpart (paper §3.1)."""
    q, k, v, la, beta, lam = make_batched(1, 64, 2, 8, 8, 3)
    ones = np.ones_like(lam)
    a = hattention_chunkwise(q, k, v, la, ones, chunk=16)
    b = mamba2_chunkwise(q, k, v, la, chunk=16)
    assert_close(a, b, "λ=1 collapse (mamba2)")
    a = loglinear_gdn_chunkwise(q, k, v, la, beta, ones, chunk=16)
    b = gdn_chunkwise(q, k, v, la, beta, chunk=16)
    assert_close(a, b, "λ=1 collapse (gdn)")


def test_recurrent_refs_match_parallel_refs():
    """The two independent oracle formulations agree (incl. the Fenwick
    O(log T) recurrence of §3.2)."""
    T, dk, dv = 64, 8, 8
    q, k, v, la, beta, lam = ref.make_inputs(T, dk, dv, seed=9)
    assert_close(
        ref.mamba2_recurrent_ref(q, k, v, la),
        ref.mamba2_parallel_ref(q, k, v, la), "mamba2 rec/par")
    assert_close(
        ref.loglinear_mamba2_recurrent_ref(q, k, v, la, lam),
        ref.loglinear_mamba2_parallel_ref(q, k, v, la, lam), "llm2 rec/par")
    assert_close(
        ref.gdn_recurrent_ref(q, k, v, la, beta),
        ref.gdn_parallel_ref(q, k, v, la, beta), "gdn rec/par")
    assert_close(
        ref.loglinear_gdn_recurrent_ref(q, k, v, la, beta, lam),
        ref.loglinear_gdn_parallel_ref(q, k, v, la, beta, lam), "llgdn rec/par")


def test_kernels_differentiable():
    """Grads flow through the custom_vjp (the paper's hand-written bwd)."""
    import jax

    q, k, v, la, _, lam = make_batched(1, 32, 2, 4, 4, 11)

    def f(q, k, v, la, lam):
        return jnp.sum(hattention_chunkwise(q, k, v, la, lam, chunk=8) ** 2)

    grads = jax.grad(f, argnums=(0, 1, 2, 3, 4))(q, k, v, la, lam)
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()
    # compare against grads of the pure-ref formulation
    def f_ref(q, k, v, la, lam):
        return jnp.sum(ref.loglinear_mamba2_ref_batched(q, k, v, la, lam) ** 2)

    grads_ref = jax.grad(f_ref, argnums=(0, 1, 2, 3, 4))(q, k, v, la, lam)
    for g, gr, name in zip(grads, grads_ref, "qkv,la,lam".split(",")):
        assert_close(g, gr, f"grad {name}")


def test_extreme_gates_no_nan():
    """Near-zero gates (heavy forgetting) must not produce NaN/Inf."""
    q, k, v, la, beta, lam = make_batched(1, 64, 2, 8, 8, 13)
    la = np.full_like(la, np.log(1e-3))
    for out in [
        mamba2_chunkwise(q, k, v, la, chunk=16),
        hattention_chunkwise(q, k, v, la, lam, chunk=16),
        gdn_chunkwise(q, k, v, la, beta, chunk=16),
        loglinear_gdn_chunkwise(q, k, v, la, beta, lam, chunk=16),
    ]:
        assert np.isfinite(np.asarray(out)).all()
