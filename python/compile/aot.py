"""AOT export: lower the L2 JAX model (with its L1 Pallas kernels inlined)
to HLO **text** artifacts that the Rust runtime loads via PJRT.

Why HLO text: jax ≥ 0.5 serializes HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Per (config, variant) we export:

- ``eval_<name>.hlo.txt``       (params…, tokens)         -> (loss, per_pos_loss, argmax_preds)
- ``train_step_<name>.hlo.txt`` (params…, m…, v…, step, tokens, lr)
                                                          -> (params'…, m'…, v'…, loss)
- ``decode_step_<name>.hlo.txt``(params…, states…, token, pos) -> (logits, states'…)
- ``prefill_<name>.hlo.txt``    (params…, tokens, start)  -> (logits, states…)
- ``manifest_<name>.json``      parameter/state names + shapes (the Rust
                                marshalling contract)
- ``params_<name>.bin``         initial parameters, raw little-endian f32
                                in manifest order

Python runs ONCE (`make artifacts`); nothing here is on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from dataclasses import replace as dataclasses_replace

from . import decode as D
from . import model as M

# Named configurations. "tiny" is the CI config; "lm" is the e2e
# language-model config (scaled from the paper's 21-layer/1536-dim models
# per DESIGN.md §6 substitutions); "mqar*" are the Table-2 models.
CONFIGS = {
    "tiny": dict(vocab=256, d_model=64, n_layers=2, n_heads=2, dk=16, dv=16,
                 d_mlp=128, seq_len=64, chunk=16),
    "lm": dict(vocab=512, d_model=256, n_layers=4, n_heads=8, dk=32, dv=32,
               d_mlp=512, seq_len=256, chunk=32),
    "lm-long": dict(vocab=512, d_model=128, n_layers=4, n_heads=4, dk=32, dv=32,
                    d_mlp=256, seq_len=1024, chunk=64),
    "mqar16": dict(vocab=192, d_model=16, n_layers=2, n_heads=1, dk=16, dv=16,
                   d_mlp=32, seq_len=256, chunk=32),
    "mqar32": dict(vocab=192, d_model=32, n_layers=2, n_heads=1, dk=16, dv=32,
                   d_mlp=64, seq_len=256, chunk=32),
    "mqar64": dict(vocab=192, d_model=64, n_layers=2, n_heads=2, dk=16, dv=32,
                   d_mlp=128, seq_len=256, chunk=32),
    # task-pretraining config: trained once at seq 256, evaluated at
    # {64, 128, 256} via extra eval artifacts (NIAH / retrieval / LongBench)
    "task": dict(vocab=256, d_model=64, n_layers=2, n_heads=2, dk=16, dv=32,
                 d_mlp=128, seq_len=256, chunk=32),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned on parse).

    ``print_large_constants=True`` is load-bearing: the default text dump
    elides big constant arrays as ``{...}``, which the 0.5.1 parser fills
    with ZEROS — silently corrupting level-index matrices, causal masks,
    and RoPE tables. (Found the hard way; see EXPERIMENTS.md §Perf log.)
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _spec(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def export_variant(cfg: M.ModelConfig, name: str, outdir: str, batch: int,
                   decode_batches: Sequence[int] = (1, 4, 8), seed: int = 0,
                   skip_decode: bool = False,
                   eval_seqs: Sequence[int] = ()) -> None:
    os.makedirs(outdir, exist_ok=True)
    params = M.init_params(cfg, seed=seed)
    flat = M.flatten_with_names(params)
    pnames = [n for n, _ in flat]
    pleaves = [p for _, p in flat]

    manifest = {
        "name": name,
        "variant": cfg.variant,
        "config": {k: getattr(cfg, k) for k in (
            "vocab", "d_model", "n_layers", "n_heads", "dk", "dv",
            "d_mlp", "seq_len", "chunk")},
        "num_levels": cfg.num_levels,
        "params": [{"name": n, "shape": list(p.shape)} for n, p in flat],
        "param_count": M.param_count(params),
        "batch": batch,
        "decode_batches": list(decode_batches),
        "artifacts": {},
    }

    tokens_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)

    # ---- eval: (params…, tokens) -> (loss, per-pos loss, argmax preds) ----
    def eval_fn(*args):
        leaves, tokens = args[:-1], args[-1]
        p = M.unflatten_like(params, leaves)
        logits = M.forward_logits(cfg, p, tokens)
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        pp = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.mean(pp), pp, preds

    low = jax.jit(eval_fn).lower(*[_spec(p) for p in pleaves], tokens_spec)
    path = f"eval_{name}.hlo.txt"
    with open(os.path.join(outdir, path), "w") as f:
        f.write(to_hlo_text(low))
    manifest["artifacts"]["eval"] = {
        "path": path,
        "inputs": pnames + ["tokens"],
        "outputs": ["loss", "per_pos_loss", "preds"],
    }

    # extra eval artifacts at other sequence lengths, sharing the same
    # parameter set (cfg.levels pins the λ head size across lengths)
    for es in eval_seqs:
        if es == cfg.seq_len:
            continue
        assert cfg.num_levels >= __import__("compile.kernels.fenwick", fromlist=["x"]).num_levels(es)
        ecfg = dataclasses_replace(cfg, seq_len=es, levels=cfg.num_levels)
        etok = jax.ShapeDtypeStruct((batch, es), jnp.int32)

        def eval_fn_s(*args, _ecfg=ecfg):
            leaves, tokens = args[:-1], args[-1]
            p = M.unflatten_like(params, leaves)
            logits = M.forward_logits(_ecfg, p, tokens)
            logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
            tgt = tokens[:, 1:]
            pp = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
            preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jnp.mean(pp), pp, preds

        low = jax.jit(eval_fn_s).lower(*[_spec(p) for p in pleaves], etok)
        path = f"eval_{name}_s{es}.hlo.txt"
        with open(os.path.join(outdir, path), "w") as f:
            f.write(to_hlo_text(low))
        manifest["artifacts"][f"eval_s{es}"] = {
            "path": path,
            "inputs": pnames + ["tokens"],
            "outputs": ["loss", "per_pos_loss", "preds"],
            "seq_len": es,
        }

    # ---- train step ----
    def train_fn(*args):
        n = len(pleaves)
        p = M.unflatten_like(params, args[:n])
        m_ = M.unflatten_like(params, args[n:2 * n])
        v_ = M.unflatten_like(params, args[2 * n:3 * n])
        step, tokens, lr = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        p2, m2, v2, loss = M.adam_train_step(cfg, p, m_, v_, step, tokens, lr)
        return (
            tuple(x for _, x in M.flatten_with_names(p2))
            + tuple(x for _, x in M.flatten_with_names(m2))
            + tuple(x for _, x in M.flatten_with_names(v2))
            + (loss,)
        )

    specs = [_spec(p) for p in pleaves] * 3 + [
        jax.ShapeDtypeStruct((), jnp.int32),
        tokens_spec,
        jax.ShapeDtypeStruct((), jnp.float32),
    ]
    low = jax.jit(train_fn).lower(*specs)
    path = f"train_step_{name}.hlo.txt"
    with open(os.path.join(outdir, path), "w") as f:
        f.write(to_hlo_text(low))
    manifest["artifacts"]["train_step"] = {
        "path": path,
        "inputs": (pnames + [f"m.{n}" for n in pnames] + [f"v.{n}" for n in pnames]
                   + ["step", "tokens", "lr"]),
        "outputs": (pnames + [f"m.{n}" for n in pnames] + [f"v.{n}" for n in pnames]
                    + ["loss"]),
    }

    # ---- decode step + prefill (recurrent variants only) ----
    if cfg.variant != "transformer" and not skip_decode:
        state_template = D.init_decode_state(cfg, 1)
        manifest["state_shapes"] = [list(s.shape[1:]) for s in state_template]
        for db in decode_batches:
            states = D.init_decode_state(cfg, db)

            def decode_fn(*args):
                n = len(pleaves)
                p = M.unflatten_like(params, args[:n])
                sts = list(args[n:n + cfg.n_layers])
                token = args[n + cfg.n_layers]
                pos = args[n + cfg.n_layers + 1]
                logits, sts2 = D.decode_step(cfg, p, sts, token, pos)
                return (logits,) + tuple(sts2)

            specs = ([_spec(p) for p in pleaves] + [_spec(s) for s in states]
                     + [jax.ShapeDtypeStruct((db,), jnp.int32),
                        jax.ShapeDtypeStruct((db,), jnp.int32)])
            low = jax.jit(decode_fn).lower(*specs)
            path = f"decode_step_{name}_b{db}.hlo.txt"
            with open(os.path.join(outdir, path), "w") as f:
                f.write(to_hlo_text(low))
            manifest["artifacts"][f"decode_step_b{db}"] = {
                "path": path,
                "inputs": pnames + [f"state_{i}" for i in range(cfg.n_layers)]
                + ["token", "pos"],
                "outputs": ["logits"] + [f"state_{i}" for i in range(cfg.n_layers)],
            }

    # ---- initial params ----
    bin_path = os.path.join(outdir, f"params_{name}.bin")
    with open(bin_path, "wb") as f:
        for p in pleaves:
            f.write(np.asarray(p, dtype=np.float32).tobytes())
    manifest["artifacts"]["params_bin"] = {"path": f"params_{name}.bin"}

    with open(os.path.join(outdir, f"manifest_{name}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] exported {name}: {manifest['param_count']} params -> {outdir}")


def export_golden(outdir: str) -> None:
    """Golden cross-layer fixtures: deterministic kernel inputs + ref
    outputs, asserted identically by pytest and `cargo test`."""
    from .kernels import ref

    os.makedirs(outdir, exist_ok=True)
    T, dk, dv = 32, 8, 8
    q, k, v, la, beta, lam = ref.make_inputs(T, dk, dv, seed=1234)
    cases = {
        "meta": {"T": T, "dk": dk, "dv": dv, "seed": 1234},
        "q": q.ravel().tolist(),
        "k": k.ravel().tolist(),
        "v": v.ravel().tolist(),
        "log_alpha": la.ravel().tolist(),
        "beta": beta.ravel().tolist(),
        "lam": lam.ravel().tolist(),
        "out": {
            "mamba2": np.asarray(ref.mamba2_parallel_ref(q, k, v, la)).ravel().tolist(),
            "loglinear_mamba2": np.asarray(
                ref.loglinear_mamba2_parallel_ref(q, k, v, la, lam)).ravel().tolist(),
            "gated_deltanet": np.asarray(
                ref.gdn_parallel_ref(q, k, v, la, beta)).ravel().tolist(),
            "loglinear_gdn": np.asarray(
                ref.loglinear_gdn_parallel_ref(q, k, v, la, beta, lam)).ravel().tolist(),
        },
    }
    with open(os.path.join(outdir, "golden_kernels.json"), "w") as f:
        json.dump(cases, f)
    print(f"[aot] golden fixtures -> {outdir}/golden_kernels.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", default="tiny", choices=sorted(CONFIGS.keys()))
    ap.add_argument("--variants", default="mamba2,loglinear_mamba2,gdn,loglinear_gdn,transformer")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--decode-batches", default="1,4,8")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-decode", action="store_true")
    ap.add_argument("--skip-golden", action="store_true")
    ap.add_argument("--eval-seqs", default="",
                    help="extra eval-artifact sequence lengths, comma separated")
    args = ap.parse_args()
    eval_seqs = [int(x) for x in args.eval_seqs.split(",") if x]

    for variant in args.variants.split(","):
        variant = variant.strip()
        assert variant in M.VARIANTS, f"unknown variant {variant}"
        cfg = M.ModelConfig(variant=variant, **CONFIGS[args.config])
        name = f"{args.config}_{variant}"
        export_variant(
            cfg, name, args.out, args.batch,
            decode_batches=[int(x) for x in args.decode_batches.split(",")],
            seed=args.seed, skip_decode=args.skip_decode, eval_seqs=eval_seqs,
        )
    if not args.skip_golden:
        export_golden(args.out)


if __name__ == "__main__":
    main()
