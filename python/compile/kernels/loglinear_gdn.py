"""Log-Linear Gated DeltaNet chunkwise kernel (paper §3.4, Algorithm 1
with gated-Householder chunk transitions).

Structure per chunk ``z``:

1. **Fenwick merge** at chunk granularity (the §3.2 recurrence lifted to
   chunks): levels ``0..lssb(z)`` of the state stack sum into
   ``lssb(z)+1``.
2. **Intra-chunk** (bespoke): the local attention matrix
   ``P = (tril(QK^T) ⊙ Gratio) (I + StrictTril(M))^{-1} diag(β)`` is
   *materialized* (the λ mask must be applied to P elementwise — the UT
   solve mixes value rows otherwise) and masked with the local H-mask.
3. **Inter-chunk reads**: effective queries ``q̂_t = G_t R_t q_t`` where
   ``R_t = Φ_start···Φ_t`` accumulates via rank-1 updates in a scan; all
   levels are read from a single stacked einsum (level fusion).
4. **Transition + write**: carried states transform by the chunk operator
   ``E_z = G_C R_C^T`` (one (dk,dk) matmul against the stack); the chunk's
   own state enters at level 0.

Pure jnp; batched over (B, H) by vmap. Shapes as in the other kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import fenwick
from .gdn import _chunk_precompute, unit_lower_inv


def _merge(states, z):
    """Fenwick merge on a (slots, dk, dv) stack for traced chunk index z."""
    slots = states.shape[0]
    l = fenwick.lssb_traced(z)
    idx = jnp.arange(slots)
    le = (idx <= l)[:, None, None]
    merged = jnp.sum(jnp.where(le, states, 0.0), axis=0)
    states = jnp.where(le, 0.0, states)
    states = jnp.where((idx == l + 1)[:, None, None], merged[None], states)
    return states


def _llgdn_head(q, k, v, la, beta, lam, chunk):
    T, dk = q.shape
    dv = v.shape[1]
    C = chunk
    Z = T // C
    lc = int(np.log2(C))
    L = lam.shape[1]

    qc = q.reshape(Z, C, dk)
    kc = k.reshape(Z, C, dk)
    vc = v.reshape(Z, C, dv)
    lac = la.reshape(Z, C)
    bc = beta.reshape(Z, C)
    lamc = lam.reshape(Z, C, L)

    cs, g, sys, qk_tril = _chunk_precompute(qc, kc, lac, bc)

    # ---- intra-chunk: materialized local P, masked by local H-mask ----
    inv_sys = unit_lower_inv(sys)
    p_loc = jnp.einsum("zij,zjl->zil", qk_tril, inv_sys) * bc[:, None, :]
    lvl = jnp.asarray(fenwick.level_index_matrix(C))            # (C, C)
    lam_local = jnp.take_along_axis(
        lamc, jnp.broadcast_to(jnp.maximum(lvl, 0)[None], (Z, C, C)), axis=2
    )                                                            # [z,i,j] = lam[z,i,lvl(i,j)]
    lam_local = jnp.where((lvl >= 0)[None], lam_local, 0.0)
    y_diag = jnp.einsum("zij,zjd->zid", p_loc * lam_local, vc)

    # chunk's own outgoing state: Ŵ0 = sys^{-1} diag(β) V, S = Σ (G_C/G_s) k ŵ^T
    w0 = jnp.einsum("zij,zjd->zid", inv_sys, bc[..., None] * vc)
    own_state = jnp.einsum("zc,zck,zcd->zkd", jnp.exp(cs[:, -1:] - cs), kc, w0)

    # ---- inter-chunk ----
    n_slots = max(fenwick.num_levels(Z), 2)  # state stack slots (chunk level)
    n_inter = fenwick.num_levels(Z) - 1 if Z > 1 else 0
    lam_inter = (
        lamc[..., lc + 1: lc + 1 + n_inter]
        if n_inter > 0
        else jnp.zeros((Z, C, 0), q.dtype)
    )

    def rq_step(r, inp):
        """Accumulate R_t = Φ_start···Φ_t by rank-1 updates; emit R_t q_t."""
        qt, kt, bt = inp
        r = r - bt * jnp.outer(r @ kt, kt)            # R ← R (I − β k k^T)
        return r, r @ qt

    def chunk_step(carry, inp):
        states, z = carry                              # (slots, dk, dv)
        qz, kz, gz, bz, lamz, own = inp
        states = jax.lax.cond(z > 0, lambda s: _merge(s, z), lambda s: s, states)
        # effective queries for this chunk
        r_end, rq = jax.lax.scan(rq_step, jnp.eye(dk, dtype=q.dtype), (qz, kz, bz))
        q_eff = gz[:, None] * rq                       # (C, dk)
        # fused multi-level read: o_t = Σ_m λ[t, lc+m] q̂_t^T S^(m)
        y_off = jnp.einsum("cm,ck,mkd->cd", lamz, q_eff, states[1: 1 + n_inter])
        # transition the whole stack by E_z = G_C R_C^T, then write level 0
        states = gz[-1] * jnp.einsum("jk,skd->sjd", r_end.T, states)
        states = states.at[0].set(own)
        return (states, z + 1), y_off

    init = (jnp.zeros((n_slots, dk, dv), q.dtype), jnp.int32(0))
    _, y_off = jax.lax.scan(chunk_step, init, (qc, kc, g, bc, lam_inter, own_state))

    return (y_diag + y_off).reshape(T, dv)


@functools.partial(jax.jit, static_argnames=("chunk",))
def loglinear_gdn_chunkwise(q, k, v, log_alpha, beta, lam, *, chunk: int = 16):
    """Batched chunkwise Log-Linear Gated DeltaNet."""
    B, T, H, dk = q.shape
    C = chunk
    assert C >= 1 and (C & (C - 1)) == 0, "chunk must be a power of two"
    assert T % C == 0, f"T={T} must be a multiple of chunk={C}"
    assert lam.shape[-1] >= fenwick.num_levels(T)
    f = functools.partial(_llgdn_head, chunk=chunk)
    inner = jax.vmap(f, in_axes=(1, 1, 1, 1, 1, 1), out_axes=1)
    outer = jax.vmap(inner, in_axes=(0, 0, 0, 0, 0, 0), out_axes=0)
    return outer(q, k, v, log_alpha, beta, lam)
