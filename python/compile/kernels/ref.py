"""Pure-jnp correctness oracles for every kernel (Layer 1's ground truth).

Two independent formulations per model:

- ``*_parallel_ref``: the masked parallel form ``O = (A ⊙ M) V`` with the
  mask materialized densely from first principles (Eq. 4). O(T^2) but
  unambiguous; mirrors the Rust oracles bit-for-bit-ish.
- ``*_recurrent_ref``: ``lax.scan`` recurrences — including the Fenwick
  O(log T)-state recurrence of §3.2, which the decode step reuses.

Per-head signatures: ``q, k: (T, dk)``, ``v: (T, dv)``,
``log_alpha, beta: (T,)``, ``lam: (T, num_levels)``. Batched wrappers
vmap over (B, H) with inputs shaped (B, T, H, ...).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import fenwick


# ---------------------------------------------------------------------------
# Mask construction
# ---------------------------------------------------------------------------

def sss_mask(log_alpha):
    """1-semiseparable mask M^S[t,s] = exp(sum log_alpha[s+1..t])."""
    return jnp.exp(fenwick.segsum(log_alpha)).astype(log_alpha.dtype)


def hmask(lam, T: int):
    """M^H[t,s] = lam[t, level_of(t,s)] for s <= t else 0 (Eq. 4)."""
    lvl = jnp.asarray(fenwick.level_index_matrix(T))  # (T,T), -1 above diag
    gathered = jnp.take_along_axis(lam, jnp.maximum(lvl, 0), axis=1)
    return jnp.where(lvl >= 0, gathered, 0.0)


def quasi_mask(log_alpha, lam):
    """M = M^S ⊙ M^H — the quasi-hierarchical mask."""
    T = log_alpha.shape[0]
    return sss_mask(log_alpha) * hmask(lam, T)


def delta_attn_matrix(q, k, beta):
    """DeltaNet attention matrix A^δ = tril(QK^T) B^{-1} diag(β) with
    B = I + StrictTril(diag(β) K K^T) (the paper's T_K(QK^T))."""
    T = q.shape[0]
    tril = jnp.tril(jnp.ones((T, T), dtype=bool))
    stril = jnp.tril(jnp.ones((T, T), dtype=bool), k=-1)
    b_sys = jnp.eye(T, dtype=q.dtype) + jnp.where(
        stril, beta[:, None] * (k @ k.T), 0.0
    )
    qk = jnp.where(tril, q @ k.T, 0.0)
    # A B = qk  (per row of A)  =>  B^T A^T = qk^T
    a_t = jax.scipy.linalg.solve_triangular(b_sys.T, qk.T, lower=False, unit_diagonal=True)
    return a_t.T * beta[None, :]


# ---------------------------------------------------------------------------
# Parallel (masked) references
# ---------------------------------------------------------------------------

def linear_parallel_ref(q, k, v):
    T = q.shape[0]
    p = jnp.where(jnp.tril(jnp.ones((T, T), dtype=bool)), q @ k.T, 0.0)
    return p @ v


def mamba2_parallel_ref(q, k, v, log_alpha):
    p = jnp.tril(q @ k.T) * sss_mask(log_alpha)
    return p @ v


def loglinear_mamba2_parallel_ref(q, k, v, log_alpha, lam):
    p = jnp.tril(q @ k.T) * quasi_mask(log_alpha, lam)
    return p @ v


def gdn_parallel_ref(q, k, v, log_alpha, beta):
    p = delta_attn_matrix(q, k, beta) * sss_mask(log_alpha)
    return p @ v


def loglinear_gdn_parallel_ref(q, k, v, log_alpha, beta, lam):
    p = delta_attn_matrix(q, k, beta) * quasi_mask(log_alpha, lam)
    return p @ v


def softmax_attention_ref(q, k, v):
    T, dk = q.shape
    scores = (q @ k.T) / jnp.sqrt(jnp.array(dk, dtype=q.dtype))
    scores = jnp.where(jnp.tril(jnp.ones((T, T), dtype=bool)), scores, -jnp.inf)
    return jax.nn.softmax(scores, axis=-1) @ v


# ---------------------------------------------------------------------------
# Recurrent references (lax.scan)
# ---------------------------------------------------------------------------

def mamba2_recurrent_ref(q, k, v, log_alpha):
    """S_t = α_t S_{t-1} + k_t v_t^T, o_t = S_t^T q_t."""
    dk, dv = q.shape[1], v.shape[1]

    def step(s, inp):
        qt, kt, vt, la = inp
        s = jnp.exp(la) * s + jnp.outer(kt, vt)
        return s, s.T @ qt

    _, o = jax.lax.scan(step, jnp.zeros((dk, dv), q.dtype), (q, k, v, log_alpha))
    return o


def gdn_recurrent_ref(q, k, v, log_alpha, beta):
    """S_t = α_t (I − β_t k_t k_t^T) S_{t-1} + β_t k_t v_t^T."""
    dk, dv = q.shape[1], v.shape[1]

    def step(s, inp):
        qt, kt, vt, la, bt = inp
        s = s - bt * jnp.outer(kt, kt @ s)
        s = jnp.exp(la) * s + bt * jnp.outer(kt, vt)
        return s, s.T @ qt

    _, o = jax.lax.scan(
        step, jnp.zeros((dk, dv), q.dtype), (q, k, v, log_alpha, beta)
    )
    return o


def _fenwick_merge(states, t):
    """One Fenwick merge step (§3.2) on a (L+1, dk, dv) state stack (L slots) for a
    traced time index t >= 1: levels 0..lssb(t) sum into level lssb(t)+1."""
    L = states.shape[0]
    l = fenwick.lssb_traced(t)
    idx = jnp.arange(L)
    le = (idx <= l)[:, None, None]
    merged = jnp.sum(jnp.where(le, states, 0.0), axis=0)
    states = jnp.where(le, 0.0, states)
    states = jnp.where((idx == l + 1)[:, None, None], merged[None], states)
    return states


def loglinear_mamba2_recurrent_ref(q, k, v, log_alpha, lam):
    """The §3.2 Fenwick recurrence: O(log T) live states."""
    T, dk = q.shape
    dv = v.shape[1]
    L = lam.shape[1]

    def step(carry, inp):
        states, t = carry
        qt, kt, vt, la, lt = inp
        states = jax.lax.cond(t > 0, lambda s: _fenwick_merge(s, t), lambda s: s, states)
        states = jnp.exp(la) * states
        states = states.at[0].set(jnp.outer(kt, vt))
        o = jnp.einsum("l,lkv,k->v", lt, states, qt)
        return (states, t + 1), o

    init = (jnp.zeros((L, dk, dv), q.dtype), jnp.int32(0))
    _, o = jax.lax.scan(step, init, (q, k, v, log_alpha, lam))
    return o


def loglinear_gdn_recurrent_ref(q, k, v, log_alpha, beta, lam):
    """Fenwick recurrence with gated Householder transitions."""
    T, dk = q.shape
    dv = v.shape[1]
    L = lam.shape[1]

    def step(carry, inp):
        states, t = carry
        qt, kt, vt, la, bt, lt = inp
        states = jax.lax.cond(t > 0, lambda s: _fenwick_merge(s, t), lambda s: s, states)
        # S ← α (I − β k k^T) S for every level
        proj = jnp.einsum("k,lkv->lv", kt, states)
        states = states - bt * kt[None, :, None] * proj[:, None, :]
        states = jnp.exp(la) * states
        states = states.at[0].set(bt * jnp.outer(kt, vt))
        o = jnp.einsum("l,lkv,k->v", lt, states, qt)
        return (states, t + 1), o

    init = (jnp.zeros((L, dk, dv), q.dtype), jnp.int32(0))
    _, o = jax.lax.scan(step, init, (q, k, v, log_alpha, beta, lam))
    return o


# ---------------------------------------------------------------------------
# Batched wrappers: (B, T, H, ...) -> (B, T, H, dv)
# ---------------------------------------------------------------------------

def _batch_heads(fn, *args):
    """vmap a per-head (T, ...) function over batch (axis 0) and head
    (axis 2 of the (B, T, H, ...) layout)."""
    inner = jax.vmap(fn, in_axes=tuple(1 for _ in args), out_axes=1)  # heads
    outer = jax.vmap(inner, in_axes=tuple(0 for _ in args), out_axes=0)  # batch
    return outer(*args)


def mamba2_ref_batched(q, k, v, log_alpha):
    return _batch_heads(mamba2_parallel_ref, q, k, v, log_alpha)


def loglinear_mamba2_ref_batched(q, k, v, log_alpha, lam):
    return _batch_heads(loglinear_mamba2_parallel_ref, q, k, v, log_alpha, lam)


def gdn_ref_batched(q, k, v, log_alpha, beta):
    return _batch_heads(gdn_parallel_ref, q, k, v, log_alpha, beta)


def loglinear_gdn_ref_batched(q, k, v, log_alpha, beta, lam):
    return _batch_heads(loglinear_gdn_parallel_ref, q, k, v, log_alpha, beta, lam)


def softmax_ref_batched(q, k, v):
    return _batch_heads(softmax_attention_ref, q, k, v)


# ---------------------------------------------------------------------------
# Deterministic golden-fixture inputs (shared with the Rust tests)
# ---------------------------------------------------------------------------

def make_inputs(T: int, dk: int, dv: int, seed: int = 0):
    """Deterministic per-head inputs matching the Rust test conventions:
    normalized keys, gates in (0.75, 1), betas in (0.1, 1), lam in (0.05, 1)."""
    rng = np.random.RandomState(seed)
    q = (rng.randn(T, dk) / np.sqrt(dk)).astype(np.float32)
    k = rng.randn(T, dk).astype(np.float32)
    k /= np.maximum(np.linalg.norm(k, axis=1, keepdims=True), 1e-6)
    v = rng.randn(T, dv).astype(np.float32)
    alpha = rng.uniform(0.75, 1.0, size=T).astype(np.float32)
    beta = rng.uniform(0.1, 1.0, size=T).astype(np.float32)
    lam = rng.uniform(0.05, 1.0, size=(T, fenwick.num_levels(T))).astype(np.float32)
    return q, k, v, np.log(alpha), beta, lam
