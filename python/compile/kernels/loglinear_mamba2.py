"""Log-Linear Mamba-2 chunkwise kernel ("hattention", paper §3.3–3.5).

TPU/Pallas adaptation of the paper's H100/Triton kernel (see DESIGN.md
§Hardware-Adaptation):

- **Intra-chunk stage** (`_intra_chunk_kernel`): one Pallas program per
  (batch·head, chunk). The (C, C) H-masked score block lives in VMEM; the
  level-index matrix rides along as a broadcast input; `Q K^T` and `P V`
  hit the MXU. This is the "bespoke intra-chunk implementation" of §5.
- **Inter-chunk stage** (fused, jnp in the same jit): all
  `log2(T/C)` levels are folded into ONE masked chunk-to-chunk transfer
  einsum (level fusion, §3.5 / App. C) — contrast the paper's naive
  variant that re-launches a Mamba-2 primitive per level.

The Pallas stage carries a ``custom_vjp`` whose backward is the VJP of the
jnp twin — mirroring the paper's hand-written Triton backward (§5).

Pallas runs with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls; on a real TPU the same BlockSpec schedule compiles
natively. Correctness is asserted against ``ref.py`` by pytest.

Shapes: ``q, k: (B, T, H, dk)``, ``v: (B, T, H, dv)``,
``log_alpha: (B, T, H)``, ``lam: (B, T, H, L)`` with
``L = num_levels(T)``; ``T`` must be a multiple of the chunk size ``C``
(power of two).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import fenwick


def _intra_chunk_kernel(q_ref, k_ref, v_ref, la_ref, lam_ref, lvl_ref, o_ref):
    """One (batch·head, chunk) program: Y_diag = (QK^T ⊙ M^S ⊙ M^H_local) V."""
    q = q_ref[0]          # (C, dk)
    k = k_ref[0]          # (C, dk)
    v = v_ref[0]          # (C, dv)
    la = la_ref[0]        # (C,)
    lam = lam_ref[0]      # (C, L)

    cum = jnp.cumsum(la)  # (C,)
    lvl = lvl_ref[...]    # (C, C) level-index matrix (same for all chunks)
    causal = lvl >= 0
    # gate decay, masked in log-space to avoid inf*0 above the diagonal
    logdec = jnp.where(causal, cum[:, None] - cum[None, :], -jnp.inf)
    decay = jnp.exp(logdec)
    # λ gathered by intra-chunk level (levels 0..log2(C))
    hm = jnp.where(
        causal,
        jnp.take_along_axis(lam, jnp.maximum(lvl, 0), axis=1),
        0.0,
    )
    scores = (q @ k.T) * decay * hm          # MXU matmul + VPU mask
    o_ref[0] = scores @ v                    # MXU matmul


def _intra_jnp(chunk, qf, kf, vf, laf, lamf):
    """jnp twin of the Pallas intra-chunk stage (backward pass + ablation)."""
    BH, T, dk = qf.shape
    dv = vf.shape[-1]
    L = lamf.shape[-1]
    C = chunk
    Z = T // C
    qc = qf.reshape(BH, Z, C, dk)
    kc = kf.reshape(BH, Z, C, dk)
    vc = vf.reshape(BH, Z, C, dv)
    lac = laf.reshape(BH, Z, C)
    lamc = lamf.reshape(BH, Z, C, L)
    cum = jnp.cumsum(lac, axis=-1)
    lvl = jnp.asarray(fenwick.level_index_matrix(C))
    causal = lvl >= 0
    logdec = jnp.where(causal[None, None], cum[..., :, None] - cum[..., None, :], -jnp.inf)
    hm = jnp.take_along_axis(
        lamc, jnp.broadcast_to(jnp.maximum(lvl, 0)[None, None], (BH, Z, C, C)), axis=3
    )
    hm = jnp.where(causal[None, None], hm, 0.0)
    scores = jnp.einsum("bzik,bzjk->bzij", qc, kc) * jnp.exp(logdec) * hm
    return jnp.einsum("bzij,bzjd->bzid", scores, vc).reshape(BH, T, dv)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _intra_op(chunk, interpret, qf, kf, vf, laf, lamf):
    BH, T, dk = qf.shape
    dv = vf.shape[-1]
    L = lamf.shape[-1]
    C = chunk
    Z = T // C
    level_idx = jnp.asarray(fenwick.level_index_matrix(C))
    return pl.pallas_call(
        _intra_chunk_kernel,
        grid=(BH, Z),
        in_specs=[
            pl.BlockSpec((1, C, dk), lambda b, z: (b, z, 0)),
            pl.BlockSpec((1, C, dk), lambda b, z: (b, z, 0)),
            pl.BlockSpec((1, C, dv), lambda b, z: (b, z, 0)),
            pl.BlockSpec((1, C), lambda b, z: (b, z)),
            pl.BlockSpec((1, C, L), lambda b, z: (b, z, 0)),
            pl.BlockSpec((C, C), lambda b, z: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, dv), lambda b, z: (b, z, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, dv), vf.dtype),
        interpret=interpret,
    )(qf, kf, vf, laf, lamf, level_idx)


def _intra_op_fwd(chunk, interpret, qf, kf, vf, laf, lamf):
    return _intra_op(chunk, interpret, qf, kf, vf, laf, lamf), (qf, kf, vf, laf, lamf)


def _intra_op_bwd(chunk, interpret, res, g):
    qf, kf, vf, laf, lamf = res
    _, vjp = jax.vjp(
        lambda q, k, v, la, lam: _intra_jnp(chunk, q, k, v, la, lam),
        qf, kf, vf, laf, lamf,
    )
    return vjp(g)


_intra_op.defvjp(_intra_op_fwd, _intra_op_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_pallas"))
def hattention_chunkwise(q, k, v, log_alpha, lam, *, chunk: int = 16,
                         interpret: bool = True, use_pallas: bool = True):
    """Chunkwise-parallel log-linear Mamba-2 forward (Algorithm 1)."""
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    C = chunk
    assert C >= 1 and (C & (C - 1)) == 0, "chunk must be a power of two"
    assert T % C == 0, f"T={T} must be a multiple of chunk={C}"
    Z = T // C
    lc = int(np.log2(C))
    L = lam.shape[-1]
    assert L >= fenwick.num_levels(T), f"lam has {L} levels, need {fenwick.num_levels(T)}"

    # Fold batch and head: (BH, T, ...)
    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape((B * H, T) + x.shape[3:])

    qf, kf, vf = fold(q), fold(k), fold(v)
    laf, lamf = fold(log_alpha), fold(lam)

    # ---- intra-chunk stage (Pallas) ----
    if use_pallas:
        y_diag = _intra_op(C, interpret, qf, kf, vf, laf, lamf)
    else:
        y_diag = _intra_jnp(C, qf, kf, vf, laf, lamf)

    # ---- inter-chunk stage (level-fused) ----
    qc = qf.reshape(B * H, Z, C, dk)
    kc = kf.reshape(B * H, Z, C, dk)
    vc = vf.reshape(B * H, Z, C, dv)
    lac = laf.reshape(B * H, Z, C)
    lamc = lamf.reshape(B * H, Z, C, L)

    a_cs = jnp.cumsum(lac, axis=-1)                    # within-chunk cumsum
    tot = a_cs[..., -1]                                # (BH, Z) chunk totals
    # chunk states: S[z] = sum_s exp(tot - a_cs[s]) k_s v_s^T
    w = jnp.exp(tot[..., None] - a_cs)                 # (BH, Z, C)
    states = jnp.einsum("bzc,bzck,bzcd->bzkd", w, kc, vc)

    # cross-chunk decay: D[z, c] = exp(sum_{i=c+1}^{z-1} tot_i), c < z
    ct = jnp.cumsum(tot, axis=-1)                      # inclusive prefix
    ctz = jnp.concatenate([jnp.zeros_like(ct[:, :1]), ct], axis=1)  # ct0[j] = sum_{i<j}
    zi = jnp.arange(Z)
    logd = ctz[:, zi][:, :, None] - ctz[:, zi + 1][:, None, :]   # (BH, Z, Z)

    # level masks at chunk granularity, stacked: (L_inter, Z, Z)
    n_inter = fenwick.num_levels(Z) - 1 if Z > 1 else 0
    if n_inter > 0:
        lvl_z = fenwick.level_index_matrix(Z)          # level_of at chunk granularity
        masks = np.stack([(lvl_z == m) for m in range(1, n_inter + 1)])
        masks = jnp.asarray(masks)
        dmask = jnp.where(masks[None], jnp.exp(logd)[:, None], 0.0)  # (BH, M, Z, Z)
        # combined[b, m, z] = sum_c dmask * states[b, c]   (level fusion)
        combined = jnp.einsum("bmzc,bckd->bmzkd", dmask, states)
        # reads: o[t in chunk z] += sum_m lam[t, lc+m] exp(a_cs[t]) q_t^T combined[m, z]
        lam_inter = lamc[..., lc + 1: lc + 1 + n_inter]           # (BH, Z, C, M)
        qw = qc * jnp.exp(a_cs)[..., None]                        # (BH, Z, C, dk)
        y_off = jnp.einsum("bzcm,bzck,bmzkd->bzcd", lam_inter, qw, combined)
        y = y_diag + y_off.reshape(B * H, T, dv)
    else:
        y = y_diag

    # unfold: (B, T, H, dv)
    return jnp.moveaxis(y.reshape(B, H, T, dv), 1, 2)
