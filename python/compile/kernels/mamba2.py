"""Mamba-2 / SSD chunkwise kernel (Dao & Gu, 2024) — the O(T) linear-time
state-passing primitive that Algorithm 1 calls O(log T/C) times, and the
baseline row of Fig. 4.

Same TPU/Pallas structure as ``loglinear_mamba2.py`` minus the H-mask:
Pallas intra-chunk program per (batch·head, chunk), sequential
``lax.scan`` over chunk states for the inter-chunk stage (true O(T)).

The Pallas stage carries a ``custom_vjp``: forward runs the kernel,
backward is the VJP of the mathematically-identical jnp twin — mirroring
the paper's hand-written Triton backward (§5) without duplicating the
derivation here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intra_chunk_kernel(q_ref, k_ref, v_ref, la_ref, o_ref):
    """Y_diag = (Q K^T ⊙ M^S_local) V for one (batch·head, chunk)."""
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    la = la_ref[0]
    C = q.shape[0]
    cum = jnp.cumsum(la)
    causal = jnp.tril(jnp.ones((C, C), dtype=bool))
    logdec = jnp.where(causal, cum[:, None] - cum[None, :], -jnp.inf)
    scores = (q @ k.T) * jnp.exp(logdec)
    o_ref[0] = scores @ v


def _intra_jnp(chunk, qf, kf, vf, laf):
    """jnp twin of the Pallas intra-chunk stage (used for the backward
    pass and the `use_pallas=False` ablation). Inputs are folded (BH, T, ·)."""
    BH, T, dk = qf.shape
    dv = vf.shape[-1]
    C = chunk
    Z = T // C
    qc = qf.reshape(BH, Z, C, dk)
    kc = kf.reshape(BH, Z, C, dk)
    vc = vf.reshape(BH, Z, C, dv)
    lac = laf.reshape(BH, Z, C)
    cum = jnp.cumsum(lac, axis=-1)
    causal = jnp.tril(jnp.ones((C, C), dtype=bool))
    logdec = jnp.where(causal[None, None], cum[..., :, None] - cum[..., None, :], -jnp.inf)
    scores = jnp.einsum("bzik,bzjk->bzij", qc, kc) * jnp.exp(logdec)
    return jnp.einsum("bzij,bzjd->bzid", scores, vc).reshape(BH, T, dv)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _intra_op(chunk, interpret, qf, kf, vf, laf):
    BH, T, dk = qf.shape
    dv = vf.shape[-1]
    C = chunk
    Z = T // C
    return pl.pallas_call(
        _intra_chunk_kernel,
        grid=(BH, Z),
        in_specs=[
            pl.BlockSpec((1, C, dk), lambda b, z: (b, z, 0)),
            pl.BlockSpec((1, C, dk), lambda b, z: (b, z, 0)),
            pl.BlockSpec((1, C, dv), lambda b, z: (b, z, 0)),
            pl.BlockSpec((1, C), lambda b, z: (b, z)),
        ],
        out_specs=pl.BlockSpec((1, C, dv), lambda b, z: (b, z, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, dv), vf.dtype),
        interpret=interpret,
    )(qf, kf, vf, laf)


def _intra_op_fwd(chunk, interpret, qf, kf, vf, laf):
    return _intra_op(chunk, interpret, qf, kf, vf, laf), (qf, kf, vf, laf)


def _intra_op_bwd(chunk, interpret, res, g):
    qf, kf, vf, laf = res
    _, vjp = jax.vjp(lambda q, k, v, la: _intra_jnp(chunk, q, k, v, la), qf, kf, vf, laf)
    return vjp(g)


_intra_op.defvjp(_intra_op_fwd, _intra_op_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_pallas"))
def mamba2_chunkwise(q, k, v, log_alpha, *, chunk: int = 16,
                     interpret: bool = True, use_pallas: bool = True):
    """Chunkwise SSD forward. Shapes as in ``loglinear_mamba2.py``."""
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    C = chunk
    assert T % C == 0, f"T={T} must be a multiple of chunk={C}"
    Z = T // C

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape((B * H, T) + x.shape[3:])

    qf, kf, vf, laf = fold(q), fold(k), fold(v), fold(log_alpha)

    if use_pallas:
        y_diag = _intra_op(C, interpret, qf, kf, vf, laf)
    else:
        y_diag = _intra_jnp(C, qf, kf, vf, laf)

    # ---- inter-chunk: sequential state passing, O(T) ----
    qc = qf.reshape(B * H, Z, C, dk)
    kc = kf.reshape(B * H, Z, C, dk)
    vc = vf.reshape(B * H, Z, C, dv)
    lac = laf.reshape(B * H, Z, C)
    a_cs = jnp.cumsum(lac, axis=-1)
    tot = a_cs[..., -1]                                # (BH, Z)
    w = jnp.exp(tot[..., None] - a_cs)
    chunk_states = jnp.einsum("bzc,bzck,bzcd->bzkd", w, kc, vc)  # (BH, Z, dk, dv)

    def scan_step(s_in, inp):
        state_z, tot_z = inp                           # (BH, dk, dv), (BH,)
        s_out = jnp.exp(tot_z)[:, None, None] * s_in + state_z
        return s_out, s_in                             # emit state *entering* chunk z

    init = jnp.zeros((B * H, dk, dv), v.dtype)
    _, s_in = jax.lax.scan(
        scan_step,
        init,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(tot, 1, 0)),
    )
    s_in = jnp.moveaxis(s_in, 0, 1)                    # (BH, Z, dk, dv)

    qw = qc * jnp.exp(a_cs)[..., None]
    y_off = jnp.einsum("bzck,bzkd->bzcd", qw, s_in).reshape(B * H, T, dv)

    y = y_diag + y_off
    return jnp.moveaxis(y.reshape(B, H, T, dv), 1, 2)
