"""Gated DeltaNet chunkwise kernel (Yang et al., 2024a) — the delta-rule
state-passing primitive that log-linear GDN lifts.

Implemented with the numerically-stable *scaled UT transform* (all
intermediate gate ratios ≤ 1; see ``rust/src/attention/gated_deltanet.rs``
for the derivation):

per chunk, solve ``(I + StrictTril(M)) Ŵ = diag(β)(V − diag(G) K S_in)``
with ``M[i,j] = β_i (k_i·k_j) G_i/G_j``, then
``O = diag(G) Q S_in + (tril(QK^T) ⊙ Gratio) Ŵ`` and
``S_out = G_C S_in + Σ_s (G_C/G_s) k_s ŵ_s^T``.

The per-chunk triangular systems are batched; only the chunk-to-chunk
state dependency is a ``lax.scan``. Pure jnp (the intra-chunk triangular
solve is the part the paper calls "bespoke"; on TPU it lowers to MXU-
friendly ops either way). Same (B, T, H, d) shapes as the other kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def unit_lower_inv(sys):
    """Inverse of a unit lower-triangular matrix (batched ...xCxC) without
    LAPACK custom-calls (XLA 0.5.1, which the Rust runtime embeds, cannot
    execute jax's typed-FFI solve_triangular). Uses the nilpotent Neumann
    doubling identity: with N = sys − I (strictly lower, N^C = 0),

        (I + N)^{-1} = Σ_k (−N)^k = Π_{i=0}^{⌈log2 C⌉−1} (I + M^{2^i}),

    with M = −N — ⌈log2 C⌉ matmuls, MXU-friendly, exact."""
    C = sys.shape[-1]
    eye = jnp.eye(C, dtype=sys.dtype)
    m = eye - sys  # = -N
    acc = eye + m
    power = m
    for _ in range(max((C - 1).bit_length() - 1, 0)):
        power = power @ power
        acc = acc @ (eye + power)
    return acc


def _chunk_precompute(q, k, la, beta):
    """Per-chunk quantities with no cross-chunk dependency.

    Shapes per head: q, k: (Z, C, dk); la, beta: (Z, C).
    Returns (g, sys, qk_tril): local decays (Z, C), unit-lower systems
    (Z, C, C), gate-ratio'd causal scores (Z, C, C).
    """
    C = q.shape[1]
    cs = jnp.cumsum(la, axis=-1)                        # (Z, C)
    g = jnp.exp(cs)
    causal = jnp.tril(jnp.ones((C, C), dtype=bool))
    strict = jnp.tril(jnp.ones((C, C), dtype=bool), k=-1)
    ratio = jnp.exp(jnp.where(causal, cs[:, :, None] - cs[:, None, :], 0.0))
    kk = jnp.einsum("zik,zjk->zij", k, k)
    sys = jnp.eye(C) + jnp.where(strict, beta[:, :, None] * kk * ratio, 0.0)
    qk = jnp.einsum("zik,zjk->zij", q, k)
    qk_tril = jnp.where(causal, qk * ratio, 0.0)
    return cs, g, sys, qk_tril


def _gdn_head(q, k, v, la, beta, chunk):
    """Chunkwise GDN for one head: q,k (T,dk), v (T,dv), la,beta (T,)."""
    T, dk = q.shape
    dv = v.shape[1]
    C = chunk
    Z = T // C
    qc = q.reshape(Z, C, dk)
    kc = k.reshape(Z, C, dk)
    vc = v.reshape(Z, C, dv)
    lac = la.reshape(Z, C)
    bc = beta.reshape(Z, C)

    cs, g, sys, qk_tril = _chunk_precompute(qc, kc, lac, bc)

    inv_sys = unit_lower_inv(sys)

    def chunk_step(s_in, inp):
        qz, kz, vz, csz, gz, bz, invz, qkz = inp
        rhs = bz[:, None] * (vz - gz[:, None] * (kz @ s_in))
        w_hat = invz @ rhs
        o = gz[:, None] * (qz @ s_in) + qkz @ w_hat
        # ratios in log space: g_C/g_s = exp(cs[-1] - cs[s]) (<= 1, no 0/0)
        tail = jnp.exp(csz[-1] - csz)
        s_out = gz[-1] * s_in + jnp.einsum("c,ck,cd->kd", tail, kz, w_hat)
        return s_out, o

    init = jnp.zeros((dk, dv), q.dtype)
    _, o = jax.lax.scan(chunk_step, init, (qc, kc, vc, cs, g, bc, inv_sys, qk_tril))
    return o.reshape(T, dv)


@functools.partial(jax.jit, static_argnames=("chunk",))
def gdn_chunkwise(q, k, v, log_alpha, beta, *, chunk: int = 16):
    """Batched chunkwise Gated DeltaNet: (B, T, H, ...) -> (B, T, H, dv)."""
    B, T, H, dk = q.shape
    assert T % chunk == 0, f"T={T} must be a multiple of chunk={chunk}"
    f = functools.partial(_gdn_head, chunk=chunk)
    inner = jax.vmap(f, in_axes=(1, 1, 1, 1, 1), out_axes=1)
    outer = jax.vmap(inner, in_axes=(0, 0, 0, 0, 0), out_axes=0)
    return outer(q, k, v, log_alpha, beta)
