"""Fenwick-tree partitioning (paper §3.1) — the Python twin of
``rust/src/fenwick/mod.rs``. Used by the Pallas kernels (level masks), the
pure-jnp reference oracles, and the decode step.

All functions are host-side (static shapes) except :func:`lssb_traced`,
which operates on traced integers inside jitted code.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def lssb(t: int) -> int:
    """Index of the least significant set bit of ``t > 0``."""
    assert t > 0
    return (t & -t).bit_length() - 1


def lssb_traced(t):
    """`lssb` for a traced int32/int64 scalar (t > 0)."""
    return jnp.int32(jnp.log2((t & -t).astype(jnp.float32)) + 0.5)


def ceil_log2(n: int) -> int:
    assert n >= 1
    return int(np.ceil(np.log2(n))) if n > 1 else 0


def num_levels(seq_len: int) -> int:
    """Levels ``0 ..= ceil_log2(seq_len)`` — matches the paper's
    ``num_levels = log2(T) + 1`` for power-of-two T."""
    return ceil_log2(seq_len) + 1


def buckets(t: int) -> list[tuple[int, int, int]]:
    """Fenwick partition of [0, t] as (level, start, end) triples."""
    out = [(0, t, t + 1)]
    b = t
    while b > 0:
        l = lssb(b)
        size = 1 << l
        out.append((l + 1, b - size, b))
        b -= size
    return out


def level_of(t: int, s: int) -> int:
    """Level of the bucket containing source ``s`` for query ``t``."""
    assert s <= t
    if s == t:
        return 0
    b = t
    while True:
        l = lssb(b)
        size = 1 << l
        if s >= b - size:
            return l + 1
        b -= size


def level_mask(level: int, n: int) -> np.ndarray:
    """Boolean (n, n) mask: entry (i, j) true iff level_of(i, j) == level
    (zero above the diagonal). The Appendix-C ``level_mask``."""
    m = np.zeros((n, n), dtype=bool)
    for i in range(n):
        for j in range(i + 1):
            m[i, j] = level_of(i, j) == level
    return m


def level_index_matrix(n: int) -> np.ndarray:
    """(n, n) int matrix of level_of(i, j) for j <= i, and -1 above the
    diagonal. One call builds every level mask at once."""
    m = np.full((n, n), -1, dtype=np.int32)
    for i in range(n):
        for j in range(i + 1):
            m[i, j] = level_of(i, j)
    return m


def segsum(x):
    """Stable segment-sum (paper Appendix C): out[..., i, j] =
    sum(x[..., j+1 : i+1]) on the lower triangle, -inf above."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    return jnp.where(mask, out, -jnp.inf)
