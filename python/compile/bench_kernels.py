"""E2 (python side): kernel fwd+bwd wallclock across sequence lengths.

Pallas runs in interpret mode on CPU, so these numbers characterize the
*lowered computation* (what XLA CPU executes), not TPU performance — the
TPU estimate lives in DESIGN.md §Hardware-Adaptation (VMEM footprint +
MXU-aligned block shapes). The Rust twin (`cargo bench --bench
fig4_throughput`) is the primary Fig. 4 reproduction; this script checks
that the *jax-side* kernels show the same ordering.

Usage: python -m compile.bench_kernels [--lens 256,512,1024] [--iters 3]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fenwick, ref
from .kernels.mamba2 import mamba2_chunkwise
from .kernels.loglinear_mamba2 import hattention_chunkwise


def timed(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lens", default="256,512,1024")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--chunk", type=int, default=64)
    args = ap.parse_args()
    lens = [int(x) for x in args.lens.split(",")]

    B, H, dk, dv = 1, 2, 64, 64
    print(f"{'T':>6} {'softmax ms':>12} {'mamba2 ms':>12} {'loglinear ms':>14} {'ll fwd+bwd ms':>14}")
    for T in lens:
        rng = np.random.RandomState(T)
        q = (rng.randn(B, T, H, dk) / 8).astype(np.float32)
        k = rng.randn(B, T, H, dk).astype(np.float32)
        v = rng.randn(B, T, H, dv).astype(np.float32)
        la = np.log(rng.uniform(0.8, 1.0, (B, T, H))).astype(np.float32)
        lam = rng.uniform(0.1, 1.0, (B, T, H, fenwick.num_levels(T))).astype(np.float32)

        t_soft = timed(jax.jit(ref.softmax_ref_batched), q, k, v, iters=args.iters)
        t_m2 = timed(
            lambda *a: mamba2_chunkwise(*a, chunk=args.chunk), q, k, v, la, iters=args.iters
        )
        t_ll = timed(
            lambda *a: hattention_chunkwise(*a, chunk=args.chunk),
            q, k, v, la, lam, iters=args.iters,
        )

        grad_fn = jax.jit(
            jax.grad(
                lambda q, k, v, la, lam: jnp.sum(
                    hattention_chunkwise(q, k, v, la, lam, chunk=args.chunk) ** 2
                ),
                argnums=(0, 1, 2, 3, 4),
            )
        )
        t_llg = timed(grad_fn, q, k, v, la, lam, iters=args.iters)
        print(
            f"{T:>6} {t_soft*1e3:>12.2f} {t_m2*1e3:>12.2f} {t_ll*1e3:>14.2f} {t_llg*1e3:>14.2f}"
        )


if __name__ == "__main__":
    main()
