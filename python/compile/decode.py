"""Layer 2 decode path: single-token recurrent steps with the paper's
O(log T)-memory Fenwick state scheme (§3.2).

The decode state for a whole model is, per layer:

- ``mamba2`` / ``gdn``:            one matrix  (B, H, dk, dv)
- ``loglinear_mamba2`` / ``_gdn``: a stack     (B, L, H, dk, dv)
  of per-level states — at any time only ~popcount(t)+1 of the L slots
  are non-zero (App. B.4); the Rust state pool exploits that, the HLO
  artifact keeps the dense stack for fixed shapes.

``decode_step`` is AOT-exported per variant and driven from the Rust
serving coordinator; ``prefill`` is the same step scanned over a prompt.
"""

from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from .kernels import fenwick
from . import model as M


def init_decode_state(cfg: M.ModelConfig, batch: int):
    """Zeroed decode state: list (per layer) of state arrays."""
    H, dk, dv = cfg.head_dims()
    states = []
    for _ in range(cfg.n_layers):
        if cfg.is_loglinear():
            states.append(jnp.zeros((batch, cfg.num_levels, H, dk, dv), jnp.float32))
        else:
            states.append(jnp.zeros((batch, H, dk, dv), jnp.float32))
    return states

def _merge_batched(states, pos):
    """Fenwick merge on (B, L, H, dk, dv) with a *per-sequence* position
    vector (B,) — sequences in a continuous batch sit at different offsets.
    Rows with pos == 0 are left untouched."""
    L = states.shape[1]
    l = fenwick.lssb_traced(jnp.maximum(pos, 1))          # (B,)
    idx = jnp.arange(L)
    le = (idx[None, :] <= l[:, None])[:, :, None, None, None]
    merged = jnp.sum(jnp.where(le, states, 0.0), axis=1, keepdims=True)
    out = jnp.where(le, 0.0, states)
    sel = (idx[None, :] == (l + 1)[:, None])[:, :, None, None, None]
    out = jnp.where(sel, merged, out)
    active = (pos > 0)[:, None, None, None, None]
    return jnp.where(active, out, states)


def _mixer_step(cfg: M.ModelConfig, layer, x, state, pos):
    """One token through one mixer. x: (B, D); returns (o: (B, D), state')."""
    B, D = x.shape
    H, dk, dv = cfg.head_dims()
    q = (x @ layer["wq"]).reshape(B, H, dk)
    k = (x @ layer["wk"]).reshape(B, H, dk)
    v = (x @ layer["wv"]).reshape(B, H, dv)
    if cfg.variant in ("gdn", "loglinear_gdn"):
        k = k / jnp.maximum(jnp.linalg.norm(k, axis=-1, keepdims=True), 1e-6)
    la = -jax.nn.softplus(x @ layer["w_alpha"] + layer["b_alpha"])  # (B, H)
    alpha = jnp.exp(la)

    if cfg.variant == "mamba2":
        state = alpha[..., None, None] * state + jnp.einsum("bhk,bhd->bhkd", k, v)
        o = jnp.einsum("bhkd,bhk->bhd", state, q)
    elif cfg.variant == "gdn":
        beta = jax.nn.sigmoid(x @ layer["w_beta"] + layer["b_beta"])
        proj = jnp.einsum("bhk,bhkd->bhd", k, state)
        state = state - beta[..., None, None] * jnp.einsum("bhk,bhd->bhkd", k, proj)
        state = alpha[..., None, None] * state + beta[..., None, None] * jnp.einsum(
            "bhk,bhd->bhkd", k, v
        )
        o = jnp.einsum("bhkd,bhk->bhd", state, q)
    elif cfg.is_loglinear():
        L = cfg.num_levels
        lam = jax.nn.softplus(x @ layer["w_lam"] + layer["b_lam"]).reshape(B, H, L)
        state = _merge_batched(state, pos)
        if cfg.variant == "loglinear_gdn":
            beta = jax.nn.sigmoid(x @ layer["w_beta"] + layer["b_beta"])
            proj = jnp.einsum("bhk,blhkd->blhd", k, state)
            state = state - beta[:, None, :, None, None] * jnp.einsum(
                "bhk,blhd->blhkd", k, proj
            )
            state = alpha[:, None, :, None, None] * state
            write = beta[..., None, None] * jnp.einsum("bhk,bhd->bhkd", k, v)
        else:
            state = alpha[:, None, :, None, None] * state
            write = jnp.einsum("bhk,bhd->bhkd", k, v)
        state = state.at[:, 0].set(write)
        # o = Σ_l λ^(l) S^(l)T q
        o = jnp.einsum("blh,blhkd,bhk->bhd", lam.transpose(0, 2, 1), state, q)
    else:
        raise ValueError(f"decode unsupported for variant {cfg.variant}")
    return o.reshape(B, H * dv) @ layer["wo"], state


def decode_step(cfg: M.ModelConfig, params, states: List[Any], token, pos):
    """One decode step. token: (B,) int32; pos: (B,) int32 (0-based index
    of each sequence's current token — sequences in a continuous batch may
    sit at different offsets). Returns (logits: (B, vocab), new states)."""
    x = params["embed"][token]                 # (B, D)
    new_states = []
    for i in range(cfg.n_layers):
        layer = params[f"layer_{i}"]
        o, st = _mixer_step(cfg, layer, M.rmsnorm(x, layer["norm1"]), states[i], pos)
        x = x + o
        x = x + M.swiglu(M.rmsnorm(x, layer["norm2"]), layer)
        new_states.append(st)
    x = M.rmsnorm(x, params["norm_f"])
    return x @ params["head"], new_states


def prefill(cfg: M.ModelConfig, params, tokens, start_pos):
    """Run ``decode_step`` over a prompt (B, Tp) via lax.scan.
    Returns (last logits (B, vocab), final states)."""
    B, Tp = tokens.shape
    states = init_decode_state(cfg, B)

    def step(carry, tok_t):
        states, pos = carry
        posv = jnp.full((B,), pos, jnp.int32)
        logits, states = decode_step(cfg, params, states, tok_t, posv)
        return (states, pos + 1), logits

    (states, _), logits_seq = jax.lax.scan(
        step, (states, start_pos), tokens.T  # (Tp, B)
    )
    return logits_seq[-1], states
