"""Layer 2: the JAX language model (build-time only; never on the request
path). A pre-norm transformer skeleton whose token mixer is swappable
between the paper's architectures:

- ``transformer``       — causal softmax attention + RoPE
- ``mamba2``            — chunkwise SSD (Pallas kernel)
- ``loglinear_mamba2``  — chunkwise hattention (Pallas kernel, Alg. 1)
- ``gdn``               — chunkwise Gated DeltaNet
- ``loglinear_gdn``     — chunkwise Log-Linear Gated DeltaNet

Log-linear variants add one linear head producing the per-head, per-level
λ_t^(ℓ) = softplus(W_λ x_t + b) (paper §4.2: "a linear layer on top of the
hidden states"), initialized so λ ≈ 1 — i.e. the model *starts* as its
linear counterpart and learns to use the hierarchy.

Everything here is AOT-lowered to HLO text by ``aot.py`` and executed from
Rust; see DESIGN.md.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fenwick, ref
from .kernels.mamba2 import mamba2_chunkwise
from .kernels.loglinear_mamba2 import hattention_chunkwise
from .kernels.gdn import gdn_chunkwise
from .kernels.loglinear_gdn import loglinear_gdn_chunkwise

VARIANTS = ("transformer", "mamba2", "loglinear_mamba2", "gdn", "loglinear_gdn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    variant: str = "loglinear_mamba2"
    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    dk: int = 32           # per-head key/query (state) dim
    dv: int = 32           # per-head value (head) dim
    d_mlp: int = 512
    seq_len: int = 256
    chunk: int = 16
    rope_base: float = 500_000.0
    # λ level count; 0 = derive from seq_len. Set explicitly to share one
    # parameter set across eval artifacts of different sequence lengths
    # (shorter sequences simply never index the top levels).
    levels: int = 0

    @property
    def num_levels(self) -> int:
        return self.levels if self.levels > 0 else fenwick.num_levels(self.seq_len)

    def head_dims(self):
        return self.n_heads, self.dk, self.dv

    def is_loglinear(self) -> bool:
        return self.variant.startswith("loglinear")


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    """Initialize the parameter pytree (plain nested dict, stable keys)."""
    rng = np.random.RandomState(seed)
    std = 0.02
    H, dk, dv = cfg.head_dims()
    D = cfg.d_model

    def mat(m, n, s=std):
        return jnp.asarray(rng.randn(m, n).astype(np.float32) * s)

    def vec(n, fill=0.0):
        return jnp.full((n,), fill, dtype=jnp.float32)

    params: Dict[str, Any] = {
        "embed": mat(cfg.vocab, D),
        "head": mat(D, cfg.vocab),
        "norm_f": jnp.ones((D,), jnp.float32),
    }
    out_scale = std / np.sqrt(2.0 * cfg.n_layers)
    for i in range(cfg.n_layers):
        layer: Dict[str, Any] = {
            "norm1": jnp.ones((D,), jnp.float32),
            "norm2": jnp.ones((D,), jnp.float32),
            "wq": mat(D, H * dk),
            "wk": mat(D, H * dk),
            "wv": mat(D, H * dv),
            "wo": mat(H * dv, D, out_scale),
            "w_gate": mat(D, cfg.d_mlp),
            "w_up": mat(D, cfg.d_mlp),
            "w_down": mat(cfg.d_mlp, D, out_scale),
        }
        if cfg.variant in ("mamba2", "loglinear_mamba2", "gdn", "loglinear_gdn"):
            layer["w_alpha"] = mat(D, H, 0.01)
            # softplus(b) ≈ 0.05 -> α ≈ 0.95 at init
            layer["b_alpha"] = vec(H, -2.97)
        if cfg.variant in ("gdn", "loglinear_gdn"):
            layer["w_beta"] = mat(D, H, 0.01)
            layer["b_beta"] = vec(H, 1.0)
        if cfg.is_loglinear():
            L = cfg.num_levels
            layer["w_lam"] = mat(D, H * L, 0.01)
            # softplus(0.5413) ≈ 1.0 -> starts as the linear variant
            layer["b_lam"] = vec(H * L, 0.5413)
        params[f"layer_{i}"] = layer
    return params


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, gain, eps=1e-6):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def swiglu(x, layer):
    return (jax.nn.silu(x @ layer["w_gate"]) * (x @ layer["w_up"])) @ layer["w_down"]


def rope(x, base: float, offset=0):
    """Rotary embedding on (B, T, H, d)."""
    B, T, H, d = x.shape
    half = d // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.arange(T, dtype=jnp.float32) + offset
    ang = pos[:, None] * freqs[None, :]                       # (T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    rot1 = x1 * cos[None, :, None, :] - x2 * sin[None, :, None, :]
    rot2 = x1 * sin[None, :, None, :] + x2 * cos[None, :, None, :]
    return jnp.concatenate([rot1, rot2], axis=-1)


def mixer_projections(cfg: ModelConfig, layer, x):
    """Shared q/k/v (+gates, +β, +λ) projections. x: (B, T, D)."""
    B, T, _ = x.shape
    H, dk, dv = cfg.head_dims()
    q = (x @ layer["wq"]).reshape(B, T, H, dk)
    k = (x @ layer["wk"]).reshape(B, T, H, dk)
    v = (x @ layer["wv"]).reshape(B, T, H, dv)
    out = {"q": q, "k": k, "v": v}
    if "w_alpha" in layer:
        out["log_alpha"] = -jax.nn.softplus(x @ layer["w_alpha"] + layer["b_alpha"])
    if "w_beta" in layer:
        out["beta"] = jax.nn.sigmoid(x @ layer["w_beta"] + layer["b_beta"])
    if "w_lam" in layer:
        L = cfg.num_levels
        lam = jax.nn.softplus(x @ layer["w_lam"] + layer["b_lam"])
        out["lam"] = lam.reshape(B, T, H, L)
    if cfg.variant in ("gdn", "loglinear_gdn"):
        # L2-normalized keys keep the Householder transitions contractive.
        out["k"] = out["k"] / jnp.maximum(
            jnp.linalg.norm(out["k"], axis=-1, keepdims=True), 1e-6
        )
    return out


def mixer_forward(cfg: ModelConfig, layer, x, *, interpret=True):
    """Token mixing. x: (B, T, D) -> (B, T, D)."""
    B, T, _ = x.shape
    H, dk, dv = cfg.head_dims()
    p = mixer_projections(cfg, layer, x)
    q, k, v = p["q"], p["k"], p["v"]
    if cfg.variant == "transformer":
        q = rope(q, cfg.rope_base)
        k = rope(k, cfg.rope_base)
        o = ref.softmax_ref_batched(q, k, v)
    elif cfg.variant == "mamba2":
        o = mamba2_chunkwise(q, k, v, p["log_alpha"], chunk=cfg.chunk, interpret=interpret)
    elif cfg.variant == "loglinear_mamba2":
        o = hattention_chunkwise(
            q, k, v, p["log_alpha"], p["lam"], chunk=cfg.chunk, interpret=interpret
        )
    elif cfg.variant == "gdn":
        o = gdn_chunkwise(q, k, v, p["log_alpha"], p["beta"], chunk=cfg.chunk)
    elif cfg.variant == "loglinear_gdn":
        o = loglinear_gdn_chunkwise(
            q, k, v, p["log_alpha"], p["beta"], p["lam"], chunk=cfg.chunk
        )
    else:
        raise ValueError(f"unknown variant {cfg.variant}")
    return o.reshape(B, T, H * dv) @ layer["wo"]


def forward_logits(cfg: ModelConfig, params, tokens, *, interpret=True):
    """tokens: (B, T) int32 -> logits (B, T, vocab)."""
    x = params["embed"][tokens]
    for i in range(cfg.n_layers):
        layer = params[f"layer_{i}"]
        x = x + mixer_forward(cfg, layer, rmsnorm(x, layer["norm1"]), interpret=interpret)
        x = x + swiglu(rmsnorm(x, layer["norm2"]), layer)
    x = rmsnorm(x, params["norm_f"])
    return x @ params["head"]


def per_position_loss(cfg: ModelConfig, params, tokens, *, interpret=True):
    """Next-token cross-entropy per position: (B, T-1)."""
    logits = forward_logits(cfg, params, tokens, interpret=interpret)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    return -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]


def loss_fn(cfg: ModelConfig, params, tokens, *, interpret=True):
    return jnp.mean(per_position_loss(cfg, params, tokens, interpret=interpret))


# ---------------------------------------------------------------------------
# Adam train step (the L2 training hot path, exported as one fused HLO)
# ---------------------------------------------------------------------------

def adam_train_step(cfg: ModelConfig, params, m, v, step, tokens, lr,
                    b1=0.9, b2=0.95, eps=1e-8, wd=0.01, *, interpret=True):
    """One fused forward+backward+Adam(W) update. Returns
    (params', m', v', loss). ``step`` is 1-based for bias correction."""
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, interpret=interpret)
    )(params)
    stepf = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** stepf
    bc2 = 1.0 - b2 ** stepf

    def upd(p, g, m_, v_):
        m2 = b1 * m_ + (1.0 - b1) * g
        v2 = b2 * v_ + (1.0 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
        return p2, m2, v2

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    params2 = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    m2 = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    v2 = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    return params2, m2, v2, loss


def zeros_like_tree(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


# ---------------------------------------------------------------------------
# Flattening (stable order shared with the Rust runtime via the manifest)
# ---------------------------------------------------------------------------

def flatten_with_names(params):
    """Flatten the param pytree into (name, leaf) pairs in a stable,
    manifest-documented order (sorted dict keys, depth-first)."""
    out = []

    def rec(prefix, node):
        if isinstance(node, dict):
            for key in sorted(node.keys()):
                rec(f"{prefix}.{key}" if prefix else key, node[key])
        else:
            out.append((prefix, node))

    rec("", params)
    return out


def unflatten_like(template, leaves):
    """Inverse of flatten_with_names given a structural template."""
    leaves = list(leaves)

    def rec(node):
        if isinstance(node, dict):
            return {key: rec(node[key]) for key in sorted(node.keys())}
        return leaves.pop(0)

    result = rec(template)
    assert not leaves, "leftover leaves"
    return result
