//! End-to-end driver: train a small LM through the full three-layer
//! stack — Rust coordinator → PJRT → AOT-compiled JAX model with the
//! Pallas log-linear kernel inside — on the synthetic corpus, log the
//! loss curve, then evaluate perplexity and planted-fact recall.
//!
//! Run: `make artifacts && cargo run --release --example train_lm`
//! Options: `--variant loglinear_mamba2 --steps 300 --config tiny`
//! (use `--config lm` after `make artifacts-lm` for the bigger model).
//! The run is recorded in EXPERIMENTS.md §E2E.

use loglinear::config::RunConfig;
use loglinear::data::corpus::{Corpus, CorpusConfig};
use loglinear::eval;
use loglinear::runtime::{ModelHandle, Runtime};
use loglinear::train::{self, TrainConfig};
use loglinear::util::cli::Args;
use loglinear::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunConfig::from_args(&args)?;
    let steps = args.usize_or("steps", 300);

    let rt = Runtime::cpu()?;
    let mut model = ModelHandle::load(&rt, &cfg.artifacts, &cfg.model_name())?;
    println!(
        "model {} | {} params | batch {} | seq {}",
        cfg.model_name(),
        model.manifest.param_count,
        model.manifest.batch,
        model.manifest.cfg("seq_len")
    );

    let seq = model.manifest.cfg("seq_len");
    let corpus = Corpus::new(
        CorpusConfig {
            vocab: model.manifest.cfg("vocab"),
            seq,
            recall_band: (8, seq * 3 / 4),
            ..Default::default()
        },
        1000,
    );

    let tc = TrainConfig {
        steps,
        lr: cfg.lr,
        warmup: cfg.warmup,
        seed: cfg.seed,
        checkpoint: Some(cfg.artifacts.join(format!("ckpt_{}.bin", cfg.model_name()))),
        ..Default::default()
    };
    let curve = train::train(&rt, &mut model, &corpus, &tc)?;

    // loss curve (coarse console plot)
    println!("\nloss curve (ema):");
    let n = curve.len();
    for frac in [0, n / 8, n / 4, n / 2, 3 * n / 4, n - 1] {
        let (step, _raw, ema) = curve[frac];
        let bar = "#".repeat(((ema as f64) * 8.0) as usize);
        println!("  step {step:>5}: {ema:7.4} {bar}");
    }

    // held-out evaluation
    let batch = model.manifest.batch;
    let mut eval_rng = Rng::new(777_000);
    let (loss, ppl) =
        eval::perplexity(&model, || corpus.train_batch(batch, &mut eval_rng), 8)?;
    let mut rng2 = Rng::new(778_000);
    let recall = eval::task_accuracy_n(&model, || corpus.eval_batch(batch, &mut rng2), 8)?;
    println!("\nheld-out: loss {loss:.4}  ppl {ppl:.2}  planted-fact recall {recall:.3}");
    println!(
        "(baseline: untrained loss ≈ ln(vocab) = {:.2})",
        (model.manifest.cfg("vocab") as f64).ln()
    );
    Ok(())
}
