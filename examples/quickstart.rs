//! Quickstart: the library in five minutes, no artifacts required.
//!
//! 1. Build the Fenwick partition of a prefix (paper §3.1).
//! 2. Run log-linear attention in its three equivalent forms and check
//!    they agree (recurrent O(log T)-state, parallel masked, chunkwise).
//! 3. Show the collapse to plain Mamba-2 when all λ = 1.
//!
//! Run: `cargo run --release --example quickstart`

use loglinear::attention::{forward, AttnInputs, Form, Model};
use loglinear::fenwick;
use loglinear::tensor::Mat;
use loglinear::util::Rng;

fn main() {
    // --- 1. Fenwick partition -------------------------------------------
    let t = 22; // binary 10110
    println!("Fenwick partition of the prefix [0, {t}]:");
    for b in fenwick::buckets(t) {
        println!(
            "  level {:>2}: positions [{:>3}, {:>3})  (size {})",
            b.level,
            b.start,
            b.end,
            b.len()
        );
    }
    println!(
        "  -> {} live states instead of {} cached tokens\n",
        fenwick::buckets(t).len(),
        t + 1
    );

    // --- 2. three equivalent forms --------------------------------------
    let mut rng = Rng::new(42);
    let x = AttnInputs::random(128, 16, 16, &mut rng);
    let o_rec = forward(Model::LogLinearMamba2, Form::Recurrent, &x);
    let o_par = forward(Model::LogLinearMamba2, Form::Parallel, &x);
    let o_chk = forward(Model::LogLinearMamba2, Form::Chunkwise(16), &x);
    println!("log-linear Mamba-2, T=128:");
    println!("  recurrent vs parallel  max |Δ| = {:.2e}", o_rec.max_abs_diff(&o_par));
    println!("  recurrent vs chunkwise max |Δ| = {:.2e}", o_rec.max_abs_diff(&o_chk));

    // --- 3. λ = 1 collapse ----------------------------------------------
    let mut x1 = x.clone();
    x1.lambda = Mat::from_fn(128, fenwick::num_levels(128), |_, _| 1.0);
    let o_ll = forward(Model::LogLinearMamba2, Form::Recurrent, &x1);
    let o_m2 = forward(Model::Mamba2, Form::Recurrent, &x1);
    println!(
        "  with λ ≡ 1, log-linear == Mamba-2: max |Δ| = {:.2e}",
        o_ll.max_abs_diff(&o_m2)
    );
    println!("\nNext: `make artifacts && cargo run --release --example train_lm`");
}
