//! MQAR mini-experiment (paper §4.1, Table 2): train one model on
//! multi-query associative recall and report accuracy — the scenario
//! the paper's introduction motivates (fixed-state RNNs struggle at
//! recall; log-linear state helps).
//!
//! Run: first export the MQAR artifacts —
//! `cd python && python -m compile.aot --out ../artifacts --config mqar64 --skip-golden`
//! then `cargo run --release --example mqar -- --variant loglinear_mamba2 --pairs 16`

use loglinear::config::RunConfig;
use loglinear::data::mqar::{self, MqarConfig};
use loglinear::eval;
use loglinear::runtime::{ModelHandle, Runtime};
use loglinear::train;
use loglinear::util::cli::Args;
use loglinear::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = RunConfig::from_args(&args)?;
    if cfg.config == "tiny" {
        cfg.config = "mqar64".into(); // default to the dim-64 MQAR model
    }
    let n_pairs = args.usize_or("pairs", 16);
    let max_steps = args.usize_or("max-steps", 400);

    let rt = Runtime::cpu()?;
    let mut model = ModelHandle::load(&rt, &cfg.artifacts, &cfg.model_name())?;
    model.ensure_train(&rt)?;
    let batch = model.manifest.batch;
    println!(
        "MQAR: model {} ({} params), {} kv pairs per 256-token sequence",
        cfg.model_name(),
        model.manifest.param_count,
        n_pairs
    );

    let mcfg = MqarConfig { n_pairs, ..Default::default() };
    let mut rng = Rng::new(cfg.seed);
    let mut eval_rng = Rng::new(999);
    let mut final_acc = 0.0;
    for step in 1..=max_steps {
        let tb = mqar::generate(&mcfg, batch, &mut rng);
        let lr = train::lr_schedule(step - 1, max_steps, cfg.lr, cfg.warmup) as f32;
        let out = model.train_step(step as i32, &tb.tokens, lr)?;
        if step % 25 == 0 {
            let acc = eval::task_accuracy_n(
                &model,
                || mqar::generate(&mcfg, batch, &mut eval_rng),
                4,
            )?;
            println!("  step {step:>4}: loss {:.4}  recall acc {:.1}%", out.loss, acc * 100.0);
            final_acc = acc;
            if acc >= 0.99 {
                println!("  early stop: ≥99% (paper App. D protocol)");
                break;
            }
        }
    }
    println!("final MQAR accuracy: {:.1}%", final_acc * 100.0);
    Ok(())
}
