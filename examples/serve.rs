//! Serving example: batched decode over the AOT `decode_step` artifacts,
//! demonstrating the O(log T)-state serving path (router → dynamic
//! batcher → decode engine → per-sequence Fenwick states).
//!
//! Run: `make artifacts && cargo run --release --example serve -- --requests 16`

use std::time::Duration;

use loglinear::config::RunConfig;
use loglinear::coordinator::batcher::BatchPolicy;
use loglinear::coordinator::server::DecodeServer;
use loglinear::coordinator::GenRequest;
use loglinear::runtime::{ModelHandle, Runtime};
use loglinear::util::cli::Args;
use loglinear::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunConfig::from_args(&args)?;
    let n_requests = args.usize_or("requests", 16);
    let max_new = args.usize_or("max-new", 32);

    let rt = Runtime::cpu()?;
    let mut model = ModelHandle::load(&rt, &cfg.artifacts, &cfg.model_name())?;
    let ckpt = cfg.artifacts.join(format!("ckpt_{}.bin", cfg.model_name()));
    if ckpt.exists() {
        model.load_checkpoint(&ckpt)?;
        println!("using trained checkpoint {}", ckpt.display());
    }

    let buckets = model.decode_batches_available();
    println!("decode buckets (compiled batch sizes): {buckets:?}");
    let policy = BatchPolicy::new(buckets, Duration::from_millis(2));
    let mut server = DecodeServer::new(&rt, model, policy)?;

    let vocab = server.model().manifest.cfg("vocab");
    let mut rng = Rng::new(123);
    for id in 0..n_requests as u64 {
        let plen = rng.range(4, 20);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
        server.submit(GenRequest { id, prompt, max_new })?;
    }

    let t0 = std::time::Instant::now();
    let results = server.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats.clone();

    println!("\nserved {} requests in {wall:.2}s", results.len());
    println!(
        "engine steps {}  sequence-tokens {}  throughput {:.0} tok/s",
        stats.steps,
        stats.tokens_processed,
        stats.tokens_per_second()
    );
    if let Some(s) = stats.latency_summary() {
        println!(
            "step latency mean {:.2}ms  p50 {:.2}ms  p99 {:.2}ms",
            s.mean * 1e3,
            s.p50 * 1e3,
            s.p99 * 1e3
        );
    }
    println!(
        "mean batch occupancy {:.2}  peak dense state bytes {}",
        stats.mean_occupancy(),
        stats.peak_state_bytes
    );
    println!("\nfirst completions:");
    for r in results.iter().take(4) {
        println!("  req {:>2}: {:?}...", r.id, &r.tokens[..r.tokens.len().min(8)]);
    }
    Ok(())
}
