//! Decode memory demo (Table 1's right columns / §3.2): drive the three
//! decoding regimes side by side in pure Rust and print resident state as
//! the sequence grows:
//!
//! - softmax attention: KV cache, O(T) memory, O(T) time/step
//! - Mamba-2: one state, O(1) memory
//! - log-linear Mamba-2: Fenwick states, O(log T) memory
//!
//! Run: `cargo run --release --example decode_memory -- --max-t 65536`

use loglinear::attention::softmax::KvCacheDecoder;
use loglinear::state::{FenwickState, Transition};
use loglinear::tensor::Mat;
use loglinear::util::cli::Args;
use loglinear::util::Rng;

fn main() {
    let args = Args::from_env();
    let max_t = args.usize_or("max-t", 65_536);
    let (dk, dv) = (16, 16);
    let mut rng = Rng::new(1);

    let mut kv = KvCacheDecoder::new(dk);
    let mut m2_state = Mat::zeros(dk, dv); // Mamba-2: single matrix
    let mut fenwick = FenwickState::new(dk, dv);
    let lambda = vec![1.0f32; 64];

    println!(
        "{:>9} | {:>14} | {:>10} | {:>22}",
        "t", "KV cache bytes", "Mamba-2 B", "log-linear (live × B)"
    );
    let mut checkpoints: Vec<usize> = (4..=max_t.ilog2()).map(|p| 1usize << p).collect();
    checkpoints.dedup();
    let mut next = 0;
    for t in 0..max_t {
        let q: Vec<f32> = (0..dk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let k: Vec<f32> = (0..dk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = (0..dv).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // only run the KV path while it is still cheap
        if t < 8192 {
            kv.step(&q, &k, &v);
        }
        m2_state.scale_inplace(0.99);
        loglinear::tensor::outer_acc(&mut m2_state, &k, &v, 1.0);
        fenwick.step(&q, &k, &v, 1.0, Transition::Decay(0.99), &lambda);

        if next < checkpoints.len() && t + 1 == checkpoints[next] {
            let kv_bytes = if t < 8192 {
                format!("{}", kv.state_bytes())
            } else {
                format!("~{}", (t + 1) * (dk + dv) * 4)
            };
            println!(
                "{:>9} | {:>14} | {:>10} | {:>4} live × {:>5} = {:>8}",
                t + 1,
                kv_bytes,
                dk * dv * 4,
                fenwick.live_states(),
                dk * dv * 4,
                fenwick.state_bytes()
            );
            next += 1;
        }
    }
    println!(
        "\nat T = {max_t}: KV cache grows linearly, Mamba-2 is constant but\n\
         forgets, log-linear holds ≤ log2(T)+1 = {} states ({} bytes).",
        max_t.ilog2() + 1,
        fenwick.state_bytes()
    );
}
