#!/usr/bin/env python3
"""Fold the current BENCH_*.json records into a cross-PR trajectory file.

Each bench target (fig4_throughput, table1_complexity, decode_batched,
prefill_throughput, ...) emits a machine-readable BENCH_<name>.json with
its latest numbers and a previous-run delta. That gives one step of
history; this script gives the whole trajectory: every invocation appends
a snapshot of all BENCH_*.json files found in the bench directory to
BENCH_HISTORY.json, keyed by timestamp and (when available) the git
revision, so per-PR perf movement can be plotted without re-running old
checkouts (the ROADMAP's perf-trajectory-tracking item).

Usage: scripts/bench_history.py [--check | --self-test | --dashboard] [bench_dir]
  bench_dir defaults to the rust/ package root (where `cargo bench` runs
  and drops its BENCH_*.json files). The history file lives next to them.

  --dashboard  render BENCH_HISTORY.json as a markdown table instead of
               folding: one row per snapshot, one column per headline
               metric (top-level numeric bench fields whose key mentions
               'speedup', 'tokens_per_s', 'per_request', 'ttft', 'p99',
               or 'overhead'). Columns
               appear in first-snapshot order; metrics a snapshot lacks
               render as '-'.
  --check      validate BENCH_HISTORY.json instead of folding: exit
               non-zero on malformed records (missing/ill-typed
               timestamp, git_rev, or benches) or duplicates (two
               identical records anywhere, or adjacent snapshots with
               identical bench payloads — the fold's idempotence
               guarantees neither can happen, so either means the file
               was corrupted or hand-edited). A missing history file is
               fine: nothing to check yet.
  --self-test  run the built-in test suite for --check and the fold's
               idempotence, in a temp directory. CI runs this.

Idempotence: a snapshot is only appended when at least one bench record
changed since the last snapshot, so re-running CI without re-running
benches does not grow the file.
"""

import json
import os
import subprocess
import sys
import tempfile
import time

HISTORY_NAME = "BENCH_HISTORY.json"
TIMESTAMP_FMT = "%Y-%m-%dT%H:%M:%SZ"


def git_rev(cwd):
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=cwd,
            capture_output=True,
            text=True,
            timeout=10,
        )
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def default_bench_dir():
    return os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "rust"
    )


def fold(bench_dir):
    records = {}
    for name in sorted(os.listdir(bench_dir)):
        if not (name.startswith("BENCH_") and name.endswith(".json")) or name == HISTORY_NAME:
            continue
        path = os.path.join(bench_dir, name)
        try:
            with open(path) as f:
                records[name[len("BENCH_"):-len(".json")]] = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"bench_history: skipping unreadable {name}: {e}", file=sys.stderr)
    if not records:
        print(f"bench_history: no BENCH_*.json in {bench_dir}; nothing to fold")
        return 0

    history_path = os.path.join(bench_dir, HISTORY_NAME)
    history = {"runs": []}
    if os.path.exists(history_path):
        try:
            with open(history_path) as f:
                history = json.load(f)
            if not isinstance(history.get("runs"), list):
                raise ValueError("malformed history (no runs list)")
        except (OSError, json.JSONDecodeError, ValueError) as e:
            print(f"bench_history: resetting malformed {HISTORY_NAME}: {e}", file=sys.stderr)
            history = {"runs": []}

    if history["runs"] and history["runs"][-1].get("benches") == records:
        print(f"bench_history: no bench record changed; {history_path} untouched "
              f"({len(history['runs'])} snapshot(s))")
        return 0

    history["runs"].append({
        "timestamp": time.strftime(TIMESTAMP_FMT, time.gmtime()),
        "git_rev": git_rev(bench_dir),
        "benches": records,
    })
    tmp = history_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, history_path)
    print(f"bench_history: appended snapshot #{len(history['runs'])} "
          f"({', '.join(sorted(records))}) -> {history_path}")
    return 0


HEADLINE_MARKERS = ("speedup", "tokens_per_s", "per_request", "ttft", "p99", "overhead")


def headline_metrics(bench_doc):
    """Top-level numeric fields of one bench record worth a dashboard column."""
    if not isinstance(bench_doc, dict):
        return {}
    return {
        k: v
        for k, v in bench_doc.items()
        if isinstance(v, (int, float)) and not isinstance(v, bool)
        and any(m in k for m in HEADLINE_MARKERS)
    }


def fmt_metric(v):
    return f"{v:,.0f}" if abs(v) >= 100 else f"{v:.3g}"


def render_dashboard(history):
    """BENCH_HISTORY.json contents -> a markdown table, one row per snapshot."""
    runs = [r for r in history.get("runs", []) if isinstance(r, dict)]
    cols = []  # (bench, key) in discovery order, stable across snapshots
    for run in runs:
        benches = run.get("benches")
        if not isinstance(benches, dict):
            continue
        for bench in sorted(benches):
            for key in sorted(headline_metrics(benches[bench])):
                if (bench, key) not in cols:
                    cols.append((bench, key))
    lines = ["# Bench trajectory", ""]
    if not runs or not cols:
        lines.append("_no snapshots with headline metrics yet_")
        return "\n".join(lines) + "\n"
    header = ["timestamp", "git_rev"] + [f"{b}: {k}" for b, k in cols]
    lines.append("| " + " | ".join(header) + " |")
    lines.append("|" + "|".join(["---"] * len(header)) + "|")
    for run in runs:
        benches = run.get("benches") if isinstance(run.get("benches"), dict) else {}
        cells = [str(run.get("timestamp", "?")), str(run.get("git_rev") or "-")]
        for bench, key in cols:
            metrics = headline_metrics(benches.get(bench, {}))
            cells.append(fmt_metric(metrics[key]) if key in metrics else "-")
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines) + "\n"


def dashboard(bench_dir):
    """Render the trajectory as markdown on stdout; return 0 if rendered."""
    history_path = os.path.join(bench_dir, HISTORY_NAME)
    if not os.path.exists(history_path):
        print(f"bench_history --dashboard: no {HISTORY_NAME} in {bench_dir}; nothing to render")
        return 0
    try:
        with open(history_path) as f:
            history = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_history --dashboard: unreadable {history_path}: {e}", file=sys.stderr)
        return 1
    if not isinstance(history, dict):
        print(f"bench_history --dashboard: {history_path} is not an object", file=sys.stderr)
        return 1
    sys.stdout.write(render_dashboard(history))
    return 0


def record_errors(i, run):
    """Structural problems of one history record, as human-readable strings."""
    errs = []
    if not isinstance(run, dict):
        return [f"run #{i}: not an object"]
    ts = run.get("timestamp")
    if not isinstance(ts, str):
        errs.append(f"run #{i}: missing/non-string timestamp")
    else:
        try:
            time.strptime(ts, TIMESTAMP_FMT)
        except ValueError:
            errs.append(f"run #{i}: timestamp {ts!r} is not {TIMESTAMP_FMT}")
    if not (run.get("git_rev") is None or isinstance(run.get("git_rev"), str)):
        errs.append(f"run #{i}: git_rev must be a string or null")
    benches = run.get("benches")
    if not isinstance(benches, dict) or not benches:
        errs.append(f"run #{i}: benches must be a non-empty object")
    unknown = set(run) - {"timestamp", "git_rev", "benches"}
    if unknown:
        errs.append(f"run #{i}: unknown keys {sorted(unknown)}")
    return errs


def check(bench_dir):
    """Validate BENCH_HISTORY.json; return 0 if clean, 1 otherwise."""
    history_path = os.path.join(bench_dir, HISTORY_NAME)
    if not os.path.exists(history_path):
        print(f"bench_history --check: no {HISTORY_NAME} in {bench_dir}; nothing to check")
        return 0
    try:
        with open(history_path) as f:
            history = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_history --check: unreadable {history_path}: {e}", file=sys.stderr)
        return 1
    errs = []
    if not isinstance(history, dict) or not isinstance(history.get("runs"), list):
        errs.append("top level must be an object with a 'runs' list")
        runs = []
    else:
        runs = history["runs"]
    for i, run in enumerate(runs):
        errs.extend(record_errors(i, run))
    # duplicates the fold can never produce: adjacent snapshots with the
    # same bench payload (idempotence skips those), or two byte-identical
    # records anywhere
    for i in range(1, len(runs)):
        if isinstance(runs[i], dict) and isinstance(runs[i - 1], dict) \
                and runs[i].get("benches") is not None \
                and runs[i].get("benches") == runs[i - 1].get("benches"):
            errs.append(f"runs #{i - 1}/#{i}: adjacent snapshots with identical benches "
                        "(idempotence violation)")
    seen = {}
    for i, run in enumerate(runs):
        key = json.dumps(run, sort_keys=True)
        if key in seen:
            errs.append(f"runs #{seen[key]}/#{i}: byte-identical records")
        else:
            seen[key] = i
    if errs:
        for e in errs:
            print(f"bench_history --check: {e}", file=sys.stderr)
        print(f"bench_history --check: {history_path} FAILED ({len(errs)} problem(s))",
              file=sys.stderr)
        return 1
    print(f"bench_history --check: {history_path} OK ({len(runs)} snapshot(s))")
    return 0


def self_test():
    """Exercise --check and the fold's idempotence in a temp dir."""
    failures = []

    def expect(name, got, want):
        if got != want:
            failures.append(f"{name}: check returned {got}, wanted {want}")

    def write_history(d, doc):
        with open(os.path.join(d, HISTORY_NAME), "w") as f:
            if isinstance(doc, str):
                f.write(doc)
            else:
                json.dump(doc, f)

    run_a = {"timestamp": "2026-07-30T00:00:00Z", "git_rev": "abc1234",
             "benches": {"decode": {"x": 1}}}
    run_b = {"timestamp": "2026-07-30T01:00:00Z", "git_rev": "abc1234",
             "benches": {"decode": {"x": 2}}}

    with tempfile.TemporaryDirectory() as d:
        expect("missing history is fine", check(d), 0)
        write_history(d, {"runs": [run_a, run_b]})
        expect("well-formed history", check(d), 0)
        write_history(d, "{not json")
        expect("unparsable history", check(d), 1)
        write_history(d, {"snapshots": []})
        expect("missing runs list", check(d), 1)
        write_history(d, {"runs": [dict(run_a, timestamp="yesterday")]})
        expect("bad timestamp", check(d), 1)
        write_history(d, {"runs": [{"timestamp": "2026-07-30T00:00:00Z",
                                    "git_rev": None, "benches": {}}]})
        expect("empty benches", check(d), 1)
        write_history(d, {"runs": [run_a, dict(run_b, benches=run_a["benches"])]})
        expect("adjacent duplicate benches", check(d), 1)
        write_history(d, {"runs": [run_a, run_b, dict(run_a)]})
        expect("byte-identical records", check(d), 1)

    # fold + check integration: folding twice over unchanged BENCH files
    # appends exactly one snapshot and stays clean
    with tempfile.TemporaryDirectory() as d:
        with open(os.path.join(d, "BENCH_decode.json"), "w") as f:
            json.dump({"bench": "decode_batched", "points": []}, f)
        fold(d)
        fold(d)
        with open(os.path.join(d, HISTORY_NAME)) as f:
            runs = json.load(f)["runs"]
        if len(runs) != 1:
            failures.append(f"idempotent fold: {len(runs)} snapshots, wanted 1")
        expect("fold output passes --check", check(d), 0)

    # dashboard rendering: column discovery, late-appearing metrics,
    # headline filtering, missing-cell placeholders
    md = render_dashboard({"runs": [
        dict(run_a, benches={"prefill": {"speedup_vs_token_by_token": 3.5,
                                         "prompt_tokens": 4096}}),
        dict(run_b, benches={"prefill": {"speedup_vs_token_by_token": 4.0,
                                         "ttft_speedup_vs_cold": 12.5},
                             "decode": {"ttft_p99_us": 850.0,
                                        "tracing_overhead_pct": 1.2,
                                        "spans_per_step": 2.0}}),
    ]})
    for needle, name in [
        ("| timestamp | git_rev | prefill: speedup_vs_token_by_token |",
         "column header"),
        ("prefill: ttft_speedup_vs_cold", "late-appearing column"),
        ("decode: ttft_p99_us", "ttft/p99 marker column"),
        ("decode: tracing_overhead_pct", "overhead marker column"),
        ("| 3.5 |", "metric cell"),
        ("| - |", "missing-cell placeholder"),
    ]:
        if needle not in md:
            failures.append(f"dashboard {name}: {needle!r} missing from:\n{md}")
    if "prompt_tokens" in md:
        failures.append("dashboard: non-headline key prompt_tokens leaked into the table")
    if "spans_per_step" in md:
        failures.append("dashboard: non-headline key spans_per_step leaked into the table")
    with tempfile.TemporaryDirectory() as d:
        expect("dashboard without history", dashboard(d), 0)
        write_history(d, {"runs": [run_a, run_b]})
        expect("dashboard on well-formed history", dashboard(d), 0)
        write_history(d, "{not json")
        expect("dashboard on unparsable history", dashboard(d), 1)

    if failures:
        for f_ in failures:
            print(f"bench_history --self-test: FAIL {f_}", file=sys.stderr)
        return 1
    print("bench_history --self-test: OK")
    return 0


def main():
    args = sys.argv[1:]
    mode = "fold"
    if "--check" in args:
        mode = "check"
        args.remove("--check")
    if "--self-test" in args:
        mode = "self-test"
        args.remove("--self-test")
    if "--dashboard" in args:
        mode = "dashboard"
        args.remove("--dashboard")
    bench_dir = args[0] if args else default_bench_dir()
    if mode == "check":
        return check(bench_dir)
    if mode == "self-test":
        return self_test()
    if mode == "dashboard":
        return dashboard(bench_dir)
    return fold(bench_dir)


if __name__ == "__main__":
    sys.exit(main())
