#!/usr/bin/env python3
"""Fold the current BENCH_*.json records into a cross-PR trajectory file.

Each bench target (fig4_throughput, table1_complexity, decode_batched,
prefill_throughput, ...) emits a machine-readable BENCH_<name>.json with
its latest numbers and a previous-run delta. That gives one step of
history; this script gives the whole trajectory: every invocation appends
a snapshot of all BENCH_*.json files found in the bench directory to
BENCH_HISTORY.json, keyed by timestamp and (when available) the git
revision, so per-PR perf movement can be plotted without re-running old
checkouts (the ROADMAP's perf-trajectory-tracking item).

Usage: scripts/bench_history.py [bench_dir]
  bench_dir defaults to the rust/ package root (where `cargo bench` runs
  and drops its BENCH_*.json files). The history file lives next to them.

Idempotence: a snapshot is only appended when at least one bench record
changed since the last snapshot, so re-running CI without re-running
benches does not grow the file.
"""

import json
import os
import subprocess
import sys
import time

HISTORY_NAME = "BENCH_HISTORY.json"


def git_rev(cwd):
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=cwd,
            capture_output=True,
            text=True,
            timeout=10,
        )
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def main():
    bench_dir = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "rust"
    )
    records = {}
    for name in sorted(os.listdir(bench_dir)):
        if not (name.startswith("BENCH_") and name.endswith(".json")) or name == HISTORY_NAME:
            continue
        path = os.path.join(bench_dir, name)
        try:
            with open(path) as f:
                records[name[len("BENCH_"):-len(".json")]] = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"bench_history: skipping unreadable {name}: {e}", file=sys.stderr)
    if not records:
        print(f"bench_history: no BENCH_*.json in {bench_dir}; nothing to fold")
        return 0

    history_path = os.path.join(bench_dir, HISTORY_NAME)
    history = {"runs": []}
    if os.path.exists(history_path):
        try:
            with open(history_path) as f:
                history = json.load(f)
            if not isinstance(history.get("runs"), list):
                raise ValueError("malformed history (no runs list)")
        except (OSError, json.JSONDecodeError, ValueError) as e:
            print(f"bench_history: resetting malformed {HISTORY_NAME}: {e}", file=sys.stderr)
            history = {"runs": []}

    if history["runs"] and history["runs"][-1].get("benches") == records:
        print(f"bench_history: no bench record changed; {history_path} untouched "
              f"({len(history['runs'])} snapshot(s))")
        return 0

    history["runs"].append({
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git_rev": git_rev(bench_dir),
        "benches": records,
    })
    tmp = history_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, history_path)
    print(f"bench_history: appended snapshot #{len(history['runs'])} "
          f"({', '.join(sorted(records))}) -> {history_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
