#!/usr/bin/env bash
# CI for the Rust substrate: tier-1 verify (build + tests), lints, and a
# bench smoke that regenerates the machine-readable BENCH_*.json records.
#
# Prerequisites: a Rust toolchain (cargo, clippy, rustfmt), network or a
# populated cargo cache for the crates.io deps (`xla`, `anyhow`), and the
# native xla_extension library the `xla` bindings link against (see
# rust/src/runtime/mod.rs docs).
#
# Usage: scripts/ci.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/../rust"

command -v cargo >/dev/null || { echo "cargo not found — install a Rust toolchain first" >&2; exit 1; }

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

# Feature matrix (docs/PRECISION.md): the SIMD microkernels are an
# opt-in feature that must be a bit-exact drop-in at f32, and the bf16
# state slab is a runtime precision choice exercised by the same suite
# (precision-forked pool/advance/trace tests run in every build). Build
# both feature sets and re-run the precision-sensitive suites under
# --features simd so the dispatched kernels face the same oracles —
# {default, simd} x {f32, bf16} in one pass each.
echo "== feature matrix: --features simd (build + precision/kernel suites) =="
cargo build --release --features simd
cargo test -q --features simd

# Observability acceptance: a traced mixed prefill/decode/score run must
# export valid Chrome-trace JSON whose timelines reconcile with the
# ServerStats latency histograms, and the GEMM flop hooks must show the
# O(log T) flops/token growth (docs/OBSERVABILITY.md).
echo "== obs: trace-export self-test =="
cargo test -q --release --test obs_trace

# The property suites (util::prop: pool no-leak, pooled no-leak, the
# serving-trace differential harness, ...) run under the fixed default
# seed above; re-run them under two extra seeds so CI explores fresh
# random traces every time the suite logic changes.
for seed in 20260730 987654321; do
    echo "== property suite under PROP_SEED=$seed =="
    PROP_SEED=$seed cargo test -q --lib -- property
done

echo "== lint: clippy -D warnings (config pinned in rust/clippy.toml) =="
cargo clippy -- -D warnings

echo "== lint: fmt --check =="
cargo fmt --check

# Invariant lints (docs/ANALYSIS.md): determinism (no HashMap/HashSet in
# serving paths), refcount pairing, unsafe hygiene, hot-path allocation.
# The self-test proves each lint still fires on its known-bad fixture
# before the clean pass over the real tree is trusted.
echo "== lint: xtask invariant lints (self-test, then tree) =="
cargo run -q -p xtask -- lint --self-test
cargo run -q -p xtask -- lint
cargo test -q -p xtask

# Memory-model pass: the tests also run natively in tier-1; under miri
# every load/store is checked against the aliasing and initialization
# rules. -Zmiri-ignore-leaks: the resident worker pool is intentionally
# process-lived and never joined.
echo "== miri: pool/dispatch/scope memory-model invariants =="
if cargo miri --version >/dev/null 2>&1; then
    MIRIFLAGS="-Zmiri-ignore-leaks" cargo miri test --test miri_invariants
else
    echo "cargo-miri not installed — skipping (rustup component add miri)" >&2
fi

# Interleaving pass: loom model-checks scope completion / panic-in-job /
# shutdown ordering across all feasible schedules. Gated on the loom
# crate actually resolving (it is an optional, cfg(loom)-only dep that
# an offline cargo cache may not carry).
echo "== loom: threadpool interleaving models =="
if RUSTFLAGS="--cfg loom" cargo build -q --release --test loom_threadpool 2>/dev/null; then
    RUSTFLAGS="--cfg loom" cargo test -q --release --test loom_threadpool
else
    echo "loom unavailable in the cargo cache — skipping model checking" >&2
fi

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== bench smoke (--quick): fig4 + table1 + decode + prefill, emits BENCH_*.json =="
    cargo bench --bench fig4_throughput -- --quick
    cargo bench --bench table1_complexity -- --quick
    # decode_batched/prefill_throughput run with --features simd so the
    # simd_speedup_vs_scalar headline reflects the dispatched kernels
    # (scalar-vs-SIMD bit-exactness is asserted in-bench before timing;
    # without the feature the headline degrades to 1.0)
    cargo bench --features simd --bench decode_batched -- --quick
    # prefill_throughput carries the chunkwise-speedup AND the
    # score_tokens_per_s headlines (equivalence asserted before timing)
    cargo bench --features simd --bench prefill_throughput -- --quick
    # the serving-engine latency bench also A/Bs the obs recorder on/off,
    # asserts the tracing-disabled regression stays <2%, and merges the
    # tracing/TTFT headlines into BENCH_decode.json
    cargo bench --bench decode_latency -- --quick

    echo "== bench history: fold BENCH_*.json into BENCH_HISTORY.json =="
    if command -v python3 >/dev/null; then
        python3 ../scripts/bench_history.py --self-test
        python3 ../scripts/bench_history.py .
        python3 ../scripts/bench_history.py --check .
    else
        echo "python3 not found — skipping bench-history fold" >&2
    fi
fi

echo "CI OK"
