//! Memory-model invariants for the unsafe/aliasing-sensitive substrate,
//! written to run under `cargo miri test --test miri_invariants` (and as
//! plain integration tests otherwise — they assert the same behavior
//! either way, miri merely checks every load/store against the borrow
//! and initialization rules while they run).
//!
//! Three surfaces earn a miri pass (see docs/ANALYSIS.md):
//!
//! 1. [`StatePool`] retain/release/clone-on-write — index-based slab
//!    sharing whose "no write to shared state" contract is enforced by
//!    refcount asserts, not the borrow checker;
//! 2. [`slab_block_dispatch`] — hands out disjoint `&mut` sub-slices of
//!    one slab to concurrently running closures via `split_at_mut`
//!    carving, exactly the pattern stacked-borrows violations hide in;
//! 3. [`ThreadPool::scope`] — erases job lifetimes with a transmute; a
//!    dangling borrow after scope returns is undefined behavior miri
//!    sees immediately.
//!
//! Sizes are deliberately tiny: miri executes ~100-1000× slower than
//! native.

use loglinear::state::pool::{BlockId, StatePool};
use loglinear::tensor::slab_block_dispatch;
use loglinear::util::threadpool::ThreadPool;

use std::sync::atomic::{AtomicUsize, Ordering};

/// A retain/release/CoW trace touching every pool entry point the
/// serving stack uses: alloc, write, retain (cache share), clone_block
/// (copy-on-write), axpy (bucket merge), release in both orders.
/// Under miri this validates that index-carved block slices never
/// overlap and freed blocks are never read.
#[test]
fn pool_retain_release_cow_trace_is_memory_clean() {
    let mut pool = StatePool::new(8, 6);

    // alloc two privately owned blocks and write them
    let a = pool.alloc().unwrap();
    let b = pool.alloc().unwrap();
    pool.get_mut(a).iter_mut().enumerate().for_each(|(i, x)| *x = i as f32);
    pool.get_mut(b).fill(2.0);

    // a "cache" shares block a; it becomes immutable
    pool.retain(a);
    assert!(pool.is_shared(a));

    // copy-on-write: the writer clones a, releases its shared handle,
    // and mutates the private clone; the cached bytes must not move
    let a2 = pool.clone_block(a).unwrap();
    pool.release(a);
    assert_eq!(pool.get(a), pool.get(a2));
    pool.get_mut(a2)[0] = 99.0;
    assert_eq!(pool.get(a)[0], 0.0, "shared original untouched by CoW write");

    // bucket merge in both slab directions (dst < src and dst > src
    // exercise both split_at_mut branches in StatePool::axpy)
    pool.axpy(a2, b, 0.5);
    pool.axpy(b, a2, 0.5);
    assert_eq!(pool.get(a2)[1], 2.0); // 1.0 + 0.5·2.0

    // drain every owner; the pool must be empty and reusable
    pool.release(a); // cache's ref
    pool.release(a2);
    pool.release(b);
    assert_eq!(pool.in_use(), 0);
    let c = pool.alloc().unwrap();
    assert!(pool.get(c).iter().all(|&x| x == 0.0), "recycled block is zeroed");
    pool.release(c);
}

/// The scattered-block dispatcher carves one slab into disjoint `&mut`
/// runs for concurrently executing jobs. A small scattered case (gaps
/// before, between, and after runs) drives every carve branch while
/// miri watches the aliasing.
#[test]
fn slab_block_dispatch_aliasing_is_disjoint() {
    let (cap, be) = (9usize, 4usize);
    let blocks = [1usize, 2, 5, 8]; // gaps at 0, 3-4, 6-7
    let mut slab = vec![0.0f32; cap * be];
    slab_block_dispatch(&mut slab, be, &blocks, 2, |j, block| {
        for x in block.iter_mut() {
            *x += (j + 1) as f32;
        }
    });
    for (row, chunk) in slab.chunks(be).enumerate() {
        let want = match blocks.iter().position(|&b| b == row) {
            Some(j) => (j + 1) as f32,
            None => 0.0,
        };
        assert!(chunk.iter().all(|&x| x == want), "row {row}");
    }
}

/// `scope`'s lifetime erasure: jobs borrow stack-local state, the pool
/// is dropped right after. If scope could return while a job still ran,
/// miri would flag the dangling borrow; if the erased box leaked, miri's
/// leak check would flag that.
#[test]
fn scope_borrowed_jobs_do_not_outlive_the_scope() {
    let hits = AtomicUsize::new(0);
    {
        let pool = ThreadPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let hits = &hits;
                Box::new(move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(jobs);
        // borrow of `hits` has ended; pool drops (joins workers) here
    }
    assert_eq!(hits.load(Ordering::SeqCst), 4);
}

/// BlockId handles stay valid across `grow` (the slab reallocates; the
/// indices — not pointers — are why). Miri confirms no stale reference
/// survives the Vec reallocation.
#[test]
fn block_handles_survive_pool_growth() {
    let mut pool = StatePool::new(4, 1);
    let a: BlockId = pool.alloc().unwrap();
    pool.get_mut(a)[3] = 7.0;
    pool.grow(3);
    assert_eq!(pool.get(a)[3], 7.0);
    let b = pool.alloc().unwrap();
    pool.axpy(b, a, 1.0);
    assert_eq!(pool.get(b)[3], 7.0);
    pool.release(a);
    pool.release(b);
    assert_eq!(pool.in_use(), 0);
}
