//! End-to-end observability acceptance test (the PR's trace-export
//! self-test, run explicitly by `scripts/ci.sh`).
//!
//! One process-wide test (the recorder is a global; in-crate obs unit
//! tests serialize on a lock, this file simply owns its own binary):
//!
//! 1. a traced mixed prefill/decode/score/cancel run exports Chrome
//!    trace-event JSON that parses back through `util::json` with the
//!    right phases and categories,
//! 2. per-request timelines reconstructed from the trace reconcile with
//!    the TTFT / inter-token / queue-wait latencies `ServerStats`
//!    measured independently,
//! 3. kernel flop accounting over chunkwise prompt scoring shows
//!    O(log T) flops-per-token growth (semilog fit checked — the
//!    paper's O(T log T) prefill claim, observed from the GEMM hooks).

use std::collections::BTreeMap;
use std::time::Duration;

use loglinear::coordinator::backend::{PooledBackend, TransitionKind};
use loglinear::coordinator::batcher::BatchPolicy;
use loglinear::coordinator::server::DecodeServer;
use loglinear::coordinator::{GenRequest, ScoreRequest, StreamEvent, SubmitError};
use loglinear::obs;
use loglinear::util::json::Json;
use loglinear::util::stats::{ols, scaling_exponent};

/// Clock-skew allowance between the recorder's epoch ticks and the
/// server's `Instant` reads (taken within a few statements of each
/// other, but a preempt between them is possible on a loaded machine).
const SKEW: f64 = 10e-3;

#[test]
fn traced_mixed_run_exports_chrome_trace_and_flops_grow_logarithmically() {
    // ---- part 1: mixed traffic under tracing -------------------------
    obs::enable_with_capacity(1 << 16);
    let backend = PooledBackend::with_model_config(
        64, 2, 2, TransitionKind::Mamba2, 8, 8, 4, 8192, 77,
    );
    let mut srv =
        DecodeServer::with_backend(backend, BatchPolicy::new(vec![1, 4], Duration::ZERO));
    // four generations whose 11-token prompts take 2 prefill chunks each
    for id in 0..4u64 {
        let prompt: Vec<i32> =
            (0..11).map(|i| ((id as i64 * 13 + i * 7) % 64) as i32).collect();
        srv.submit(GenRequest { id, prompt, max_new: 6 }).unwrap();
    }
    // a scoring request rides along (2 chunks + tail, 10 score rows)
    let score_tokens: Vec<i32> = (0..11).map(|i| ((i * 5 + 3) % 64) as i32).collect();
    srv.submit_score(ScoreRequest { id: 100, tokens: score_tokens }).unwrap();
    // and a long-running generation that gets cancelled mid-flight
    srv.submit(GenRequest { id: 50, prompt: vec![1, 2, 3], max_new: 50 }).unwrap();
    // duplicate ids are rejected wherever the original is live — the
    // generation queue, the score queue, and across request kinds
    // (stream events, timelines, and cancel all key on the id, so a
    // duplicate would make them ambiguous). Rejection happens before
    // the Submit hook fires, so these leave no trace events and the
    // timeline / queue-wait assertions below stay exact.
    assert_eq!(
        srv.submit(GenRequest { id: 2, prompt: vec![4, 5], max_new: 1 }),
        Err(SubmitError::DuplicateId),
        "id 2 is queued for generation"
    );
    assert_eq!(
        srv.submit_score(ScoreRequest { id: 100, tokens: vec![1, 2, 3] }),
        Err(SubmitError::DuplicateId),
        "id 100 is queued for scoring"
    );
    assert_eq!(
        srv.submit(GenRequest { id: 100, prompt: vec![9], max_new: 1 }),
        Err(SubmitError::DuplicateId),
        "liveness is checked across kinds: a queued score id blocks a gen"
    );
    assert_eq!(
        srv.submit_score(ScoreRequest { id: 50, tokens: vec![7, 8] }),
        Err(SubmitError::DuplicateId),
        "liveness is checked across kinds: a queued gen id blocks a score"
    );
    for _ in 0..8 {
        srv.step().unwrap();
    }
    // ...and ids stay reserved once admitted and mid-decode, not just
    // while queued
    assert_eq!(
        srv.submit(GenRequest { id: 50, prompt: vec![1], max_new: 1 }),
        Err(SubmitError::DuplicateId),
        "id 50 is mid-generation"
    );
    let mut stream = srv.take_stream_events();
    assert!(srv.cancel(50), "id 50 must be live to cancel");
    let mut guard = 0;
    while srv.pending() > 0 {
        srv.step().unwrap();
        stream.extend(srv.take_stream_events());
        guard += 1;
        assert!(guard < 10_000, "no forward progress");
    }
    stream.extend(srv.take_stream_events());
    let stats = srv.stats.clone();
    let drained = obs::drain();
    obs::disable();
    assert_eq!(drained.dropped, 0, "2^16 capacity must hold this run");
    assert!(!drained.events.is_empty());

    // ---- Chrome trace export is valid, Perfetto-shaped JSON ----------
    let doc = obs::chrome_trace(&drained.events, drained.dropped);
    let parsed = Json::parse(&doc.to_string()).expect("chrome trace must parse back");
    let arr = parsed.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
    assert_eq!(arr.len(), drained.events.len());
    for ev in arr {
        let ph = ev.get("ph").and_then(|v| v.as_str()).expect("every event has a phase");
        assert!(ph == "X" || ph == "i", "unexpected phase {ph}");
        assert!(ev.get("ts").and_then(|v| v.as_f64()).is_some());
        assert!(ev.get("name").and_then(|v| v.as_str()).is_some());
        assert!(ev.get("args").and_then(|a| a.get("flops")).is_some());
    }
    let table = obs::summary_table(&drained.events, drained.dropped);
    for needle in [
        "submit", "queue_wait", "admit", "prefill_chunk", "score_chunk", "decode_step",
        "advance_bucket", "read_batch", "project", "logits_gemm", "stream_emit", "cancel",
    ] {
        assert!(table.contains(needle), "summary table missing {needle}:\n{table}");
    }

    // ---- timelines reconstruct every request's lifecycle -------------
    let tls = obs::timelines(&drained.events);
    assert_eq!(
        tls.iter().map(|t| t.id).collect::<Vec<_>>(),
        vec![0, 1, 2, 3, 50, 100],
        "one timeline per submitted request"
    );
    // per-request streamed-token counts, from the server's own stream
    let mut token_counts: BTreeMap<u64, usize> = BTreeMap::new();
    let mut score_rows = 0usize;
    for e in &stream {
        match *e {
            StreamEvent::Token { id, .. } => *token_counts.entry(id).or_default() += 1,
            StreamEvent::Score { id, .. } => {
                assert_eq!(id, 100);
                score_rows += 1;
            }
            _ => {}
        }
    }
    assert_eq!(score_rows, 10, "11 score tokens stream 10 rows");
    for id in 0..4u64 {
        let tl = tls.iter().find(|t| t.id == id).unwrap();
        assert!(tl.submit_ns.is_some() && tl.queue_wait_ns.is_some() && tl.admit_ns.is_some());
        assert_eq!(tl.prefill_chunks, 2, "11-token prompt at C=4 ingests 2 chunks");
        assert!(tl.prefill_flops > 0, "prefill chunks must attribute kernel flops");
        assert_eq!(tl.stream_ns.len(), 6, "one StreamEmit per generated token");
        assert!(!tl.cancelled);
    }
    let t50 = tls.iter().find(|t| t.id == 50).unwrap();
    assert!(t50.cancelled, "cancel must land in the timeline");
    assert_eq!(t50.stream_ns.len(), token_counts[&50], "tokens streamed before cancel");
    let t100 = tls.iter().find(|t| t.id == 100).unwrap();
    assert_eq!(t100.score_chunks, 3, "2 score chunks + the tail");
    assert_eq!(t100.stream_ns.len(), 10, "one StreamEmit per score row");

    // ---- trace-derived latencies reconcile with ServerStats ----------
    assert_eq!(stats.ttft_seconds.count(), token_counts.len(), "one TTFT per streaming request");
    let total_tokens: usize = token_counts.values().sum();
    assert_eq!(
        stats.inter_token_seconds.count(),
        total_tokens - token_counts.len(),
        "one gap per consecutive token pair"
    );
    assert_eq!(stats.queue_wait_seconds.count(), 6, "6 admissions (5 gen + 1 score)");
    let gen_tls: Vec<_> =
        tls.iter().filter(|t| token_counts.contains_key(&t.id)).collect();
    let trace_ttfts: Vec<f64> =
        gen_tls.iter().map(|t| t.ttft_seconds().expect("both endpoints captured")).collect();
    for &ttft in &trace_ttfts {
        assert!(
            ttft >= stats.ttft_seconds.min() - SKEW && ttft <= stats.ttft_seconds.max() + SKEW,
            "trace TTFT {ttft} outside stats extrema [{}, {}]",
            stats.ttft_seconds.min(),
            stats.ttft_seconds.max()
        );
    }
    let trace_mean_ttft = trace_ttfts.iter().sum::<f64>() / trace_ttfts.len() as f64;
    assert!(
        (trace_mean_ttft - stats.ttft_seconds.mean()).abs() < SKEW,
        "mean TTFT: trace {trace_mean_ttft} vs stats {}",
        stats.ttft_seconds.mean()
    );
    let gaps: Vec<f64> = gen_tls.iter().flat_map(|t| t.inter_token_seconds()).collect();
    assert_eq!(gaps.len(), stats.inter_token_seconds.count());
    let trace_mean_gap = gaps.iter().sum::<f64>() / gaps.len() as f64;
    assert!(
        (trace_mean_gap - stats.inter_token_seconds.mean()).abs() < SKEW,
        "mean inter-token gap: trace {trace_mean_gap} vs stats {}",
        stats.inter_token_seconds.mean()
    );

    // ---- part 2: flop accounting shows O(log T) flops-per-token ------
    // Score one prompt per length through a fresh traced server: the
    // chunkwise path's per-token flops must grow like a + b·log2 T
    // (level reads touch O(log T) Fenwick levels), NOT polynomially.
    let lengths = [64usize, 128, 256, 512, 1024];
    let mut per_token: Vec<f64> = Vec::new();
    for &t in &lengths {
        obs::enable_with_capacity(1 << 12); // resets flop counters
        let backend = PooledBackend::with_model_config(
            64, 1, 1, TransitionKind::Mamba2, 8, 8, 16, 4096, 5,
        );
        let mut srv =
            DecodeServer::with_backend(backend, BatchPolicy::new(vec![1], Duration::ZERO));
        let tokens: Vec<i32> = (0..t).map(|i| ((i * 7 + 5) % 64) as i32).collect();
        srv.submit_score(ScoreRequest { id: 0, tokens }).unwrap();
        let mut guard = 0;
        while srv.pending() > 0 {
            srv.step().unwrap();
            guard += 1;
            assert!(guard < 10 * t, "scoring made no progress");
        }
        let flops = obs::total_flops();
        obs::drain();
        obs::disable();
        assert!(flops > 0, "T={t}: GEMM hooks must attribute flops");
        per_token.push(flops as f64 / t as f64);
    }
    // strictly increasing (longer prompts touch more Fenwick levels)...
    for w in per_token.windows(2) {
        assert!(w[1] > w[0], "flops/token must grow with T: {per_token:?}");
    }
    // ...fitting a + b·log2 T almost perfectly...
    let log_t: Vec<f64> = lengths.iter().map(|&t| (t as f64).log2()).collect();
    let (_a, b, r2) = ols(&log_t, &per_token);
    assert!(b > 0.0, "semilog slope must be positive: {per_token:?}");
    assert!(r2 > 0.9, "flops/token vs log2 T fit r2={r2}: {per_token:?}");
    // ...and strongly sublinear in T (log-log slope far below linear)
    let expo = scaling_exponent(&lengths, &per_token);
    assert!(
        expo < 0.5,
        "flops/token scaling exponent {expo} — not O(log T): {per_token:?}"
    );
}
