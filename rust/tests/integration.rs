//! Cross-layer integration tests.
//!
//! - golden fixtures: the pure-jnp oracles (`python/compile/kernels/ref.py`)
//!   and the Rust attention zoo must agree on identical inputs — this pins
//!   the two independent implementations of the paper's math together.
//! - artifact contract: manifests, params.bin, and the eval executable
//!   agree end-to-end (requires `make artifacts`).
//! - full-stack train smoke: two Adam steps through PJRT reduce loss
//!   deterministically.

use std::path::PathBuf;

use loglinear::attention;
use loglinear::tensor::Mat;
use loglinear::util::json::Json;

fn artifacts_dir() -> PathBuf {
    loglinear::runtime::artifacts_dir()
}

fn golden() -> Option<Json> {
    let path = artifacts_dir().join("golden_kernels.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(Json::parse(&text).expect("golden fixture parses"))
}

fn mat_from(j: &Json, key: &str, rows: usize, cols: usize) -> Mat {
    let v = j.get(key).unwrap().as_f32_vec().unwrap();
    Mat::from_vec(rows, cols, v)
}

#[test]
fn rust_oracles_match_python_golden_fixtures() {
    let Some(g) = golden() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let meta = g.get("meta").unwrap();
    let t = meta.get("T").unwrap().as_usize().unwrap();
    let dk = meta.get("dk").unwrap().as_usize().unwrap();
    let dv = meta.get("dv").unwrap().as_usize().unwrap();
    let q = mat_from(&g, "q", t, dk);
    let k = mat_from(&g, "k", t, dk);
    let v = mat_from(&g, "v", t, dv);
    let log_alpha = g.get("log_alpha").unwrap().as_f32_vec().unwrap();
    let alpha: Vec<f32> = log_alpha.iter().map(|x| x.exp()).collect();
    let beta = g.get("beta").unwrap().as_f32_vec().unwrap();
    let nl = loglinear::fenwick::num_levels(t);
    let lam = mat_from(&g, "lam", t, nl);
    let out = g.get("out").unwrap();

    let check = |name: &str, got: Mat| {
        let expect = mat_from(out, name, t, dv);
        if let Err(e) = loglinear::tensor::allclose(&got, &expect, 5e-4, 5e-4) {
            panic!("golden mismatch for {name}: {e}");
        }
    };
    check("mamba2", attention::mamba2::recurrent(&q, &k, &v, &alpha));
    check(
        "loglinear_mamba2",
        attention::loglinear_mamba2::recurrent(&q, &k, &v, &alpha, &lam),
    );
    check(
        "gated_deltanet",
        attention::gated_deltanet::recurrent(&q, &k, &v, &alpha, &beta),
    );
    check(
        "loglinear_gdn",
        attention::loglinear_gdn::recurrent(&q, &k, &v, &alpha, &beta, &lam),
    );
}

#[test]
fn full_stack_eval_and_train_smoke() {
    let dir = artifacts_dir();
    if !dir.join("manifest_tiny_loglinear_mamba2.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = loglinear::runtime::Runtime::cpu().expect("pjrt client");
    let mut model =
        loglinear::runtime::ModelHandle::load(&rt, &dir, "tiny_loglinear_mamba2").unwrap();
    let b = model.manifest.batch;
    let t = model.manifest.cfg("seq_len");
    let vocab = model.manifest.cfg("vocab") as i32;
    let tokens: Vec<i32> = (0..b * t).map(|i| (i as i32 * 7 + 3) % vocab).collect();

    // eval: finite loss near ln(vocab) for an untrained model
    let out = model.eval(&tokens).unwrap();
    assert!(out.loss.is_finite());
    assert!((out.loss - (vocab as f32).ln()).abs() < 1.0, "loss {}", out.loss);
    assert_eq!(out.per_pos.len(), b * (t - 1));
    assert_eq!(out.preds.len(), b * t);

    // two train steps reduce loss on a fixed batch, deterministically
    model.ensure_train(&rt).unwrap();
    let l1 = model.train_step(1, &tokens, 1e-2).unwrap().loss;
    let mut l_last = l1;
    for step in 2..=4 {
        l_last = model.train_step(step, &tokens, 1e-2).unwrap().loss;
    }
    assert!(l_last < l1, "no progress: {l1} -> {l_last}");
}

#[test]
fn decode_step_matches_eval_forward() {
    // Feeding a sequence token-by-token through the compiled decode_step
    // must reproduce the eval artifact's argmax predictions (chunkwise
    // forward == Fenwick recurrence, across the whole three-layer stack).
    let dir = artifacts_dir();
    if !dir.join("manifest_tiny_loglinear_mamba2.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = loglinear::runtime::Runtime::cpu().unwrap();
    let mut model =
        loglinear::runtime::ModelHandle::load(&rt, &dir, "tiny_loglinear_mamba2").unwrap();
    let b = model.manifest.batch;
    let t = model.manifest.cfg("seq_len");
    let vocab = model.manifest.cfg("vocab") as i32;
    let tokens: Vec<i32> = (0..b * t).map(|i| (i as i32 * 11 + 5) % vocab).collect();
    let eval_out = model.eval(&tokens).unwrap();

    model.ensure_decode(&rt, 1).unwrap();
    // run sequence 0 through decode
    let mut states = model.zero_states(1);
    let mut preds = Vec::new();
    for pos in 0..t {
        let tok = [tokens[pos]];
        let logits = model
            .decode_step(1, &mut states, &tok, &[pos as i32])
            .unwrap();
        preds.push(loglinear::tensor::ops::argmax(&logits) as i32);
    }
    let mismatches = (0..t)
        .filter(|&p| preds[p] != eval_out.preds[p])
        .count();
    // tiny numerical differences can flip near-tie argmaxes; demand 95%+
    assert!(
        mismatches <= t / 20,
        "decode/eval argmax mismatch at {mismatches}/{t} positions"
    );
}
