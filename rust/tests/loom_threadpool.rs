//! loom model-checking of the thread-pool concurrency substrate.
//!
//! Compiled (and meaningful) only under `RUSTFLAGS="--cfg loom"`, which
//! swaps every sync primitive the pool uses for loom's instrumented
//! doubles via `util/sync.rs`. Each `loom::model` closure below is then
//! executed under **every** feasible thread interleaving and memory
//! ordering, so a passing model is a proof over the explored state space
//! rather than a lucky schedule:
//!
//! ```sh
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_threadpool
//! ```
//!
//! Under a plain build this file is empty (`#![cfg(loom)]`), so tier-1
//! `cargo test` neither compiles nor needs the loom crate. The models
//! are deliberately tiny — loom's state space is exponential in
//! threads × synchronization operations — but each one pins exactly one
//! contract of [`loglinear::util::threadpool::ThreadPool`] that the
//! serving stack's soundness argument leans on (see the SAFETY comment
//! in `ThreadPool::scope` and docs/ANALYSIS.md):
//!
//! 1. `scope` never returns while a dispatched job is still running
//!    (the lifetime-erasure barrier),
//! 2. a panicking job still drains the barrier, and `scope` re-raises
//!    only after every sibling job finished,
//! 3. pool shutdown runs every already-queued job before workers exit.

#![cfg(loom)]

use std::panic::{catch_unwind, AssertUnwindSafe};

use loglinear::util::threadpool::ThreadPool;
use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::Arc;

/// Contract 1 — completion barrier. Two workers, two borrowed-lifetime
/// jobs: when `scope` returns, both jobs must have fully executed, under
/// every interleaving of dispatch, execution, and the condvar handshake.
/// The counter lives on the model's stack, so any schedule in which
/// `scope` returned early would be a genuine use-after-free of `'env`
/// borrows — exactly what the `CompletionBarrier` forbids.
#[test]
fn scope_completion_barrier_holds_under_all_interleavings() {
    loom::model(|| {
        let pool = ThreadPool::new(2);
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|_| {
                let counter = &counter;
                Box::new(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(jobs);
        // scope returned => every job ran and its borrow of `counter` ended
        assert_eq!(counter.load(Ordering::SeqCst), 2);
    });
}

/// Contract 2 — panic-during-job. One of two jobs panics; the worker
/// catches it, still decrements the barrier, and `scope` re-raises only
/// after the sibling job has completed. In every interleaving the
/// observable outcome must be the same: `scope` unwinds *and* the
/// surviving job's side effect is visible.
#[test]
fn scope_reraises_job_panic_after_sibling_jobs_complete() {
    loom::model(|| {
        let pool = ThreadPool::new(2);
        let done = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|i| {
                let done = &done;
                Box::new(move || {
                    if i == 0 {
                        panic!("deliberate model panic");
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        let result = catch_unwind(AssertUnwindSafe(|| pool.scope(jobs)));
        assert!(result.is_err(), "scope must re-raise the job panic");
        assert_eq!(
            done.load(Ordering::SeqCst),
            1,
            "the non-panicking job must have finished before scope unwound"
        );
    });
}

/// Contract 3 — shutdown ordering. Jobs queued with `execute` before the
/// pool is dropped must all run: `Drop` raises the shutdown flag under
/// the scheduler lock and a worker only exits once the flag is set *and*
/// every per-worker run queue (its own and every steal target) is empty,
/// so no interleaving may discard queued work or let a worker exit past
/// an unprocessed job — including jobs parked on a sibling's queue that
/// must be stolen on the way out.
#[test]
fn shutdown_runs_every_queued_job_before_workers_exit() {
    loom::model(|| {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            let counter = Arc::clone(&counter);
            pool.execute(move || {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // raises shutdown, wakes all workers, joins both
        assert_eq!(counter.load(Ordering::SeqCst), 2);
    });
}
