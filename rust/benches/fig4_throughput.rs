//! E1/E2/E12 — Fig. 4 reproduction: training-form kernel runtime and
//! throughput across sequence lengths, CPU substrate (DESIGN.md §6:
//! absolute numbers differ from the paper's H100, the *shape* — scaling
//! exponents and who-crosses-whom — is the claim under test).
//!
//! Series (paper legend → here):
//! - FlashAttention-2        → softmax attention (O(T^2))
//! - Mamba-2                 → chunkwise SSD (O(T))
//! - Log-Linear Mamba-2      → chunkwise Alg. 1, level-fused (O(T log T))
//! - Log-Linear Mamba-2 (naive) → one masked sweep per level (E12 ablation)
//!
//! Run: `cargo bench --bench fig4_throughput [-- --quick] [--threads N]`
//!
//! Emits `BENCH_fig4.json` (series, T, secs, ns/token, fitted scaling
//! exponents, GEMM thread count). If a previous `BENCH_fig4.json` exists
//! its points are carried along as `previous_ns_per_token` and a
//! `speedup_vs_previous` table is computed — run once before and once
//! after a kernel change to record the before/after trajectory.

use loglinear::attention::{self, AttnInputs};
use loglinear::bench::{bench, section};
use loglinear::tensor;
use loglinear::util::json::Json;
use loglinear::util::stats::scaling_exponent;
use loglinear::util::Rng;

const OUT_PATH: &str = "BENCH_fig4.json";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    if let Some(pos) = args.iter().position(|a| a == "--threads") {
        if let Some(n) = args.get(pos + 1).and_then(|s| s.parse::<usize>().ok()) {
            tensor::gemm_threads(n);
        }
    }

    let (dk, dv, c) = (64, 64, 64);
    let lens: Vec<usize> = if quick {
        vec![512, 1024, 2048]
    } else {
        vec![512, 1024, 2048, 4096, 8192]
    };

    section(&format!(
        "Fig. 4 (right): kernel runtime, forward pass, head-dim 64, chunk 64, gemm_threads={}",
        tensor::current_gemm_threads()
    ));
    let mut rows: Vec<(String, usize, f64)> = Vec::new();
    for &t in &lens {
        let mut rng = Rng::new(t as u64);
        let x = AttnInputs::random(t, dk, dv, &mut rng);
        let softmax_cap = 4096; // O(T^2) gets slow; cap like the paper caps FA2 plots
        if t <= softmax_cap {
            let r = bench(&format!("softmax/T={t}"), 0.4, || {
                std::hint::black_box(attention::softmax::softmax_attention(&x.q, &x.k, &x.v));
            });
            rows.push(("softmax".into(), t, r.secs.mean));
        }
        let r = bench(&format!("mamba2-chunkwise/T={t}"), 0.4, || {
            std::hint::black_box(attention::mamba2::chunkwise(&x.q, &x.k, &x.v, &x.alpha, c));
        });
        rows.push(("mamba2".into(), t, r.secs.mean));
        let r = bench(&format!("loglinear-mamba2/T={t}"), 0.4, || {
            std::hint::black_box(attention::loglinear_mamba2::chunkwise(
                &x.q, &x.k, &x.v, &x.alpha, &x.lambda, c,
            ));
        });
        rows.push(("loglinear_mamba2".into(), t, r.secs.mean));
        let r = bench(&format!("loglinear-mamba2-naive/T={t}"), 0.4, || {
            std::hint::black_box(attention::loglinear_mamba2::chunkwise_naive(
                &x.q, &x.k, &x.v, &x.alpha, &x.lambda, c,
            ));
        });
        rows.push(("loglinear_naive".into(), t, r.secs.mean));
    }

    section("Fig. 4 (left): training throughput (tokens/s, fwd-pass proxy)");
    println!("{:<22} {:>8} {:>14}", "series", "T", "tokens/s");
    for (name, t, secs) in &rows {
        println!("{name:<22} {t:>8} {:>14.0}", *t as f64 / secs);
    }

    section("scaling exponents (log-log slope of runtime vs T)");
    let mut exponents: Vec<(&str, f64)> = Vec::new();
    for series in ["softmax", "mamba2", "loglinear_mamba2", "loglinear_naive"] {
        let pts: Vec<(usize, f64)> = rows
            .iter()
            .filter(|(n, _, _)| n == series)
            .map(|(_, t, s)| (*t, *s))
            .collect();
        if pts.len() >= 3 {
            let p = scaling_exponent(
                &pts.iter().map(|(t, _)| *t).collect::<Vec<_>>(),
                &pts.iter().map(|(_, s)| *s).collect::<Vec<_>>(),
            );
            println!("  {series:<22} T^{p:.2}");
            exponents.push((series, p));
        }
    }

    section("crossovers (paper: log-linear beats FA2 beyond 8K on H100)");
    for &t in &lens {
        let get = |name: &str| {
            rows.iter()
                .find(|(n, tt, _)| n == name && *tt == t)
                .map(|(_, _, s)| *s)
        };
        if let (Some(sm), Some(ll)) = (get("softmax"), get("loglinear_mamba2")) {
            println!(
                "  T={t:>6}: loglinear/softmax runtime ratio = {:.2} {}",
                ll / sm,
                if ll < sm { "(log-linear wins)" } else { "" }
            );
        }
        if let (Some(nv), Some(ll)) = (get("loglinear_naive"), get("loglinear_mamba2")) {
            println!("  T={t:>6}: fused speedup over naive = {:.2}x", nv / ll);
        }
    }

    // ---- machine-readable record (BENCH_fig4.json) ----
    let previous = std::fs::read_to_string(OUT_PATH)
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    let prev_ns = |series: &str, t: usize| -> Option<f64> {
        previous
            .as_ref()?
            .get("points")?
            .as_arr()?
            .iter()
            .find(|p| {
                p.get("series").and_then(|s| s.as_str()) == Some(series)
                    && p.get("T").and_then(|v| v.as_usize()) == Some(t)
            })?
            .get("ns_per_token")?
            .as_f64()
    };

    let mut points = Vec::new();
    let mut speedups = Vec::new();
    for (name, t, secs) in &rows {
        let ns_per_token = secs * 1e9 / *t as f64;
        let mut p = Json::obj()
            .set("series", name.as_str())
            .set("T", *t)
            .set("secs", *secs)
            .set("ns_per_token", ns_per_token);
        if let Some(old) = prev_ns(name, *t) {
            p = p.set("previous_ns_per_token", old);
            speedups.push(
                Json::obj()
                    .set("series", name.as_str())
                    .set("T", *t)
                    .set("speedup", old / ns_per_token),
            );
        }
        points.push(p);
    }
    let mut doc = Json::obj()
        .set("bench", "fig4_throughput")
        .set("quick", quick)
        .set("gemm_threads", tensor::current_gemm_threads())
        .set("dk", dk)
        .set("dv", dv)
        .set("chunk", c)
        .set("points", Json::Arr(points));
    let mut exp_obj = Json::obj();
    for (series, p) in &exponents {
        exp_obj = exp_obj.set(series, *p);
    }
    doc = doc.set("scaling_exponents", exp_obj);
    if !speedups.is_empty() {
        doc = doc.set("speedup_vs_previous", Json::Arr(speedups.clone()));
        section("speedup vs previous BENCH_fig4.json");
        for s in &speedups {
            println!(
                "  {:<22} T={:>6}: {:.2}x",
                s.get("series").and_then(|v| v.as_str()).unwrap_or("?"),
                s.get("T").and_then(|v| v.as_usize()).unwrap_or(0),
                s.get("speedup").and_then(|v| v.as_f64()).unwrap_or(0.0),
            );
        }
    }
    match std::fs::write(OUT_PATH, doc.pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH}"),
        Err(e) => eprintln!("\nfailed to write {OUT_PATH}: {e}"),
    }
}
