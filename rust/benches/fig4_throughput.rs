//! E1/E2/E12 — Fig. 4 reproduction: training-form kernel runtime and
//! throughput across sequence lengths, CPU substrate (DESIGN.md §6:
//! absolute numbers differ from the paper's H100, the *shape* — scaling
//! exponents and who-crosses-whom — is the claim under test).
//!
//! Series (paper legend → here):
//! - FlashAttention-2        → softmax attention (O(T^2))
//! - Mamba-2                 → chunkwise SSD (O(T))
//! - Log-Linear Mamba-2      → chunkwise Alg. 1, level-fused (O(T log T))
//! - Log-Linear Mamba-2 (naive) → one masked sweep per level (E12 ablation)
//!
//! Run: `cargo bench --bench fig4_throughput`

use loglinear::attention::{self, AttnInputs};
use loglinear::bench::{bench, section};
use loglinear::util::stats::scaling_exponent;
use loglinear::util::Rng;

fn main() {
    let (dk, dv, c) = (64, 64, 64);
    let lens: Vec<usize> = std::env::args()
        .nth(1)
        .and_then(|s| if s == "--quick" { Some(vec![512, 1024, 2048]) } else { None })
        .unwrap_or_else(|| vec![512, 1024, 2048, 4096, 8192]);

    section("Fig. 4 (right): kernel runtime, forward pass, head-dim 64, chunk 64");
    let mut rows: Vec<(String, usize, f64)> = Vec::new();
    for &t in &lens {
        let mut rng = Rng::new(t as u64);
        let x = AttnInputs::random(t, dk, dv, &mut rng);
        let softmax_cap = 4096; // O(T^2) gets slow; cap like the paper caps FA2 plots
        if t <= softmax_cap {
            let r = bench(&format!("softmax/T={t}"), 0.4, || {
                std::hint::black_box(attention::softmax::softmax_attention(&x.q, &x.k, &x.v));
            });
            rows.push(("softmax".into(), t, r.secs.mean));
        }
        let r = bench(&format!("mamba2-chunkwise/T={t}"), 0.4, || {
            std::hint::black_box(attention::mamba2::chunkwise(&x.q, &x.k, &x.v, &x.alpha, c));
        });
        rows.push(("mamba2".into(), t, r.secs.mean));
        let r = bench(&format!("loglinear-mamba2/T={t}"), 0.4, || {
            std::hint::black_box(attention::loglinear_mamba2::chunkwise(
                &x.q, &x.k, &x.v, &x.alpha, &x.lambda, c,
            ));
        });
        rows.push(("loglinear_mamba2".into(), t, r.secs.mean));
        let r = bench(&format!("loglinear-mamba2-naive/T={t}"), 0.4, || {
            std::hint::black_box(attention::loglinear_mamba2::chunkwise_naive(
                &x.q, &x.k, &x.v, &x.alpha, &x.lambda, c,
            ));
        });
        rows.push(("loglinear_naive".into(), t, r.secs.mean));
    }

    section("Fig. 4 (left): training throughput (tokens/s, fwd-pass proxy)");
    println!("{:<22} {:>8} {:>14}", "series", "T", "tokens/s");
    for (name, t, secs) in &rows {
        println!("{name:<22} {t:>8} {:>14.0}", *t as f64 / secs);
    }

    section("scaling exponents (log-log slope of runtime vs T)");
    for series in ["softmax", "mamba2", "loglinear_mamba2", "loglinear_naive"] {
        let pts: Vec<(usize, f64)> = rows
            .iter()
            .filter(|(n, _, _)| n == series)
            .map(|(_, t, s)| (*t, *s))
            .collect();
        if pts.len() >= 3 {
            let p = scaling_exponent(
                &pts.iter().map(|(t, _)| *t).collect::<Vec<_>>(),
                &pts.iter().map(|(_, s)| *s).collect::<Vec<_>>(),
            );
            println!("  {series:<22} T^{p:.2}");
        }
    }

    section("crossovers (paper: log-linear beats FA2 beyond 8K on H100)");
    for &t in &lens {
        let get = |name: &str| {
            rows.iter()
                .find(|(n, tt, _)| n == name && *tt == t)
                .map(|(_, _, s)| *s)
        };
        if let (Some(sm), Some(ll)) = (get("softmax"), get("loglinear_mamba2")) {
            println!(
                "  T={t:>6}: loglinear/softmax runtime ratio = {:.2} {}",
                ll / sm,
                if ll < sm { "(log-linear wins)" } else { "" }
            );
        }
        if let (Some(nv), Some(ll)) = (get("loglinear_naive"), get("loglinear_mamba2")) {
            println!("  T={t:>6}: fused speedup over naive = {:.2}x", nv / ll);
        }
    }
}
