//! L3 coordinator micro-benches: the serving hot path must not be the
//! bottleneck. Measures batcher planning, queue ops, state-pool
//! alloc/release, and — on the REAL serving engine ([`PooledBackend`]
//! driven by [`DecodeServer`]) — end-to-end engine-step overhead for a
//! mixed prefill + decode + scoring workload, where the old bench
//! measured only the PJRT path's gather/scatter mirror.
//!
//! Run: `cargo bench --bench coordinator`

use std::time::Duration;

use loglinear::bench::{bench, section};
use loglinear::coordinator::backend::{PooledBackend, TransitionKind};
use loglinear::coordinator::batcher::{BatchPolicy, RequestQueue};
use loglinear::coordinator::server::DecodeServer;
use loglinear::coordinator::{GenRequest, ScoreRequest};
use loglinear::state::pool::StatePool;
use loglinear::util::Rng;

fn main() {
    section("batcher planning (pure logic)");
    let policy = BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(2));
    bench("plan x1000", 0.2, || {
        for ready in 0..1000usize {
            std::hint::black_box(policy.plan(ready % 17, Duration::from_millis((ready % 5) as u64)));
        }
    });

    section("request queue push/take");
    bench("queue 1024 push + take", 0.2, || {
        let mut q = RequestQueue::new();
        for i in 0..1024u32 {
            q.push(i);
        }
        while !q.is_empty() {
            std::hint::black_box(q.take(8));
        }
    });

    section("state pool alloc/release (dk*dv = 1024 floats)");
    bench("pool churn x1024", 0.2, || {
        let mut pool = StatePool::new(1024, 64);
        let mut live = Vec::new();
        let mut rng = Rng::new(1);
        for _ in 0..1024 {
            if !live.is_empty() && rng.chance(0.5) {
                let i = rng.below(live.len());
                let id = live.swap_remove(i);
                pool.release(id);
            } else if let Some(id) = pool.alloc() {
                live.push(id);
            }
        }
        for id in live {
            pool.release(id);
        }
    });

    // The real serving engine end to end: a sequential 2-layer 2-head
    // pooled backend under continuous batching, with chunked prefill,
    // decode, and prompt-scoring traffic mixed — measures the whole
    // engine loop (admission, budgeted ingest, batched step, sampling,
    // retirement), not a gather/scatter mirror of it.
    section("pooled serving engine: mixed prefill/decode/score traffic (L=2, H=2, dk=dv=16)");
    let serve = || {
        let backend = PooledBackend::with_model_config(
            128,
            2,
            2,
            TransitionKind::Mamba2,
            16,
            16,
            8,
            4096,
            0xC00,
        );
        let mut srv = DecodeServer::with_backend(
            backend,
            BatchPolicy::new(vec![8], Duration::ZERO).with_prefill_budget(4),
        );
        let mut rng = Rng::new(7);
        for id in 0..16u64 {
            let prompt_len = 2 + rng.below(30);
            let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.below(128) as i32).collect();
            srv.submit(GenRequest { id, prompt, max_new: 8 }).unwrap();
        }
        for id in 0..4u64 {
            let tokens: Vec<i32> = (0..24).map(|_| rng.below(128) as i32).collect();
            srv.submit_score(ScoreRequest { id: 100 + id, tokens }).unwrap();
        }
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 16);
        assert_eq!(srv.take_score_results().len(), 4);
        let s = &srv.stats;
        // every executed engine work unit: decode batches + prefill
        // chunks + scoring chunks + scoring tails (one per request)
        let units = s.steps + s.prefill_chunks + s.score_chunks + s.score_requests;
        (s.steps, s.tokens_processed, s.prefill_chunks, units)
    };
    // warm once, then time full serves
    let (steps, toks, chunks, units) = serve();
    println!("  one serve: {steps} decode steps, {toks} decode rows, {chunks} prefill chunks");
    let r = bench("serve 16 gen + 4 score", 0.3, || {
        std::hint::black_box(serve());
    });
    let per_unit_us = r.secs.mean / units as f64 * 1e6;
    println!(
        "  ~{per_unit_us:.1} us per engine work unit ({units} units = decode batches + prefill/score chunks + score tails)"
    );
}
