//! L3 coordinator micro-benches: the serving hot path must not be the
//! bottleneck (DESIGN.md §9 L3 target). Measures batcher planning, queue
//! ops, state-pool alloc/release, and the gather/scatter of per-sequence
//! Fenwick state stacks into batched buffers — everything around the
//! PJRT execute call.
//!
//! Run: `cargo bench --bench coordinator`

use std::time::Duration;

use loglinear::bench::{bench, section};
use loglinear::coordinator::batcher::{BatchPolicy, RequestQueue};
use loglinear::state::pool::StatePool;
use loglinear::util::Rng;

fn main() {
    section("batcher planning (pure logic)");
    let policy = BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(2));
    bench("plan x1000", 0.2, || {
        for ready in 0..1000usize {
            std::hint::black_box(policy.plan(ready % 17, Duration::from_millis((ready % 5) as u64)));
        }
    });

    section("request queue push/take");
    bench("queue 1024 push + take", 0.2, || {
        let mut q = RequestQueue::new();
        for i in 0..1024u32 {
            q.push(i);
        }
        while !q.is_empty() {
            std::hint::black_box(q.take(8));
        }
    });

    section("state pool alloc/release (dk*dv = 1024 floats)");
    bench("pool churn x1024", 0.2, || {
        let mut pool = StatePool::new(1024, 64);
        let mut live = Vec::new();
        let mut rng = Rng::new(1);
        for _ in 0..1024 {
            if !live.is_empty() && rng.chance(0.5) {
                let i = rng.below(live.len());
                let id = live.swap_remove(i);
                pool.release(id);
            } else if let Some(id) = pool.alloc() {
                live.push(id);
            }
        }
        for id in live {
            pool.release(id);
        }
    });

    section("state gather/scatter (8 seqs x 4 layers x (9,2,16,32) stacks)");
    // mirrors DecodeServer::step's memory movement around the execute call
    let numel = 9 * 2 * 16 * 32;
    let layers = 4;
    let batch = 8;
    let seq_states: Vec<Vec<Vec<f32>>> = (0..batch)
        .map(|_| (0..layers).map(|_| vec![1.0f32; numel]).collect())
        .collect();
    bench("gather+scatter", 0.3, || {
        let mut batched: Vec<Vec<f32>> = (0..layers).map(|_| vec![0.0f32; batch * numel]).collect();
        for (i, seq) in seq_states.iter().enumerate() {
            for (l, st) in seq.iter().enumerate() {
                batched[l][i * numel..(i + 1) * numel].copy_from_slice(st);
            }
        }
        std::hint::black_box(&batched);
        // scatter back
        let mut out = seq_states.clone();
        for (i, seq) in out.iter_mut().enumerate() {
            for (l, st) in seq.iter_mut().enumerate() {
                st.copy_from_slice(&batched[l][i * numel..(i + 1) * numel]);
            }
        }
        std::hint::black_box(&out);
    });

    println!(
        "\n  (for end-to-end step latency incl. PJRT execute, run\n   `loglinear serve-demo` or `cargo run --release --example serve`)"
    );
}
