//! E3 — Table 1 reproduction: empirically fit the training-time and
//! decoding time/space complexity of every model row and check each
//! against the paper's claimed asymptotics.
//!
//! Method: measure runtime at geometrically spaced T, fit the log-log
//! slope. Decode: measure per-step time and resident state at step t.
//!
//! Run: `cargo bench --bench table1_complexity [-- --quick]`
//!
//! Emits `BENCH_table1.json`: per-model training points (T, ns/token),
//! fitted scaling exponent, and the decode-time rows — so future PRs can
//! track the perf trajectory mechanically.

use loglinear::attention::{self, forward, AttnInputs, Form, Model};
use loglinear::bench::section;
use loglinear::state::{FenwickState, Transition};
use loglinear::tensor::Mat;
use loglinear::util::json::Json;
use loglinear::util::stats::{sample_times, scaling_exponent, Summary};
use loglinear::util::Rng;

const OUT_PATH: &str = "BENCH_table1.json";

fn main() {
    let quick = std::env::args().skip(1).any(|a| a == "--quick");
    let (dk, dv, c) = (32, 32, 32);
    let lens: Vec<usize> = if quick {
        vec![128, 256, 512, 1024]
    } else {
        vec![256, 512, 1024, 2048, 4096]
    };
    let softmax_cap = if quick { 1024 } else { 2048 };
    let t_decode = if quick { 4096usize } else { 16_384usize };

    section("Table 1: training-time scaling (fit of runtime ~ T^p)");
    println!(
        "{:<22} {:>10} {:>12} {:>10}",
        "model (chunkwise)", "fit T^p", "paper", "verdict"
    );
    let cases: Vec<(Model, &str, f64)> = vec![
        (Model::Softmax, "O(T^2)", 2.0),
        (Model::Linear, "O(T)", 1.0),
        (Model::Mamba2, "O(T)", 1.0),
        (Model::GatedDeltaNet, "O(T)", 1.0),
        (Model::LogLinearMamba2, "O(T log T)", 1.0), // slope ~1.0-1.3
        (Model::LogLinearGdn, "O(T log T)", 1.0),
    ];
    let mut train_rows = Vec::new();
    for (model, paper, expect) in cases {
        let mut ts = Vec::new();
        let mut times = Vec::new();
        let mut points = Vec::new();
        for &t in &lens {
            // keep the quadratic baseline affordable
            if model == Model::Softmax && t > softmax_cap {
                continue;
            }
            let mut rng = Rng::new(t as u64);
            let x = AttnInputs::random(t, dk, dv, &mut rng);
            let form = if model == Model::Softmax { Form::Parallel } else { Form::Chunkwise(c) };
            let samples = sample_times(1, 3, || {
                std::hint::black_box(forward(model, form, &x));
            });
            let p50 = Summary::of(&samples).p50;
            ts.push(t);
            times.push(p50);
            points.push(
                Json::obj()
                    .set("T", t)
                    .set("secs", p50)
                    .set("ns_per_token", p50 * 1e9 / t as f64),
            );
        }
        let p = scaling_exponent(&ts, &times);
        // log-linear shows as slope slightly above 1; quadratic ~2
        let ok = (p - expect).abs() < 0.45;
        println!(
            "{:<22} {:>10.2} {:>12} {:>10}",
            model.name(),
            p,
            paper,
            if ok { "matches" } else { "CHECK" }
        );
        train_rows.push(
            Json::obj()
                .set("model", model.name())
                .set("fit_exponent", p)
                .set("paper", paper)
                .set("matches", ok)
                .set("points", Json::Arr(points)),
        );
    }

    section(&format!("Table 1: decoding time per step & state memory at T = {t_decode}"));
    println!(
        "{:<22} {:>14} {:>16} {:>12}",
        "model", "us/step@T", "state bytes", "paper space"
    );
    let mut rng = Rng::new(9);
    let x = AttnInputs::random(1024, dk, dv, &mut rng);
    let mut decode_rows = Vec::new();
    let mut push_decode = |model: &str, us_per_step: f64, state_bytes: usize, paper: &str| {
        println!("{model:<22} {us_per_step:>14.1} {state_bytes:>16} {paper:>12}");
        decode_rows.push(
            Json::obj()
                .set("model", model)
                .set("us_per_step", us_per_step)
                .set("state_bytes", state_bytes)
                .set("paper_space", paper),
        );
    };

    // softmax: KV-cache decode, measure at a few depths then extrapolate slope
    {
        let depth = t_decode / 2;
        let mut kv = attention::softmax::KvCacheDecoder::new(dk);
        let mut step_times = Vec::new();
        for t in 0..depth {
            let i = t % 1024;
            let t0 = std::time::Instant::now();
            kv.step(x.q.row(i), x.k.row(i), x.v.row(i));
            if t >= depth - 192 {
                step_times.push(t0.elapsed().as_secs_f64());
            }
        }
        let mean = Summary::of(&step_times).p50;
        // per-step cost is linear in t; extrapolate to full depth
        push_decode(
            "softmax (KV cache)",
            mean * 1e6 * (t_decode as f64 / depth as f64),
            t_decode * (dk + dv) * 4,
            "O(T)",
        );
    }
    // mamba2: constant state
    {
        let mut s = Mat::zeros(dk, dv);
        let times = sample_times(100, 2000, || {
            s.scale_inplace(0.99);
            loglinear::tensor::outer_acc(&mut s, x.k.row(0), x.v.row(0), 1.0);
            std::hint::black_box(s.matvec_t(x.q.row(0)));
        });
        push_decode("mamba2", Summary::of(&times).p50 * 1e6, dk * dv * 4, "O(1)");
    }
    // log-linear: Fenwick states at full decode depth
    {
        let mut st = FenwickState::new(dk, dv);
        let lambda = vec![1.0f32; 20];
        let mut step_times = Vec::new();
        for t in 0..t_decode {
            let i = t % 1024;
            let t0 = std::time::Instant::now();
            st.step(x.q.row(i), x.k.row(i), x.v.row(i), 1.0, Transition::Decay(0.99), &lambda);
            if t >= t_decode - 2000 {
                step_times.push(t0.elapsed().as_secs_f64());
            }
        }
        push_decode(
            "loglinear_mamba2",
            Summary::of(&step_times).p50 * 1e6,
            st.state_bytes(),
            "O(log T)",
        );
    }
    // log-linear GDN
    {
        let mut st = FenwickState::new(dk, dv);
        let lambda = vec![1.0f32; 20];
        let mut step_times = Vec::new();
        for t in 0..t_decode {
            let i = t % 1024;
            let t0 = std::time::Instant::now();
            st.step(
                x.q.row(i),
                x.k.row(i),
                x.v.row(i),
                0.8,
                Transition::GatedHouseholder { alpha: 0.99, beta: 0.8, k: x.k.row(i) },
                &lambda,
            );
            if t >= t_decode - 2000 {
                step_times.push(t0.elapsed().as_secs_f64());
            }
        }
        push_decode(
            "loglinear_gdn",
            Summary::of(&step_times).p50 * 1e6,
            st.state_bytes(),
            "O(log T)",
        );
    }

    let doc = Json::obj()
        .set("bench", "table1_complexity")
        .set("quick", quick)
        .set("dk", dk)
        .set("dv", dv)
        .set("chunk", c)
        .set("decode_depth", t_decode)
        .set("training", Json::Arr(train_rows))
        .set("decode", Json::Arr(decode_rows));
    match std::fs::write(OUT_PATH, doc.pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH}"),
        Err(e) => eprintln!("\nfailed to write {OUT_PATH}: {e}"),
    }
}
