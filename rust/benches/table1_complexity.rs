//! E3 — Table 1 reproduction: empirically fit the training-time and
//! decoding time/space complexity of every model row and check each
//! against the paper's claimed asymptotics.
//!
//! Method: measure runtime at geometrically spaced T, fit the log-log
//! slope. Decode: measure per-step time and resident state at step t.
//!
//! Run: `cargo bench --bench table1_complexity`

use loglinear::attention::{self, forward, AttnInputs, Form, Model};
use loglinear::bench::section;
use loglinear::state::{FenwickState, Transition};
use loglinear::tensor::Mat;
use loglinear::util::stats::{sample_times, scaling_exponent, Summary};
use loglinear::util::Rng;

fn main() {
    let (dk, dv, c) = (32, 32, 32);
    let lens = [256usize, 512, 1024, 2048, 4096];

    section("Table 1: training-time scaling (fit of runtime ~ T^p)");
    println!(
        "{:<22} {:>10} {:>12} {:>10}",
        "model (chunkwise)", "fit T^p", "paper", "verdict"
    );
    let cases: Vec<(Model, &str, f64)> = vec![
        (Model::Softmax, "O(T^2)", 2.0),
        (Model::Linear, "O(T)", 1.0),
        (Model::Mamba2, "O(T)", 1.0),
        (Model::GatedDeltaNet, "O(T)", 1.0),
        (Model::LogLinearMamba2, "O(T log T)", 1.0), // slope ~1.0-1.3
        (Model::LogLinearGdn, "O(T log T)", 1.0),
    ];
    for (model, paper, expect) in cases {
        let mut ts = Vec::new();
        let mut times = Vec::new();
        for &t in &lens {
            // keep the quadratic baseline affordable
            if model == Model::Softmax && t > 2048 {
                continue;
            }
            let mut rng = Rng::new(t as u64);
            let x = AttnInputs::random(t, dk, dv, &mut rng);
            let form = if model == Model::Softmax { Form::Parallel } else { Form::Chunkwise(c) };
            let samples = sample_times(1, 3, || {
                std::hint::black_box(forward(model, form, &x));
            });
            ts.push(t);
            times.push(Summary::of(&samples).p50);
        }
        let p = scaling_exponent(&ts, &times);
        // log-linear shows as slope slightly above 1; quadratic ~2
        let ok = (p - expect).abs() < 0.45;
        println!(
            "{:<22} {:>10.2} {:>12} {:>10}",
            model.name(),
            p,
            paper,
            if ok { "matches" } else { "CHECK" }
        );
    }

    section("Table 1: decoding time per step & state memory at T = 16384");
    let t_decode = 16_384usize;
    println!(
        "{:<22} {:>14} {:>16} {:>12}",
        "model", "us/step@T", "state bytes", "paper space"
    );
    let mut rng = Rng::new(9);
    let x = AttnInputs::random(1024, dk, dv, &mut rng);

    // softmax: KV-cache decode, measure at a few depths then extrapolate slope
    {
        let mut kv = attention::softmax::KvCacheDecoder::new(dk);
        let mut step_times = Vec::new();
        for t in 0..8192 {
            let i = t % 1024;
            let t0 = std::time::Instant::now();
            kv.step(x.q.row(i), x.k.row(i), x.v.row(i));
            if t >= 8000 {
                step_times.push(t0.elapsed().as_secs_f64());
            }
        }
        let mean = Summary::of(&step_times).p50;
        // per-step cost is linear in t; extrapolate to 16K
        println!(
            "{:<22} {:>14.1} {:>16} {:>12}",
            "softmax (KV cache)",
            mean * 1e6 * (t_decode as f64 / 8192.0),
            t_decode * (dk + dv) * 4,
            "O(T)"
        );
    }
    // mamba2: constant state
    {
        let mut s = Mat::zeros(dk, dv);
        let times = sample_times(100, 2000, || {
            s.scale_inplace(0.99);
            loglinear::tensor::outer_acc(&mut s, x.k.row(0), x.v.row(0), 1.0);
            std::hint::black_box(s.matvec_t(x.q.row(0)));
        });
        println!(
            "{:<22} {:>14.1} {:>16} {:>12}",
            "mamba2",
            Summary::of(&times).p50 * 1e6,
            dk * dv * 4,
            "O(1)"
        );
    }
    // log-linear: Fenwick states at depth 16K
    {
        let mut st = FenwickState::new(dk, dv);
        let lambda = vec![1.0f32; 20];
        let mut step_times = Vec::new();
        for t in 0..t_decode {
            let i = t % 1024;
            let t0 = std::time::Instant::now();
            st.step(x.q.row(i), x.k.row(i), x.v.row(i), 1.0, Transition::Decay(0.99), &lambda);
            if t >= t_decode - 2000 {
                step_times.push(t0.elapsed().as_secs_f64());
            }
        }
        println!(
            "{:<22} {:>14.1} {:>16} {:>12}",
            "loglinear_mamba2",
            Summary::of(&step_times).p50 * 1e6,
            st.state_bytes(),
            "O(log T)"
        );
    }
    // log-linear GDN
    {
        let mut st = FenwickState::new(dk, dv);
        let lambda = vec![1.0f32; 20];
        let mut step_times = Vec::new();
        for t in 0..t_decode {
            let i = t % 1024;
            let t0 = std::time::Instant::now();
            st.step(
                x.q.row(i),
                x.k.row(i),
                x.v.row(i),
                0.8,
                Transition::GatedHouseholder { alpha: 0.99, beta: 0.8, k: x.k.row(i) },
                &lambda,
            );
            if t >= t_decode - 2000 {
                step_times.push(t0.elapsed().as_secs_f64());
            }
        }
        println!(
            "{:<22} {:>14.1} {:>16} {:>12}",
            "loglinear_gdn",
            Summary::of(&step_times).p50 * 1e6,
            st.state_bytes(),
            "O(log T)"
        );
    }
}
