//! Decode-side batched Fenwick passes (the serving analogue of Fig. 4's
//! level fusion), both halves of a pooled decode step:
//!
//! - **read**: per-step read cost for a batch of sequences at mixed
//!   positions, per-sequence matvec loop vs the pooled
//!   [`BatchedDecoder`](loglinear::state::pooled::BatchedDecoder) that
//!   folds every live level of every sequence into one λ-weighted
//!   block-sparse GEMM over the state-pool slab;
//! - **advance**: per-step state-update cost, the per-sequence
//!   `PooledFenwickState::advance` loop vs the pool-wide
//!   [`BatchedAdvance`](loglinear::state::BatchedAdvance) pass that
//!   groups every sequence's merge/transition/sentinel-write by Fenwick
//!   level and runs the per-block work as one scattered-slab dispatch
//!   (mixed Mamba-2 + GDN transitions across the bucket);
//! - **sharded step**: one full `PooledBackend::step` bucket over the
//!   shard count × layer-pipelining grid (docs/SHARDING.md) — every
//!   cell asserted bit-identical to the single-shard baseline before
//!   timing, `shard_speedup_vs_single` recorded per cell.
//!
//! Run: `cargo bench --bench decode_batched [-- --quick] [--threads N]`
//!
//! Emits `BENCH_decode.json` (per-batch ns/token for all four paths, the
//! batched/per-seq speedups — headline `advance_speedup_vs_per_seq` —
//! Σ live blocks, GEMM thread count) in the style of `BENCH_fig4.json`:
//! if a previous record exists its points are carried along as
//! `previous_ns_per_token` with a `speedup_vs_previous` table, so
//! before/after trajectories of engine changes are recorded. Every
//! batched path is asserted bit-exact against its per-sequence
//! counterpart before timing.

use loglinear::bench::{bench, section};
use loglinear::coordinator::backend::{DecodeBackend, PooledBackend, SeqSlot, TransitionKind};
use loglinear::state::pool::{Precision, StatePool};
use loglinear::state::pooled::{BatchedDecoder, PooledFenwickState};
use loglinear::state::{AdvanceJob, BatchedAdvance, FenwickState, Transition};
use loglinear::tensor;
use loglinear::util::json::Json;
use loglinear::util::Rng;

const OUT_PATH: &str = "BENCH_decode.json";

/// A/B the batched read path with the SIMD microkernels forced off vs the
/// runtime-dispatched kernels (docs/PRECISION.md). The two modes must be
/// bit-identical *before* anything is timed — the SIMD kernels are drop-in
/// replacements, not approximations — so the speedup is pure substrate.
/// Returns `(simd_speedup_vs_scalar, dispatch_mode)`.
#[cfg(feature = "simd")]
fn simd_read_ab(b: usize, dk: usize, dv: usize, base_pos: usize) -> (f64, &'static str) {
    use loglinear::tensor::simd;
    let mode = if simd::runtime_available() { "avx2" } else { "portable" };
    let fx = build(b, dk, dv, base_pos);
    let mut dec = BatchedDecoder::new();
    let refs: Vec<&PooledFenwickState> = fx.pooled.iter().collect();
    let lambdas: Vec<&[f32]> = vec![&fx.lambda[..]; b];
    let (mut got_scalar, mut got_simd) = (vec![0.0f32; b * dv], vec![0.0f32; b * dv]);
    simd::set_forced_scalar(true);
    dec.read_batch(&fx.pool, &refs, &fx.qs, &lambdas, &mut got_scalar);
    simd::set_forced_scalar(false);
    dec.read_batch(&fx.pool, &refs, &fx.qs, &lambdas, &mut got_simd);
    for (i, (a, c)) in got_scalar.iter().zip(&got_simd).enumerate() {
        assert_eq!(
            a.to_bits(),
            c.to_bits(),
            "SIMD read diverged from the scalar oracle (B={b}, elem {i})"
        );
    }
    simd::set_forced_scalar(true);
    let r_scalar = bench(&format!("forced-scalar batched read/B={b}"), 0.25, || {
        dec.read_batch(&fx.pool, &refs, &fx.qs, &lambdas, &mut got_scalar);
        std::hint::black_box(&got_scalar);
    });
    simd::set_forced_scalar(false);
    let r_simd = bench(&format!("dispatched batched read/B={b} ({mode})"), 0.25, || {
        dec.read_batch(&fx.pool, &refs, &fx.qs, &lambdas, &mut got_simd);
        std::hint::black_box(&got_simd);
    });
    (r_scalar.secs.mean / r_simd.secs.mean, mode)
}

#[cfg(not(feature = "simd"))]
fn simd_read_ab(_b: usize, _dk: usize, _dv: usize, _base_pos: usize) -> (f64, &'static str) {
    println!("  simd feature disabled: the scalar kernels are the only path; speedup is 1.0");
    (1.0, "off")
}

/// One batch's fixture: the same sequences held twice — as Mat-backed
/// `FenwickState`s (the per-sequence matvec-loop baseline) and as
/// pool-backed `PooledFenwickState`s (the batched path) — advanced to
/// mixed positions with a shared trace.
struct Fixture {
    plain: Vec<FenwickState>,
    pooled: Vec<PooledFenwickState>,
    pool: StatePool,
    qs: Vec<f32>,
    lambda: Vec<f32>,
}

fn build(batch: usize, dk: usize, dv: usize, base_pos: usize) -> Fixture {
    let mut rng = Rng::new(0xDEC0DE + batch as u64);
    let lambda: Vec<f32> = (0..24).map(|l| 1.0 / (l as f32 + 1.0)).collect();
    let mut pool = StatePool::new(dk * dv, batch * 16);
    let mut plain = Vec::new();
    let mut pooled = Vec::new();
    for i in 0..batch {
        let mut fs = FenwickState::new(dk, dv);
        let mut ps = PooledFenwickState::new(dk, dv);
        let steps = base_pos + 137 * i; // mixed positions across the batch
        for _ in 0..steps {
            let k: Vec<f32> = (0..dk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..dv).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            fs.step(&k, &k, &v, 1.0, Transition::Decay(0.999), &lambda);
            ps.advance(&mut pool, &k, &v, 1.0, Transition::Decay(0.999))
                .expect("pool sized for the trace");
        }
        plain.push(fs);
        pooled.push(ps);
    }
    let qs: Vec<f32> = (0..batch * dk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    Fixture { plain, pooled, pool, qs, lambda }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    if let Some(pos) = args.iter().position(|a| a == "--threads") {
        if let Some(n) = args.get(pos + 1).and_then(|s| s.parse::<usize>().ok()) {
            tensor::gemm_threads(n);
        }
    }

    let (dk, dv) = (64, 64);
    let base_pos = 700; // ~6 live levels per sequence
    let batches: Vec<usize> = if quick { vec![1, 2, 4, 8] } else { vec![1, 2, 4, 8, 16, 32] };

    section(&format!(
        "decode read path: per-seq matvec loop vs batched pool GEMM (dk=dv={dk}, mixed positions, gemm_threads={})",
        tensor::current_gemm_threads()
    ));

    // rows: (path, batch, secs_per_step, sum_live)
    let mut rows: Vec<(String, usize, f64, usize)> = Vec::new();
    for &b in &batches {
        let mut fx = build(b, dk, dv, base_pos);
        let sum_live: usize = fx.pooled.iter().map(|s| s.live_states()).sum();

        // correctness first: the two paths must agree bit-for-bit
        let mut want = vec![0.0f32; b * dv];
        for i in 0..b {
            let q = &fx.qs[i * dk..(i + 1) * dk];
            fx.plain[i].read_into(q, &fx.lambda, &mut want[i * dv..(i + 1) * dv]);
        }
        let mut dec = BatchedDecoder::new();
        let mut got = vec![0.0f32; b * dv];
        {
            let refs: Vec<&PooledFenwickState> = fx.pooled.iter().collect();
            let lambdas: Vec<&[f32]> = vec![&fx.lambda[..]; b];
            dec.read_batch(&fx.pool, &refs, &fx.qs, &lambdas, &mut got);
        }
        assert_eq!(got, want, "batched read diverged from per-sequence oracle (B={b})");

        let r = bench(&format!("per-seq matvec loop/B={b} (Σlive={sum_live})"), 0.25, || {
            for i in 0..b {
                let q = &fx.qs[i * dk..(i + 1) * dk];
                fx.plain[i].read_into(q, &fx.lambda, &mut want[i * dv..(i + 1) * dv]);
            }
            std::hint::black_box(&want);
        });
        rows.push(("per_seq".into(), b, r.secs.mean, sum_live));

        let refs: Vec<&PooledFenwickState> = fx.pooled.iter().collect();
        let lambdas: Vec<&[f32]> = vec![&fx.lambda[..]; b];
        let r = bench(&format!("batched pool read/B={b} (Σlive={sum_live})"), 0.25, || {
            dec.read_batch(&fx.pool, &refs, &fx.qs, &lambdas, &mut got);
            std::hint::black_box(&got);
        });
        rows.push(("batched".into(), b, r.secs.mean, sum_live));
    }

    // ---- advance path: per-sequence loop vs pool-wide batched pass ----
    section(&format!(
        "decode advance path: per-seq advance loop vs pool-wide batched pass (dk=dv={dk}, mixed Mamba-2/GDN, gemm_threads={})",
        tensor::current_gemm_threads()
    ));
    for &b in &batches {
        // twin pooled fixtures at the same mixed positions; pools sized
        // for any step count a timed run can reach (t < 2^33)
        let blocks = b * 34;
        let mut pool_a = StatePool::new(dk * dv, blocks);
        let mut pool_b = StatePool::new(dk * dv, blocks);
        let mut rng = Rng::new(0xADFACE + b as u64);
        // normalized keys keep the GDN Householder transitions contractive
        let ks: Vec<Vec<f32>> = (0..b)
            .map(|_| {
                let mut k: Vec<f32> = (0..dk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let n = loglinear::tensor::ops::l2_norm(&k).max(1e-6);
                k.iter_mut().for_each(|x| *x /= n);
                k
            })
            .collect();
        let vs: Vec<Vec<f32>> = (0..b)
            .map(|_| (0..dv).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let mut seqs_a: Vec<PooledFenwickState> = Vec::new();
        let mut seqs_b: Vec<PooledFenwickState> = Vec::new();
        for i in 0..b {
            let mut sa = PooledFenwickState::new(dk, dv);
            let mut sb = PooledFenwickState::new(dk, dv);
            for _ in 0..base_pos + 137 * i {
                sa.advance(&mut pool_a, &ks[i], &vs[i], 1.0, Transition::Decay(0.999))
                    .expect("pool sized for the trace");
                sb.advance(&mut pool_b, &ks[i], &vs[i], 1.0, Transition::Decay(0.999))
                    .expect("pool sized for the trace");
            }
            seqs_a.push(sa);
            seqs_b.push(sb);
        }
        // mixed transition families across the bucket, as in serving
        let job = |i: usize| {
            if i % 2 == 0 {
                (1.0, Transition::Decay(0.999))
            } else {
                (0.5, Transition::GatedHouseholder { alpha: 0.999, beta: 0.5, k: &ks[i] })
            }
        };
        let jobs: Vec<AdvanceJob<'_>> = (0..b)
            .map(|i| {
                let (write_scale, transition) = job(i);
                AdvanceJob { k: &ks[i], v: &vs[i], write_scale, transition }
            })
            .collect();
        let mut adv = BatchedAdvance::new();
        // correctness first: one batched round must be bit-exact with the
        // per-sequence loop (states AND pool occupancy)
        {
            for (i, sa) in seqs_a.iter_mut().enumerate() {
                let (ws, tr) = job(i);
                sa.advance(&mut pool_a, &ks[i], &vs[i], ws, tr).unwrap();
            }
            let mut refs: Vec<&mut PooledFenwickState> = seqs_b.iter_mut().collect();
            let refused = adv.advance_bucket(&mut pool_b, &mut refs, &jobs);
            assert!(refused.is_empty(), "pool sized for the trace (B={b})");
            assert_eq!(pool_a.in_use(), pool_b.in_use(), "occupancy diverged (B={b})");
            let q: Vec<f32> = (0..dk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let lambda: Vec<f32> = (0..24).map(|l| 1.0 / (l as f32 + 1.0)).collect();
            let (mut oa, mut ob) = (vec![0.0f32; dv], vec![0.0f32; dv]);
            for i in 0..b {
                seqs_a[i].read_into(&pool_a, &q, &lambda, &mut oa);
                seqs_b[i].read_into(&pool_b, &q, &lambda, &mut ob);
                assert_eq!(oa, ob, "batched advance diverged from per-seq loop (B={b} seq {i})");
            }
        }
        let sum_live: usize = seqs_a.iter().map(|s| s.live_states()).sum();
        let r = bench(&format!("per-seq advance loop/B={b} (Σlive={sum_live})"), 0.25, || {
            for (i, sa) in seqs_a.iter_mut().enumerate() {
                let (ws, tr) = job(i);
                sa.advance(&mut pool_a, &ks[i], &vs[i], ws, tr).expect("pool sized for the trace");
            }
        });
        rows.push(("advance_per_seq".into(), b, r.secs.mean, sum_live));
        let mut refs: Vec<&mut PooledFenwickState> = seqs_b.iter_mut().collect();
        let r = bench(&format!("batched pool advance/B={b} (Σlive={sum_live})"), 0.25, || {
            let refused = adv.advance_bucket(&mut pool_b, &mut refs, &jobs);
            debug_assert!(refused.is_empty());
            std::hint::black_box(&refused);
        });
        rows.push(("advance_batched".into(), b, r.secs.mean, sum_live));
    }

    // ---- sharded serving step: shard count × pipelining grid ----------
    // Serving-shaped workload: a 3-layer × 2-head PooledBackend stepped
    // as one decode bucket, over every shard count × pipelining cell.
    // Each cell feeds the same deterministic token stream, so the first
    // CHECK steps' logits must be bit-identical to the single-shard
    // non-pipelined baseline *before* anything is timed — the same bar
    // the trace harness holds (docs/SHARDING.md).
    const SHARD_VOCAB: usize = 64;
    let (sl, sh, sdk) = (3usize, 2usize, 32usize);
    let shard_b = if quick { 4 } else { 8 };
    const CHECK: usize = 4;
    section(&format!(
        "sharded decode step: shards x pipelining (L={sl}, H={sh}, dk=dv={sdk}, B={shard_b}, gemm_threads={})",
        tensor::current_gemm_threads()
    ));
    let tok = |i: usize, t: usize| ((i * 7 + t * 13 + 5) % SHARD_VOCAB) as i32;
    let grid: [(usize, bool); 6] =
        [(1, false), (1, true), (2, false), (2, true), (4, false), (4, true)];
    let mut baseline: Vec<Vec<f32>> = Vec::new();
    let mut shard_rows: Vec<(usize, bool, f64)> = Vec::new();
    for &(shards, pipelined) in &grid {
        // pools sized for any step count a timed run can reach
        // (t < 2^33 => 34 blocks per head per layer), split evenly so
        // every grid cell has the same aggregate capacity
        let per_seq = sl * sh * 34;
        let per_shard = (shard_b / shards) * per_seq;
        let mut backend = PooledBackend::with_model_config(
            SHARD_VOCAB,
            sl,
            sh,
            TransitionKind::Mamba2,
            sdk,
            sdk,
            0,
            per_shard * shards,
            0x5AADED,
        );
        backend.set_shards(shards);
        backend.set_pipelined(pipelined);
        let slots: Vec<SeqSlot> = (0..shard_b)
            .map(|_| backend.admit_prompt(1usize << 33, &[]).expect("pool sized for the grid").0)
            .collect();
        let step_rows = |backend: &mut PooledBackend, pos: usize| {
            let batch: Vec<(SeqSlot, i32, i32)> = slots
                .iter()
                .enumerate()
                .map(|(i, &s)| (s, tok(i, pos), pos as i32))
                .collect();
            backend.step(shard_b, &batch).expect("pool sized for the grid")
        };
        let mut pos = 0usize;
        for _ in 0..CHECK {
            let logits = step_rows(&mut backend, pos);
            if shards == 1 && !pipelined {
                baseline.push(logits);
            } else {
                assert_eq!(
                    logits, baseline[pos],
                    "sharded step diverged from the single-shard baseline \
                     (shards={shards}, pipelined={pipelined}, step {pos})"
                );
            }
            pos += 1;
        }
        let r = bench(
            &format!("pooled step/shards={shards} pipelined={pipelined} B={shard_b}"),
            0.25,
            || {
                std::hint::black_box(step_rows(&mut backend, pos));
                pos += 1;
            },
        );
        shard_rows.push((shards, pipelined, r.secs.mean));
    }

    // ---- SIMD microkernels: forced-scalar vs dispatched A/B -----------
    section("SIMD microkernels: forced-scalar vs dispatched batched read — simd_speedup_vs_scalar");
    let simd_b = *batches.last().unwrap();
    let (simd_speedup_vs_scalar, simd_mode) = simd_read_ab(simd_b, dk, dv, base_pos);
    println!("  dispatch mode: {simd_mode}  simd_speedup_vs_scalar: {simd_speedup_vs_scalar:.2}x");

    // ---- bf16 state slab: bytes/seq and pooled-read tolerance ---------
    // Twin fixtures advanced through the identical mixed Mamba-2/GDN
    // trace, one pool per precision. The bf16 slab halves the resident
    // bytes per sequence (asserted >= 1.9x below; the pool stores blocks
    // at 2 bytes/elem) while reads stay within the documented tolerance
    // of the f32 oracle (docs/PRECISION.md).
    section("bf16 state slab: state_bytes_per_seq and read tolerance vs f32");
    let bf16_b = if quick { 4 } else { 8 };
    let (f32_bytes_per_seq, bf16_bytes_per_seq, bf16_reduction, bf16_worst_rel) = {
        let mut rng = Rng::new(0xB16B00);
        let lambda: Vec<f32> = (0..24).map(|l| 1.0 / (l as f32 + 1.0)).collect();
        let cap = bf16_b * 16;
        let mut pool_f = StatePool::new(dk * dv, cap);
        let mut pool_h = StatePool::with_precision(dk * dv, cap, Precision::Bf16);
        let mut seqs_f: Vec<PooledFenwickState> = Vec::new();
        let mut seqs_h: Vec<PooledFenwickState> = Vec::new();
        for i in 0..bf16_b {
            let mut k: Vec<f32> = (0..dk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let n = loglinear::tensor::ops::l2_norm(&k).max(1e-6);
            k.iter_mut().for_each(|x| *x /= n);
            let v: Vec<f32> = (0..dv).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut sf = PooledFenwickState::new(dk, dv);
            let mut sh = PooledFenwickState::new(dk, dv);
            for t in 0..base_pos + 137 * i {
                let (ws, tr) = if t % 2 == 0 {
                    (1.0, Transition::Decay(0.999))
                } else {
                    (0.5, Transition::GatedHouseholder { alpha: 0.999, beta: 0.5, k: &k })
                };
                sf.advance(&mut pool_f, &k, &v, ws, tr).expect("pool sized for the trace");
                sh.advance(&mut pool_h, &k, &v, ws, tr).expect("pool sized for the trace");
            }
            seqs_f.push(sf);
            seqs_h.push(sh);
        }
        let q: Vec<f32> = (0..dk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let (mut of, mut oh) = (vec![0.0f32; dv], vec![0.0f32; dv]);
        let mut worst_rel = 0.0f32;
        for i in 0..bf16_b {
            seqs_f[i].read_into(&pool_f, &q, &lambda, &mut of);
            seqs_h[i].read_into(&pool_h, &q, &lambda, &mut oh);
            for (a, c) in of.iter().zip(&oh) {
                let rel = (a - c).abs() / (1.0 + a.abs());
                assert!(
                    rel <= 0.05,
                    "bf16 pooled read outside tolerance (seq {i}: rel {rel:.4})"
                );
                worst_rel = worst_rel.max(rel);
            }
        }
        let bytes_f = pool_f.in_use() * pool_f.bytes_per_block();
        let bytes_h = pool_h.in_use() * pool_h.bytes_per_block();
        assert_eq!(pool_f.in_use(), pool_h.in_use(), "precision changed pool occupancy");
        let per_f = bytes_f as f64 / bf16_b as f64;
        let per_h = bytes_h as f64 / bf16_b as f64;
        let reduction = per_f / per_h;
        assert!(
            reduction >= 1.9,
            "bf16 slab must cut state bytes/seq by >= 1.9x (got {reduction:.2}x)"
        );
        println!(
            "  state_bytes_per_seq: f32 {per_f:.0} B  bf16 {per_h:.0} B  \
             reduction {reduction:.2}x  worst read rel err {worst_rel:.2e}"
        );
        (per_f, per_h, reduction, worst_rel)
    };

    section("ns per sequence-token (read path) and batched speedup");
    println!("{:>6} {:>16} {:>16} {:>10}", "B", "per-seq ns/tok", "batched ns/tok", "speedup");
    let mut speedup_rows: Vec<(usize, f64, f64, f64)> = Vec::new();
    for &b in &batches {
        let get = |path: &str| {
            rows.iter()
                .find(|(p, bb, _, _)| p == path && *bb == b)
                .map(|(_, _, s, _)| *s)
                .unwrap()
        };
        let per_seq = get("per_seq") * 1e9 / b as f64;
        let batched = get("batched") * 1e9 / b as f64;
        let speedup = per_seq / batched;
        println!("{b:>6} {per_seq:>16.1} {batched:>16.1} {speedup:>9.2}x");
        speedup_rows.push((b, per_seq, batched, speedup));
    }

    section("ns per sequence-token (advance path) and batched speedup — the headline");
    println!("{:>6} {:>16} {:>16} {:>10}", "B", "per-seq ns/tok", "batched ns/tok", "speedup");
    let mut adv_speedup_rows: Vec<(usize, f64, f64, f64)> = Vec::new();
    for &b in &batches {
        let get = |path: &str| {
            rows.iter()
                .find(|(p, bb, _, _)| p == path && *bb == b)
                .map(|(_, _, s, _)| *s)
                .unwrap()
        };
        let per_seq = get("advance_per_seq") * 1e9 / b as f64;
        let batched = get("advance_batched") * 1e9 / b as f64;
        let speedup = per_seq / batched;
        println!("{b:>6} {per_seq:>16.1} {batched:>16.1} {speedup:>9.2}x");
        adv_speedup_rows.push((b, per_seq, batched, speedup));
    }

    section("sharded decode step: ns/step per grid cell and speedup vs single shard");
    let single_shard_secs = shard_rows
        .iter()
        .find(|&&(s, p, _)| s == 1 && !p)
        .map(|&(_, _, t)| t)
        .unwrap();
    println!("{:>7} {:>10} {:>14} {:>10}", "shards", "pipelined", "ns/step", "speedup");
    let mut shard_points: Vec<Json> = Vec::new();
    let mut shard_speedups: Vec<Json> = Vec::new();
    for &(shards, pipelined, secs) in &shard_rows {
        let speedup = single_shard_secs / secs;
        println!("{shards:>7} {pipelined:>10} {:>14.0} {speedup:>9.2}x", secs * 1e9);
        shard_points.push(
            Json::obj()
                .set("shards", shards)
                .set("pipelined", pipelined)
                .set("ns_per_step", secs * 1e9)
                .set("ns_per_row", secs * 1e9 / shard_b as f64),
        );
        shard_speedups.push(
            Json::obj()
                .set("shards", shards)
                .set("pipelined", pipelined)
                .set("shard_speedup_vs_single", speedup),
        );
    }

    // ---- machine-readable record (BENCH_decode.json) ----
    let previous = std::fs::read_to_string(OUT_PATH)
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    let prev_ns = |path: &str, b: usize| -> Option<f64> {
        previous
            .as_ref()?
            .get("points")?
            .as_arr()?
            .iter()
            .find(|p| {
                p.get("path").and_then(|s| s.as_str()) == Some(path)
                    && p.get("batch").and_then(|v| v.as_usize()) == Some(b)
            })?
            .get("ns_per_token")?
            .as_f64()
    };

    let mut points = Vec::new();
    let mut prev_speedups = Vec::new();
    for (path, b, secs, sum_live) in &rows {
        let ns_per_token = secs * 1e9 / *b as f64;
        let mut p = Json::obj()
            .set("path", path.as_str())
            .set("batch", *b)
            .set("secs", *secs)
            .set("ns_per_token", ns_per_token)
            .set("sum_live_blocks", *sum_live);
        if let Some(old) = prev_ns(path, *b) {
            p = p.set("previous_ns_per_token", old);
            prev_speedups.push(
                Json::obj()
                    .set("path", path.as_str())
                    .set("batch", *b)
                    .set("speedup", old / ns_per_token),
            );
        }
        points.push(p);
    }
    let batched_speedup: Vec<Json> = speedup_rows
        .iter()
        .map(|(b, _, _, s)| Json::obj().set("batch", *b).set("speedup_vs_per_seq", *s))
        .collect();
    let advance_speedup: Vec<Json> = adv_speedup_rows
        .iter()
        .map(|(b, _, _, s)| Json::obj().set("batch", *b).set("advance_speedup_vs_per_seq", *s))
        .collect();
    let mut doc = Json::obj()
        .set("bench", "decode_batched")
        .set("quick", quick)
        .set("gemm_threads", tensor::current_gemm_threads())
        .set("dk", dk)
        .set("dv", dv)
        .set("base_pos", base_pos)
        .set("points", Json::Arr(points))
        .set("batched_speedup", Json::Arr(batched_speedup))
        .set("advance_speedup_vs_per_seq", Json::Arr(advance_speedup))
        .set("sharded_step", Json::Arr(shard_points))
        .set("shard_speedup_vs_single", Json::Arr(shard_speedups))
        .set("simd_dispatch", simd_mode)
        .set("simd_speedup_vs_scalar", simd_speedup_vs_scalar)
        .set(
            "state_bytes_per_seq",
            Json::obj()
                .set("f32", f32_bytes_per_seq)
                .set("bf16", bf16_bytes_per_seq)
                .set("reduction_vs_f32", bf16_reduction)
                .set("bf16_worst_read_rel_err", bf16_worst_rel as f64),
        );
    if !prev_speedups.is_empty() {
        doc = doc.set("speedup_vs_previous", Json::Arr(prev_speedups));
    }
    match std::fs::write(OUT_PATH, doc.pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH}"),
        Err(e) => eprintln!("\nfailed to write {OUT_PATH}: {e}"),
    }
}
