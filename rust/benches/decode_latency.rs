//! E11 — decode latency/memory growth (the §3.2 claim), measured on the
//! REAL serving engine: per-step decode time and resident state vs
//! context depth, through [`PooledBackend::step`] (pool-backed batched
//! Fenwick advance + batched read + logits GEMM — the exact code
//! `DecodeServer` drives), for a single-layer model and a sequential
//! 2-layer × 2-head stack, against a softmax KV-cache baseline.
//! KV-cache cost grows linearly with depth; the Fenwick engines stay ~log.
//!
//! Run: `cargo bench --bench decode_latency [-- --quick]`

use loglinear::attention::softmax::KvCacheDecoder;
use loglinear::bench::section;
use loglinear::coordinator::backend::{DecodeBackend, PooledBackend, SeqSlot, TransitionKind};
use loglinear::util::stats::Summary;
use loglinear::util::Rng;

fn window_p50_us(samples: &[f64]) -> f64 {
    Summary::of(samples).p50 * 1e6
}

/// One pooled serving sequence stepped to `max_t` depth through the real
/// backend; records per-step seconds.
struct PooledRun {
    backend: PooledBackend,
    slot: SeqSlot,
    times: Vec<f64>,
}

impl PooledRun {
    fn new(layers: usize, heads: usize, dk: usize, max_t: usize) -> PooledRun {
        // chunked prefill off: this bench measures the decode step itself
        let mut backend = PooledBackend::with_model_config(
            128,
            layers,
            heads,
            TransitionKind::Mamba2,
            dk,
            dk,
            0,
            4 * layers * heads * 32,
            0xE11,
        );
        let slot = backend.admit(max_t).expect("pool sized for the run");
        PooledRun { backend, slot, times: Vec::new() }
    }

    fn step(&mut self, tok: i32, pos: usize) {
        let t0 = std::time::Instant::now();
        let logits = self
            .backend
            .step(1, &[(self.slot, tok, pos as i32)])
            .expect("decode step");
        self.times.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(logits);
    }

    fn state_bytes(&self) -> usize {
        self.backend.state_bytes()
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");

    let (dk, dv) = (32usize, 32usize);
    let depths: &[usize] =
        if quick { &[1024, 4096] } else { &[1024, 4096, 16_384, 65_536] };
    let max_t = *depths.last().unwrap();
    let mut rng = Rng::new(3);
    let n_inputs = 2048;
    let qs: Vec<Vec<f32>> = (0..n_inputs)
        .map(|_| (0..dk).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let ks = qs.clone();
    let vs: Vec<Vec<f32>> = (0..n_inputs)
        .map(|_| (0..dv).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();

    section("per-step decode time (us, p50) and state bytes vs context depth");
    println!(
        "{:>8} | {:>12} {:>12} | {:>12} {:>12} | {:>14} {:>12}",
        "depth", "kv us/step", "kv bytes", "pooled L1 us", "L1 bytes", "pooled L2xH2 us", "L2 bytes"
    );

    let mut kv = KvCacheDecoder::new(dk);
    let mut kv_t = Vec::new();
    let kv_cap = 16_384.min(max_t); // KV path becomes the bench's own bottleneck
    let mut l1 = PooledRun::new(1, 1, dk, max_t);
    let mut l2 = PooledRun::new(2, 2, dk, max_t);

    let mut next = 0usize;
    for t in 0..max_t {
        let i = t % n_inputs;
        if t < kv_cap {
            let t0 = std::time::Instant::now();
            kv.step(&qs[i], &ks[i], &vs[i]);
            kv_t.push(t0.elapsed().as_secs_f64());
        }
        let tok = (t % 128) as i32;
        l1.step(tok, t);
        l2.step(tok, t);

        if next < depths.len() && t + 1 == depths[next] {
            let w = 512.min(t + 1);
            let kv_us = if t < kv_cap {
                format!("{:.2}", window_p50_us(&kv_t[kv_t.len() - w.min(kv_t.len())..]))
            } else {
                // linear extrapolation from the last measured window
                format!(
                    "~{:.2}",
                    window_p50_us(&kv_t[kv_t.len() - w.min(kv_t.len())..]) * (t + 1) as f64
                        / kv_cap as f64
                )
            };
            let kv_bytes = if t < kv_cap { kv.state_bytes() } else { (t + 1) * (dk + dv) * 4 };
            println!(
                "{:>8} | {:>12} {:>12} | {:>12.2} {:>12} | {:>14.2} {:>12}",
                t + 1,
                kv_us,
                kv_bytes,
                window_p50_us(&l1.times[l1.times.len() - w..]),
                l1.state_bytes(),
                window_p50_us(&l2.times[l2.times.len() - w..]),
                l2.state_bytes(),
            );
            next += 1;
        }
    }

    section("growth factors (paper: KV xT, Fenwick ~log)");
    println!(
        "  pooled L1 blocks in use at depth {}: {} (= popcount+1; bound log2+1 = {})",
        max_t,
        l1.backend.pool().in_use(),
        (usize::BITS - max_t.leading_zeros()) as usize
    );
    println!(
        "  pooled L2xH2 blocks in use: {} (4 entries x live levels)",
        l2.backend.pool().in_use()
    );
}
