//! E11 — decode latency/memory growth (the §3.2 claim), measured on the
//! REAL serving engine: per-step decode time and resident state vs
//! context depth, through [`PooledBackend::step`] (pool-backed batched
//! Fenwick advance + batched read + logits GEMM — the exact code
//! `DecodeServer` drives), for a single-layer model and a sequential
//! 2-layer × 2-head stack, against a softmax KV-cache baseline.
//! KV-cache cost grows linearly with depth; the Fenwick engines stay ~log.
//!
//! Run: `cargo bench --bench decode_latency [-- --quick]`

use std::time::{Duration, Instant};

use loglinear::attention::softmax::KvCacheDecoder;
use loglinear::bench::section;
use loglinear::coordinator::backend::{DecodeBackend, PooledBackend, SeqSlot, TransitionKind};
use loglinear::coordinator::batcher::BatchPolicy;
use loglinear::coordinator::server::DecodeServer;
use loglinear::coordinator::GenRequest;
use loglinear::obs;
use loglinear::util::json::Json;
use loglinear::util::stats::Summary;
use loglinear::util::Rng;

/// Where the decode bench family records machine-readable headlines.
/// `decode_batched` owns the file (and runs first in `scripts/ci.sh`);
/// this bench merges its tracing/TTFT headlines into the same record.
const OUT_PATH: &str = "BENCH_decode.json";

/// Bound on obs hook sites crossed by one L2xH2 pooled decode step:
/// ~6 span guards (per-layer advance/read + projection + logits) plus
/// ~6 flop-accounting calls (projection/logits GEMMs, batched reads),
/// doubled for margin.
const HOOK_SITES_PER_STEP: f64 = 24.0;

fn window_p50_us(samples: &[f64]) -> f64 {
    Summary::of(samples).p50 * 1e6
}

/// One pooled serving sequence stepped to `max_t` depth through the real
/// backend; records per-step seconds.
struct PooledRun {
    backend: PooledBackend,
    slot: SeqSlot,
    times: Vec<f64>,
}

impl PooledRun {
    fn new(layers: usize, heads: usize, dk: usize, max_t: usize) -> PooledRun {
        // chunked prefill off: this bench measures the decode step itself
        let mut backend = PooledBackend::with_model_config(
            128,
            layers,
            heads,
            TransitionKind::Mamba2,
            dk,
            dk,
            0,
            4 * layers * heads * 32,
            0xE11,
        );
        let slot = backend.admit(max_t).expect("pool sized for the run");
        PooledRun { backend, slot, times: Vec::new() }
    }

    fn step(&mut self, tok: i32, pos: usize) {
        let t0 = std::time::Instant::now();
        let logits = self
            .backend
            .step(1, &[(self.slot, tok, pos as i32)])
            .expect("decode step");
        self.times.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(logits);
    }

    fn state_bytes(&self) -> usize {
        self.backend.state_bytes()
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");

    let (dk, dv) = (32usize, 32usize);
    let depths: &[usize] =
        if quick { &[1024, 4096] } else { &[1024, 4096, 16_384, 65_536] };
    let max_t = *depths.last().unwrap();
    let mut rng = Rng::new(3);
    let n_inputs = 2048;
    let qs: Vec<Vec<f32>> = (0..n_inputs)
        .map(|_| (0..dk).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let ks = qs.clone();
    let vs: Vec<Vec<f32>> = (0..n_inputs)
        .map(|_| (0..dv).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();

    section("per-step decode time (us, p50) and state bytes vs context depth");
    println!(
        "{:>8} | {:>12} {:>12} | {:>12} {:>12} | {:>14} {:>12}",
        "depth", "kv us/step", "kv bytes", "pooled L1 us", "L1 bytes", "pooled L2xH2 us", "L2 bytes"
    );

    let mut kv = KvCacheDecoder::new(dk);
    let mut kv_t = Vec::new();
    let kv_cap = 16_384.min(max_t); // KV path becomes the bench's own bottleneck
    let mut l1 = PooledRun::new(1, 1, dk, max_t);
    let mut l2 = PooledRun::new(2, 2, dk, max_t);

    let mut next = 0usize;
    for t in 0..max_t {
        let i = t % n_inputs;
        if t < kv_cap {
            let t0 = std::time::Instant::now();
            kv.step(&qs[i], &ks[i], &vs[i]);
            kv_t.push(t0.elapsed().as_secs_f64());
        }
        let tok = (t % 128) as i32;
        l1.step(tok, t);
        l2.step(tok, t);

        if next < depths.len() && t + 1 == depths[next] {
            let w = 512.min(t + 1);
            let kv_us = if t < kv_cap {
                format!("{:.2}", window_p50_us(&kv_t[kv_t.len() - w.min(kv_t.len())..]))
            } else {
                // linear extrapolation from the last measured window
                format!(
                    "~{:.2}",
                    window_p50_us(&kv_t[kv_t.len() - w.min(kv_t.len())..]) * (t + 1) as f64
                        / kv_cap as f64
                )
            };
            let kv_bytes = if t < kv_cap { kv.state_bytes() } else { (t + 1) * (dk + dv) * 4 };
            println!(
                "{:>8} | {:>12} {:>12} | {:>12.2} {:>12} | {:>14.2} {:>12}",
                t + 1,
                kv_us,
                kv_bytes,
                window_p50_us(&l1.times[l1.times.len() - w..]),
                l1.state_bytes(),
                window_p50_us(&l2.times[l2.times.len() - w..]),
                l2.state_bytes(),
            );
            next += 1;
        }
    }

    section("growth factors (paper: KV xT, Fenwick ~log)");
    println!(
        "  pooled L1 blocks in use at depth {}: {} (= popcount+1; bound log2+1 = {})",
        max_t,
        l1.backend.pool().in_use(),
        (usize::BITS - max_t.leading_zeros()) as usize
    );
    println!(
        "  pooled L2xH2 blocks in use: {} (4 entries x live levels)",
        l2.backend.pool().in_use()
    );

    // ---- tracing on/off overhead (the obs recorder A/B) --------------
    section("tracing overhead: obs recorder off vs on (pooled L2xH2 decode)");
    let warm = 1024usize;
    let steps = if quick { 1024 } else { 4096 };
    let mut run = PooledRun::new(2, 2, dk, warm + 2 * steps + 16);
    for t in 0..warm {
        run.step((t % 128) as i32, t);
    }
    run.times.clear();
    obs::disable();
    for t in warm..warm + steps {
        run.step((t % 128) as i32, t);
    }
    let off = Summary::of(&run.times);
    run.times.clear();
    obs::enable_with_capacity(1 << 15);
    for t in warm + steps..warm + 2 * steps {
        run.step((t % 128) as i32, t);
    }
    let drained = obs::drain();
    obs::disable();
    let on = Summary::of(&run.times);
    let spans_per_step = (drained.events.len() as u64 + drained.dropped) as f64 / steps as f64;
    let tracing_overhead_pct = (on.p50 / off.p50 - 1.0) * 100.0;
    println!(
        "  p50 us/step: off {:.2}  on {:.2}  ({:+.2}% traced, {:.1} spans/step)",
        off.p50 * 1e6,
        on.p50 * 1e6,
        tracing_overhead_pct,
        spans_per_step
    );

    // Disabled-mode regression: the hooks are compiled in, so their cost
    // with the recorder OFF is what every untraced serving step pays.
    // Measure one disabled span-guard + flop-account pair directly and
    // scale by a conservative per-step hook-site bound.
    let m = 1_000_000u64;
    let t0 = Instant::now();
    for i in 0..m {
        let g = obs::span(obs::SpanCat::DecodeStep, i);
        obs::account_flops(2, 4);
        std::hint::black_box(&g);
    }
    let pair_ns = t0.elapsed().as_secs_f64() * 1e9 / m as f64;
    let tracing_disabled_overhead_pct =
        HOOK_SITES_PER_STEP * pair_ns / (off.p50 * 1e9) * 100.0;
    println!(
        "  disabled hook pair {pair_ns:.2} ns; {HOOK_SITES_PER_STEP:.0} sites/step \
         => {tracing_disabled_overhead_pct:.3}% of a decode step"
    );
    assert!(
        tracing_disabled_overhead_pct < 2.0,
        "tracing-disabled decode-step regression must stay under 2%: \
         {tracing_disabled_overhead_pct:.3}%"
    );

    // ---- served TTFT / inter-token latency (ServerStats histograms) --
    section("served latency: TTFT and inter-token gaps through DecodeServer");
    let backend = PooledBackend::with_model_config(
        128, 2, 2, TransitionKind::Mamba2, dk, dk, 16, 8192, 0xE11,
    );
    let mut srv = DecodeServer::with_backend(backend, BatchPolicy::new(vec![1, 4], Duration::ZERO));
    for id in 0..8u64 {
        let prompt: Vec<i32> = (0..33).map(|i| ((id as i64 * 11 + i * 3) % 128) as i32).collect();
        srv.submit(GenRequest { id, prompt, max_new: 16 }).expect("submit");
    }
    let mut guard_steps = 0;
    while srv.pending() > 0 {
        srv.step().expect("serve step");
        guard_steps += 1;
        assert!(guard_steps < 100_000, "served run made no progress");
    }
    let stats = srv.stats.clone();
    let ttft = stats.ttft_seconds.summary().expect("8 requests streamed");
    let gap = stats.inter_token_seconds.summary().expect("gaps recorded");
    println!(
        "  ttft us: mean {:.1}  p50 {:.1}  p99 {:.1}   inter-token us: p50 {:.1}  p99 {:.1}",
        ttft.mean * 1e6,
        ttft.p50 * 1e6,
        ttft.p99 * 1e6,
        gap.p50 * 1e6,
        gap.p99 * 1e6
    );

    // ---- merge headlines into BENCH_decode.json ----------------------
    let doc = std::fs::read_to_string(OUT_PATH)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .filter(|j| matches!(j, Json::Obj(_)))
        .unwrap_or_else(Json::obj)
        .set("tracing_overhead_pct", tracing_overhead_pct)
        .set("tracing_disabled_overhead_pct", tracing_disabled_overhead_pct)
        .set("decode_p50_us_tracing_off", off.p50 * 1e6)
        .set("decode_p50_us_tracing_on", on.p50 * 1e6)
        .set("spans_per_step", spans_per_step)
        .set("ttft_p50_us", ttft.p50 * 1e6)
        .set("ttft_p99_us", ttft.p99 * 1e6)
        .set("inter_token_p99_us", gap.p99 * 1e6);
    match std::fs::write(OUT_PATH, doc.pretty()) {
        Ok(()) => println!("\nmerged tracing/TTFT headlines into {OUT_PATH}"),
        Err(e) => eprintln!("\nfailed to write {OUT_PATH}: {e}"),
    }
}
