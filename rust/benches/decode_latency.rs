//! E11 — decode latency/memory growth (the §3.2 claim): per-step decode
//! time and resident state vs context depth for the three regimes.
//! KV-cache cost grows linearly, Fenwick stays ~log.
//!
//! Run: `cargo bench --bench decode_latency`

use loglinear::attention::softmax::KvCacheDecoder;
use loglinear::bench::section;
use loglinear::state::{FenwickState, Transition};
use loglinear::util::stats::Summary;
use loglinear::util::Rng;

fn window_mean(samples: &[f64]) -> f64 {
    Summary::of(samples).p50 * 1e6
}

fn main() {
    let (dk, dv) = (32, 32);
    let depths = [1024usize, 4096, 16_384, 65_536];
    let max_t = *depths.last().unwrap();
    let mut rng = Rng::new(3);
    let n_inputs = 2048;
    let qs: Vec<Vec<f32>> = (0..n_inputs)
        .map(|_| (0..dk).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let ks = qs.clone();
    let vs: Vec<Vec<f32>> = (0..n_inputs)
        .map(|_| (0..dv).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();

    section("per-step decode time (us) and state bytes vs context depth");
    println!(
        "{:>8} | {:>12} {:>12} | {:>10} {:>10} | {:>12} {:>12}",
        "depth", "kv us/step", "kv bytes", "m2 us", "m2 bytes", "fenwick us", "fenwick bytes"
    );

    let mut kv = KvCacheDecoder::new(dk);
    let mut m2 = loglinear::tensor::Mat::zeros(dk, dv);
    let mut fw = FenwickState::new(dk, dv);
    let lambda = vec![1.0f32; 24];
    let mut next = 0usize;
    let mut kv_t = Vec::new();
    let mut m2_t = Vec::new();
    let mut fw_t = Vec::new();
    let kv_cap = 16_384; // KV path becomes the bottleneck of the bench itself

    for t in 0..max_t {
        let i = t % n_inputs;
        if t < kv_cap {
            let t0 = std::time::Instant::now();
            kv.step(&qs[i], &ks[i], &vs[i]);
            kv_t.push(t0.elapsed().as_secs_f64());
        }
        let t0 = std::time::Instant::now();
        m2.scale_inplace(0.999);
        loglinear::tensor::outer_acc(&mut m2, &ks[i], &vs[i], 1.0);
        std::hint::black_box(m2.matvec_t(&qs[i]));
        m2_t.push(t0.elapsed().as_secs_f64());

        let t0 = std::time::Instant::now();
        std::hint::black_box(fw.step(&qs[i], &ks[i], &vs[i], 1.0, Transition::Decay(0.999), &lambda));
        fw_t.push(t0.elapsed().as_secs_f64());

        if next < depths.len() && t + 1 == depths[next] {
            let w = 512.min(t + 1);
            let kv_us = if t < kv_cap {
                format!("{:.2}", window_mean(&kv_t[kv_t.len() - w..]))
            } else {
                // linear extrapolation from the last measured window
                format!(
                    "~{:.2}",
                    window_mean(&kv_t[kv_t.len() - w..]) * (t + 1) as f64 / kv_cap as f64
                )
            };
            let kv_bytes = if t < kv_cap {
                kv.state_bytes()
            } else {
                (t + 1) * (dk + dv) * 4
            };
            println!(
                "{:>8} | {:>12} {:>12} | {:>10.2} {:>10} | {:>12.2} {:>12}",
                t + 1,
                kv_us,
                kv_bytes,
                window_mean(&m2_t[m2_t.len() - w..]),
                dk * dv * 4,
                window_mean(&fw_t[fw_t.len() - w..]),
                fw.state_bytes(),
            );
            next += 1;
        }
    }

    section("growth factors depth 1K -> 64K (paper: KV x64, Fenwick ~x1.6)");
    println!(
        "  fenwick live states at 64K: {} (= popcount+1; bound log2(64K)+1 = 17)",
        fw.live_states()
    );
}
