//! Prompt-ingestion throughput: chunkwise prefill (the `prefill`
//! subsystem — head-batched Alg. 1 + export bridge) vs the token-by-token
//! recurrent path the serving engine used before (one `PooledFenwickState`
//! advance + λ-read per token per head), plus the **sequential L-layer
//! stack** ingest mode and the **prompt-scoring** workload the per-token
//! chunk outputs unlock.
//!
//! Run: `cargo bench --bench prefill_throughput [-- --quick] [--threads N]`
//!
//! Emits `BENCH_prefill.json`:
//! - prompt tokens/s for both paths and both log-linear variants, with
//!   the chunkwise-vs-token speedup headline (`speedup_vs_token_by_token`)
//!   and previous-run deltas;
//! - sequential L-layer stack ingest tokens/s (`sequential` block);
//! - the `score_tokens_per_s` headline: per-token log-probs for a whole
//!   prompt through the serving scoring path (chunkwise stack outputs +
//!   logits GEMMs + sub-chunk tail), vs the token-by-token replay —
//!   **equivalence asserted before timing** in both sections;
//! - the shared-workspace accounting (`workspace_bytes_shared` /
//!   `workspace_bytes_saved_per_extra_prompt`): scratch one extra
//!   concurrent prompt no longer allocates now that all engines share
//!   one `prefill::Workspace`;
//! - the shared-system-prompt serving section: a fleet of requests
//!   repeating one long system prompt, served cold vs through the
//!   copy-on-write prefix-state cache — **hit-vs-cold logits asserted
//!   bit-equal before timing** — emitting
//!   `prefill_tokens_saved_per_request` and `ttft_speedup_vs_cold`.

use loglinear::bench::{bench, section};
use loglinear::coordinator::backend::{
    fold_score_logprobs, DecodeBackend, PooledBackend, TransitionKind,
};
use loglinear::coordinator::batcher::BatchPolicy;
use loglinear::coordinator::server::DecodeServer;
use loglinear::coordinator::GenRequest;
use std::time::Duration;
use loglinear::prefill::bridge::export_prefill_head;
use loglinear::prefill::{LayerProjection, LayerStack, PrefillEngine, Workspace};
use loglinear::state::pool::StatePool;
use loglinear::state::pooled::PooledFenwickState;
use loglinear::state::{GateTable, Transition};
use loglinear::tensor::{self, Mat};
use loglinear::obs;
use loglinear::util::json::Json;
use loglinear::util::stats::ols;
use loglinear::util::Rng;

const OUT_PATH: &str = "BENCH_prefill.json";

struct Fixture {
    heads: usize,
    dk: usize,
    dv: usize,
    c: usize,
    t: usize,
    /// per-head inputs, (T, d) each; keys L2-normalized
    ks: Vec<Mat>,
    vs: Vec<Mat>,
    qs: Vec<Mat>,
    /// per-chunk stacked (H, C, d) views for the engine
    kc: Vec<Vec<f32>>,
    vc: Vec<Vec<f32>>,
    qc: Vec<Vec<f32>>,
    alpha: Vec<f32>,
    beta: Vec<f32>,
    lambda: Vec<f32>,
}

fn build(heads: usize, dk: usize, dv: usize, c: usize, t: usize) -> Fixture {
    let mut rng = Rng::new(0x9F11);
    let mut ks = Vec::new();
    let mut vs = Vec::new();
    let mut qs = Vec::new();
    for _ in 0..heads {
        let mut k = Mat::randn(t, dk, 1.0, &mut rng);
        for i in 0..t {
            let n = loglinear::tensor::ops::l2_norm(k.row(i)).max(1e-6);
            for x in k.row_mut(i) {
                *x /= n;
            }
        }
        ks.push(k);
        vs.push(Mat::randn(t, dv, 1.0, &mut rng));
        qs.push(Mat::randn(t, dk, 1.0, &mut rng));
    }
    let mut kc = Vec::new();
    let mut vc = Vec::new();
    let mut qc = Vec::new();
    for z in 0..t / c {
        let mut kz = Vec::with_capacity(heads * c * dk);
        let mut vz = Vec::with_capacity(heads * c * dv);
        let mut qz = Vec::with_capacity(heads * c * dk);
        for h in 0..heads {
            kz.extend_from_slice(ks[h].rows_data(z * c, (z + 1) * c));
            vz.extend_from_slice(vs[h].rows_data(z * c, (z + 1) * c));
            qz.extend_from_slice(qs[h].rows_data(z * c, (z + 1) * c));
        }
        kc.push(kz);
        vc.push(vz);
        qc.push(qz);
    }
    let alpha: Vec<f32> = (0..t).map(|_| rng.range_f32(0.99, 1.0)).collect();
    let beta: Vec<f32> = (0..t).map(|_| rng.range_f32(0.1, 0.9)).collect();
    let lambda: Vec<f32> = (0..24).map(|l| 0.5f32.powi(l)).collect();
    Fixture { heads, dk, dv, c, t, ks, vs, qs, kc, vc, qc, alpha, beta, lambda }
}

impl Fixture {
    fn transition(&self, gdn: bool, h: usize, t: usize) -> Transition<'_> {
        if gdn {
            Transition::GatedHouseholder {
                alpha: self.alpha[t],
                beta: self.beta[t],
                k: self.ks[h].row(t),
            }
        } else {
            Transition::Decay(self.alpha[t])
        }
    }

    fn write_scale(&self, gdn: bool, t: usize) -> f32 {
        if gdn {
            self.beta[t]
        } else {
            1.0
        }
    }

    /// The old serving path: every prompt token through the recurrent
    /// advance + λ-read, per head.
    fn ingest_token_by_token(&self, gdn: bool, pool: &mut StatePool) -> Vec<PooledFenwickState> {
        let mut out = Vec::with_capacity(self.heads);
        let mut o = vec![0.0f32; self.dv];
        for h in 0..self.heads {
            let mut seq = PooledFenwickState::new(self.dk, self.dv);
            for t in 0..self.t {
                seq.advance(
                    pool,
                    self.ks[h].row(t),
                    self.vs[h].row(t),
                    self.write_scale(gdn, t),
                    self.transition(gdn, h, t),
                )
                .expect("pool sized for the trace");
                seq.read_into(pool, self.qs[h].row(t), &self.lambda, &mut o);
                std::hint::black_box(&o);
            }
            out.push(seq);
        }
        out
    }

    /// The new path: full chunks through the head-batched engine (shared
    /// workspace), then the export bridge into pool blocks (state-only —
    /// the serving prefill never reads).
    fn ingest_chunkwise(
        &self,
        gdn: bool,
        ws: &mut Workspace,
        pool: &mut StatePool,
    ) -> Vec<PooledFenwickState> {
        let mut eng = PrefillEngine::new(self.heads, self.dk, self.dv, self.c);
        for z in 0..self.t / self.c {
            let (s, e) = (z * self.c, (z + 1) * self.c);
            if gdn {
                eng.ingest_chunk_gdn(ws, &self.kc[z], &self.vc[z], &self.alpha[s..e], &self.beta[s..e], None);
            } else {
                eng.ingest_chunk_mamba2(ws, &self.kc[z], &self.vc[z], &self.alpha[s..e], None);
            }
        }
        eng.finish();
        (0..self.heads)
            .map(|h| export_prefill_head(&eng, h, pool).expect("pool sized for export"))
            .collect()
    }

    /// Both paths must agree: advance one probe token past the boundary
    /// on each and compare the λ-reads within the chunkwise tolerance.
    fn assert_equivalent(&self, gdn: bool, ws: &mut Workspace, pool: &mut StatePool) {
        let mut a = self.ingest_token_by_token(gdn, pool);
        let mut b = self.ingest_chunkwise(gdn, ws, pool);
        let probe_t = self.t - 1; // reuse the last token as the probe
        for h in 0..self.heads {
            for (seq, path) in [(&mut a[h], "token"), (&mut b[h], "chunkwise")] {
                let o = seq
                    .step(
                        pool,
                        self.qs[h].row(probe_t),
                        self.ks[h].row(probe_t),
                        self.vs[h].row(probe_t),
                        self.write_scale(gdn, probe_t),
                        self.transition(gdn, h, probe_t),
                        &self.lambda,
                    )
                    .unwrap_or_else(|e| panic!("{path} probe step: {e}"));
                std::hint::black_box(o);
            }
        }
        // re-run the probe on fresh clones is overkill; compare directly
        let mut oa = vec![0.0f32; self.dv];
        let mut ob = vec![0.0f32; self.dv];
        for h in 0..self.heads {
            a[h].read_into(pool, self.qs[h].row(0), &self.lambda, &mut oa);
            b[h].read_into(pool, self.qs[h].row(0), &self.lambda, &mut ob);
            for j in 0..self.dv {
                // looser than the unit tests' 2e-3: 4k-token cumulative
                // decay products accumulate ~T·ε of relative f32 error
                assert!(
                    (oa[j] - ob[j]).abs() < 1e-3 + 1e-2 * ob[j].abs(),
                    "gdn={gdn} head={h} j={j}: chunkwise prefill diverged ({} vs {})",
                    ob[j],
                    oa[j]
                );
            }
        }
        for mut seq in a {
            seq.release(pool);
        }
        for mut seq in b {
            seq.release(pool);
        }
        assert_eq!(pool.in_use(), 0);
    }

    /// Sequential L-layer stack ingest over the whole prompt (per-token
    /// outputs carried layer-to-layer) — the serving prefill shape for
    /// the paper's actual stacked models.
    fn ingest_stack(
        &self,
        gdn: bool,
        layers: usize,
        ws: &mut Workspace,
        projs: &[LayerProjection],
        gates: &[GateTable],
    ) -> LayerStack {
        let kind = if gdn { TransitionKind::Gdn } else { TransitionKind::Mamba2 };
        let mut stack = LayerStack::new(layers, self.heads, self.dk, self.dv, self.c);
        for z in 0..self.t / self.c {
            stack.ingest_chunk(ws, kind, projs, gates, z * self.c, &self.qc[z], &self.kc[z], &self.vc[z], true);
            std::hint::black_box(stack.last_output());
        }
        stack
    }
}

/// Score a whole prompt through the serving trait path (budget-free:
/// chunk loop + tail), returning its per-token log-probs.
fn score_prompt(b: &mut PooledBackend, tokens: &[i32]) -> Vec<f32> {
    let slot = b.score_admit().expect("score admit");
    let c = b.prefill_chunk_size();
    let n = tokens.len();
    let mut lps = Vec::with_capacity(n.saturating_sub(1));
    let mut pos = 0;
    if c > 0 {
        while pos + c < n {
            let logits = b.score_chunk(slot, &tokens[pos..pos + c], pos).expect("score chunk");
            fold_score_logprobs(&logits, c, tokens, pos, &mut lps);
            pos += c;
        }
    }
    let tail = &tokens[pos..n - 1];
    let logits = b.score_tail(slot, tail, pos).expect("score tail");
    fold_score_logprobs(&logits, tail.len(), tokens, pos, &mut lps);
    b.retire(slot);
    lps
}

/// A/B the chunkwise ingest with the SIMD microkernels forced off vs the
/// runtime-dispatched kernels (docs/PRECISION.md). Boundary states are
/// asserted bit-identical across the two modes *before* anything is
/// timed. Returns `(simd_speedup_vs_scalar, dispatch_mode)`.
#[cfg(feature = "simd")]
fn simd_ingest_ab(fx: &Fixture, ws: &mut Workspace) -> (f64, &'static str) {
    use loglinear::tensor::simd;
    let mode = if simd::runtime_available() { "avx2" } else { "portable" };
    let (dk, dv) = (fx.dk, fx.dv);
    let mut pool_s = StatePool::new(dk * dv, fx.heads * 16);
    let mut pool_d = StatePool::new(dk * dv, fx.heads * 16);
    simd::set_forced_scalar(true);
    let a = fx.ingest_chunkwise(false, ws, &mut pool_s);
    simd::set_forced_scalar(false);
    let b = fx.ingest_chunkwise(false, ws, &mut pool_d);
    let (mut oa, mut ob) = (vec![0.0f32; dv], vec![0.0f32; dv]);
    for h in 0..fx.heads {
        a[h].read_into(&pool_s, fx.qs[h].row(0), &fx.lambda, &mut oa);
        b[h].read_into(&pool_d, fx.qs[h].row(0), &fx.lambda, &mut ob);
        for (x, y) in oa.iter().zip(&ob) {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "SIMD chunkwise ingest diverged from the scalar oracle (head {h})"
            );
        }
    }
    for mut s in a {
        s.release(&mut pool_s);
    }
    for mut s in b {
        s.release(&mut pool_d);
    }
    simd::set_forced_scalar(true);
    let r_s = bench("forced-scalar chunkwise ingest/loglinear_mamba2", 0.3, || {
        let seqs = fx.ingest_chunkwise(false, ws, &mut pool_s);
        for mut s in seqs {
            s.release(&mut pool_s);
        }
    });
    simd::set_forced_scalar(false);
    let r_d = bench(&format!("dispatched chunkwise ingest/loglinear_mamba2 ({mode})"), 0.3, || {
        let seqs = fx.ingest_chunkwise(false, ws, &mut pool_d);
        for mut s in seqs {
            s.release(&mut pool_d);
        }
    });
    (r_s.secs.mean / r_d.secs.mean, mode)
}

#[cfg(not(feature = "simd"))]
fn simd_ingest_ab(_fx: &Fixture, _ws: &mut Workspace) -> (f64, &'static str) {
    println!("  simd feature disabled: the scalar kernels are the only path; speedup is 1.0");
    (1.0, "off")
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    if let Some(pos) = args.iter().position(|a| a == "--threads") {
        if let Some(n) = args.get(pos + 1).and_then(|s| s.parse::<usize>().ok()) {
            tensor::gemm_threads(n);
        }
    }

    let (heads, dk, dv, c, t) = (4usize, 64usize, 64usize, 64usize, 4096usize);
    let fx = build(heads, dk, dv, c, t);
    let variants: &[bool] = if quick { &[false] } else { &[false, true] };
    let mut ws = Workspace::new();

    section(&format!(
        "prompt ingestion: chunkwise prefill vs token-by-token (H={heads}, dk=dv={dk}, C={c}, T={t}, gemm_threads={})",
        tensor::current_gemm_threads()
    ));

    // (variant, path, secs_per_ingest)
    let mut rows: Vec<(String, String, f64)> = Vec::new();
    for &gdn in variants {
        let variant = if gdn { "loglinear_gdn" } else { "loglinear_mamba2" };
        let mut pool = StatePool::new(dk * dv, heads * 16);
        fx.assert_equivalent(gdn, &mut ws, &mut pool);

        let r = bench(&format!("token-by-token/{variant}"), 0.3, || {
            let seqs = fx.ingest_token_by_token(gdn, &mut pool);
            for mut seq in seqs {
                seq.release(&mut pool);
            }
        });
        rows.push((variant.into(), "token_by_token".into(), r.secs.mean));

        let r = bench(&format!("chunkwise prefill/{variant}"), 0.3, || {
            let seqs = fx.ingest_chunkwise(gdn, &mut ws, &mut pool);
            for mut seq in seqs {
                seq.release(&mut pool);
            }
        });
        rows.push((variant.into(), "chunkwise".into(), r.secs.mean));
    }

    // ---- SIMD microkernels: forced-scalar vs dispatched ingest --------
    section("SIMD microkernels: forced-scalar vs dispatched chunkwise ingest — simd_speedup_vs_scalar");
    let (simd_speedup_vs_scalar, simd_mode) = simd_ingest_ab(&fx, &mut ws);
    println!("  dispatch mode: {simd_mode}  simd_speedup_vs_scalar: {simd_speedup_vs_scalar:.2}x");

    // ---- sequential L-layer stack mode ----
    let stack_layers = 2usize;
    section(&format!(
        "sequential {stack_layers}-layer stack ingest (per-token outputs carried layer-to-layer)"
    ));
    let mut srng = Rng::new(0x5E0);
    let projs: Vec<LayerProjection> =
        (1..stack_layers).map(|_| LayerProjection::random(heads, dk, dv, &mut srng)).collect();
    let gates =
        vec![
            GateTable::fixed(0.99, (0..24).map(|l| 0.5f32.powi(l)).collect())
                .with_beta(vec![0.5]);
            stack_layers
        ];
    let mut stack_rows: Vec<(String, f64)> = Vec::new();
    for &gdn in variants {
        let variant = if gdn { "loglinear_gdn" } else { "loglinear_mamba2" };
        let r = bench(&format!("sequential stack x{stack_layers}/{variant}"), 0.3, || {
            let stack = fx.ingest_stack(gdn, stack_layers, &mut ws, &projs, &gates);
            std::hint::black_box(stack.tokens());
        });
        stack_rows.push((variant.into(), r.secs.mean));
    }

    // ---- prompt scoring: the workload the per-token outputs unlock ----
    let (s_layers, s_heads, s_dk, s_vocab) = (2usize, 2usize, 32usize, 256usize);
    let s_t = if quick { 1024usize } else { 2048 };
    section(&format!(
        "prompt scoring: chunkwise stack outputs vs token-by-token replay (L={s_layers}, H={s_heads}, dk=dv={s_dk}, vocab={s_vocab}, T={s_t})"
    ));
    let mut prng = Rng::new(0x5C0);
    let prompt: Vec<i32> = (0..s_t).map(|_| prng.below(s_vocab) as i32).collect();
    let mut chunked = PooledBackend::with_model_config(
        s_vocab,
        s_layers,
        s_heads,
        TransitionKind::Mamba2,
        s_dk,
        s_dk,
        64,
        64,
        0x5EED,
    );
    let tokenwise = PooledBackend::with_model_config(
        s_vocab,
        s_layers,
        s_heads,
        TransitionKind::Mamba2,
        s_dk,
        s_dk,
        0, // chunked prefill off: scoring degenerates to the per-token replay
        64,
        0x5EED, // same weights (the chunk size does not touch the RNG)
    );
    // equivalence before timing: the chunkwise score must match the
    // token-by-token replay within the chunkwise tolerance
    {
        let got = score_prompt(&mut chunked, &prompt);
        let want = tokenwise.oracle_score_logprobs(&prompt);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g - w).abs() < 5e-2 + 2e-2 * w.abs(),
                "score target {}: chunkwise {} vs token-by-token {}",
                i + 1,
                g,
                w
            );
        }
    }
    let r = bench("score/chunkwise", 0.3, || {
        std::hint::black_box(score_prompt(&mut chunked, &prompt));
    });
    let score_chunk_secs = r.secs.mean;
    let r = bench("score/token-by-token", 0.3, || {
        std::hint::black_box(tokenwise.oracle_score_logprobs(&prompt));
    });
    let score_token_secs = r.secs.mean;
    let score_tps = s_t as f64 / score_chunk_secs;
    let score_speedup = score_token_secs / score_chunk_secs;

    // ---- shared-system-prompt serving: the CoW prefix-state cache ----
    let (pc_layers, pc_heads, pc_dk, pc_vocab, pc_chunk) = (2usize, 2usize, 32usize, 256usize, 64usize);
    let sys_len = 1024usize;
    let n_req = 6usize;
    let suffix_len = 8usize;
    let pc_new = 4usize;
    section(&format!(
        "shared-system-prompt serving: CoW prefix cache (L={pc_layers}, H={pc_heads}, dk=dv={pc_dk}, C={pc_chunk}, system={sys_len} tokens, {n_req} requests)"
    ));
    let mut crng = Rng::new(0xCAC4E);
    let system: Vec<i32> = (0..sys_len).map(|_| crng.below(pc_vocab) as i32).collect();
    let prompts: Vec<Vec<i32>> = (0..n_req)
        .map(|_| {
            let mut p = system.clone();
            p.extend((0..suffix_len).map(|_| crng.below(pc_vocab) as i32));
            p
        })
        .collect();
    let pc_backend = |cache: bool| {
        let mut b = PooledBackend::with_model_config(
            pc_vocab, pc_layers, pc_heads, TransitionKind::Mamba2, pc_dk, pc_dk, pc_chunk, 1024, 0xCAFE,
        );
        if cache {
            b.enable_prefix_cache();
        }
        b
    };
    let pc_policy = || BatchPolicy::new(vec![1, 2, 4], Duration::ZERO);
    // two waves: the first publishes the shared span's chunk boundaries
    // into the cache, the second repeats every prompt verbatim (the
    // serving pattern: many users, one system prompt). Returns the
    // second wave's (hits, prefill tokens saved) deltas.
    let serve_waves = |srv: &mut DecodeServer<PooledBackend>| -> (usize, usize) {
        for (i, p) in prompts.iter().enumerate() {
            srv.submit(GenRequest { id: i as u64, prompt: p.clone(), max_new: pc_new })
                .expect("submit wave 1");
        }
        srv.run_to_completion().expect("serve wave 1");
        let (h1, s1) = (srv.stats.prefix_cache_hits, srv.stats.prefill_tokens_saved);
        for (i, p) in prompts.iter().enumerate() {
            srv.submit(GenRequest { id: 100 + i as u64, prompt: p.clone(), max_new: pc_new })
                .expect("submit wave 2");
        }
        srv.run_to_completion().expect("serve wave 2");
        (srv.stats.prefix_cache_hits - h1, srv.stats.prefill_tokens_saved - s1)
    };
    // equivalence before timing: the cached serve must reproduce the cold
    // serve's captured logits bit-for-bit, both waves, every row
    let mut cold_srv = DecodeServer::with_backend(pc_backend(false), pc_policy());
    cold_srv.enable_logit_capture();
    let (cold_hits, _) = serve_waves(&mut cold_srv);
    assert_eq!(cold_hits, 0, "cache disabled: no hits expected");
    let mut hit_srv = DecodeServer::with_backend(pc_backend(true), pc_policy());
    hit_srv.enable_logit_capture();
    let (w2_hits, w2_saved) = serve_waves(&mut hit_srv);
    assert!(w2_hits >= n_req, "verbatim repeat wave must hit the cache (got {w2_hits} hits)");
    assert_eq!(w2_saved, n_req * sys_len, "each repeat must skip the whole shared span");
    let mut want = cold_srv.take_captured_logits();
    let mut got = hit_srv.take_captured_logits();
    want.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    got.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    assert_eq!(want.len(), got.len(), "cached serve dropped or added logit rows");
    for (w, g) in want.iter().zip(got.iter()) {
        assert_eq!((w.0, w.1), (g.0, g.1));
        assert!(w.2 == g.2, "id={} pos={}: cached serve logits diverged", w.0, w.1);
    }
    drop(want);
    drop(got);
    drop(cold_srv);
    drop(hit_srv);
    let saved_per_request = w2_saved as f64 / n_req as f64;

    // TTFT: one system-prompt request at max_new = 1, cold prefill vs a
    // warm full-prefix hit. The cache stays warm across iterations — a
    // full hit adopts the cached boundary without re-inserting, so every
    // timed pass skips the shared span's prefill entirely.
    let mut next_id = 1000u64;
    let mut cold_t = DecodeServer::with_backend(pc_backend(false), pc_policy());
    let r = bench("ttft/cold prefill", 0.3, || {
        next_id += 1;
        cold_t
            .submit(GenRequest { id: next_id, prompt: prompts[0].clone(), max_new: 1 })
            .expect("submit cold ttft");
        std::hint::black_box(cold_t.run_to_completion().expect("cold ttft serve"));
    });
    let ttft_cold = r.secs.mean;
    let mut hit_t = DecodeServer::with_backend(pc_backend(true), pc_policy());
    hit_t
        .submit(GenRequest { id: 1, prompt: prompts[0].clone(), max_new: 1 })
        .expect("submit warmup");
    hit_t.run_to_completion().expect("cache warmup serve");
    let r = bench("ttft/prefix-cache hit", 0.3, || {
        next_id += 1;
        hit_t
            .submit(GenRequest { id: next_id, prompt: prompts[0].clone(), max_new: 1 })
            .expect("submit hit ttft");
        std::hint::black_box(hit_t.run_to_completion().expect("hit ttft serve"));
    });
    let ttft_hit = r.secs.mean;
    assert!(hit_t.stats.prefix_cache_hits >= 1, "timed hit pass never hit the cache");
    let ttft_speedup = ttft_cold / ttft_hit;
    println!(
        "  prefill_tokens_saved_per_request: {saved_per_request:.0}   ttft: {:.3} ms cold vs {:.3} ms hit ({ttft_speedup:.2}x)",
        ttft_cold * 1e3,
        ttft_hit * 1e3
    );

    // ---- shared-workspace accounting ----
    let ws_bytes = ws.bytes();
    section("shared prefill workspace");
    println!(
        "  one shared workspace: {} KiB (before: every concurrent prompt's engine held its own copy)",
        ws_bytes / 1024
    );

    section("prompt tokens/s and chunkwise speedup");
    println!("{:>18} {:>18} {:>18} {:>10}", "variant", "token-by-token", "chunkwise", "speedup");
    let mut speedups: Vec<(String, f64)> = Vec::new();
    for &gdn in variants {
        let variant = if gdn { "loglinear_gdn" } else { "loglinear_mamba2" };
        let get = |path: &str| {
            rows.iter()
                .find(|(v, p, _)| v == variant && p == path)
                .map(|(_, _, s)| *s)
                .unwrap()
        };
        let tok_s = t as f64 / get("token_by_token");
        let chunk_s = t as f64 / get("chunkwise");
        let speedup = chunk_s / tok_s;
        println!("{variant:>18} {tok_s:>14.0} t/s {chunk_s:>14.0} t/s {speedup:>9.2}x");
        speedups.push((variant.into(), speedup));
    }
    println!(
        "\n  score_tokens_per_s: {score_tps:.0} ({score_speedup:.2}x vs token-by-token replay)"
    );

    // ---- kernel flop accounting: flops/token vs prompt length --------
    // The obs GEMM hooks attribute every dense and batched matmul; over
    // chunkwise scoring the per-token flop cost must grow like
    // a + b·log2 T (level reads touch O(log T) Fenwick levels) — the
    // paper's O(T log T) prefill claim measured from the kernels, not
    // from wall clock.
    section("kernel flop accounting: flops/token vs prompt length (chunkwise scoring)");
    let fl_lengths: &[usize] =
        if quick { &[128, 256, 512] } else { &[128, 256, 512, 1024, 2048] };
    let mut fl_per_token: Vec<f64> = Vec::new();
    let mut flrng = Rng::new(0xF10);
    for &ft in fl_lengths {
        obs::enable_with_capacity(1 << 10); // resets the flop counters
        let mut b = PooledBackend::with_model_config(
            64, 1, 1, TransitionKind::Mamba2, 8, 8, 16, 4096, 0xF10,
        );
        let toks: Vec<i32> = (0..ft).map(|_| flrng.below(64) as i32).collect();
        std::hint::black_box(score_prompt(&mut b, &toks));
        let flops = obs::total_flops();
        obs::drain();
        obs::disable();
        assert!(flops > 0, "T={ft}: GEMM hooks must attribute flops");
        fl_per_token.push(flops as f64 / ft as f64);
    }
    let fl_log_t: Vec<f64> = fl_lengths.iter().map(|&v| (v as f64).log2()).collect();
    let (_fl_a, fl_b, fl_r2) = ols(&fl_log_t, &fl_per_token);
    println!("{:>8} {:>16}", "T", "flops/token");
    for (i, &ft) in fl_lengths.iter().enumerate() {
        println!("{ft:>8} {:>16.0}", fl_per_token[i]);
    }
    println!("  semilog fit: flops/token = a + {fl_b:.1}*log2(T), r2 = {fl_r2:.4}");
    assert!(
        fl_b > 0.0 && fl_r2 > 0.9,
        "flops/token must fit a + b*log2 T (b={fl_b}, r2={fl_r2}): {fl_per_token:?}"
    );

    // ---- machine-readable record (BENCH_prefill.json) ----
    let previous = std::fs::read_to_string(OUT_PATH)
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    let prev_tps = |variant: &str, path: &str| -> Option<f64> {
        previous
            .as_ref()?
            .get("points")?
            .as_arr()?
            .iter()
            .find(|p| {
                p.get("variant").and_then(|s| s.as_str()) == Some(variant)
                    && p.get("path").and_then(|s| s.as_str()) == Some(path)
            })?
            .get("tokens_per_s")?
            .as_f64()
    };

    let mut points = Vec::new();
    let mut prev_speedups = Vec::new();
    for (variant, path, secs) in &rows {
        let tps = t as f64 / secs;
        let mut p = Json::obj()
            .set("variant", variant.as_str())
            .set("path", path.as_str())
            .set("secs_per_prompt", *secs)
            .set("tokens_per_s", tps);
        if let Some(old) = prev_tps(variant, path) {
            p = p.set("previous_tokens_per_s", old);
            prev_speedups.push(
                Json::obj()
                    .set("variant", variant.as_str())
                    .set("path", path.as_str())
                    .set("speedup", tps / old),
            );
        }
        points.push(p);
    }
    let speedup_json: Vec<Json> = speedups
        .iter()
        .map(|(v, s)| Json::obj().set("variant", v.as_str()).set("speedup_vs_token_by_token", *s))
        .collect();
    let stack_json: Vec<Json> = stack_rows
        .iter()
        .map(|(v, secs)| {
            Json::obj()
                .set("variant", v.as_str())
                .set("layers", stack_layers)
                .set("tokens_per_s", t as f64 / secs)
        })
        .collect();
    // headline acceptance numbers: the serving-path chunkwise-vs-token
    // speedup, and the scoring throughput the sequential outputs unlock
    let headline = speedups
        .iter()
        .find(|(v, _)| v == "loglinear_mamba2")
        .map(|(_, s)| *s)
        .unwrap_or(0.0);
    let mut doc = Json::obj()
        .set("bench", "prefill_throughput")
        .set("quick", quick)
        .set("gemm_threads", tensor::current_gemm_threads())
        .set("heads", heads)
        .set("dk", dk)
        .set("dv", dv)
        .set("chunk", c)
        .set("prompt_tokens", t)
        .set("speedup_vs_token_by_token", headline)
        .set("simd_dispatch", simd_mode)
        .set("simd_speedup_vs_scalar", simd_speedup_vs_scalar)
        .set("score_tokens_per_s", score_tps)
        .set("score_speedup_vs_token_by_token", score_speedup)
        .set("score_prompt_tokens", s_t)
        .set("prefill_tokens_saved_per_request", saved_per_request)
        .set("ttft_speedup_vs_cold", ttft_speedup)
        .set(
            "prefix_cache",
            Json::obj()
                .set("shared_prefix_tokens", sys_len)
                .set("requests_per_wave", n_req)
                .set("prefix_cache_hits", w2_hits)
                .set("prefill_tokens_saved_per_request", saved_per_request)
                .set("ttft_cold_secs", ttft_cold)
                .set("ttft_hit_secs", ttft_hit)
                .set("ttft_speedup_vs_cold", ttft_speedup),
        )
        .set(
            "flop_accounting",
            Json::obj()
                .set(
                    "per_token",
                    Json::Arr(
                        fl_lengths
                            .iter()
                            .zip(&fl_per_token)
                            .map(|(&tt, &f)| {
                                Json::obj().set("prompt_tokens", tt).set("flops_per_token", f)
                            })
                            .collect(),
                    ),
                )
                .set("log2_slope", fl_b)
                .set("fit_r2", fl_r2),
        )
        .set("workspace_bytes_shared", ws_bytes as f64)
        .set("workspace_bytes_saved_per_extra_prompt", ws_bytes as f64)
        .set("points", Json::Arr(points))
        .set("sequential", Json::Arr(stack_json))
        .set("chunkwise_speedup", Json::Arr(speedup_json));
    if !prev_speedups.is_empty() {
        doc = doc.set("speedup_vs_previous", Json::Arr(prev_speedups));
    }
    match std::fs::write(OUT_PATH, doc.pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH}"),
        Err(e) => eprintln!("\nfailed to write {OUT_PATH}: {e}"),
    }
}
