//! Prompt-ingestion throughput: chunkwise prefill (the new
//! `loglinear::prefill` subsystem — head-batched state-only Alg. 1 +
//! export bridge) vs the token-by-token recurrent path the serving engine
//! used before (one `PooledFenwickState` advance + λ-read per token per
//! head, which is what feeding prompt tokens through the decode step
//! costs, minus the logits GEMM).
//!
//! Run: `cargo bench --bench prefill_throughput [-- --quick] [--threads N]`
//!
//! Emits `BENCH_prefill.json` (prompt tokens/s for both paths and both
//! log-linear variants, with the chunkwise-vs-token speedup — the ≥5×
//! acceptance number — and previous-run deltas in the style of
//! `BENCH_decode.json`). Before timing, both ingestion paths are advanced
//! one probe token and their reads compared within the chunkwise
//! tolerance, so the speedup is only reported for equivalent states.

use loglinear::bench::{bench, section};
use loglinear::prefill::bridge::export_prefill_head;
use loglinear::prefill::PrefillEngine;
use loglinear::state::pool::StatePool;
use loglinear::state::pooled::PooledFenwickState;
use loglinear::state::Transition;
use loglinear::tensor::{self, Mat};
use loglinear::util::json::Json;
use loglinear::util::Rng;

const OUT_PATH: &str = "BENCH_prefill.json";

struct Fixture {
    heads: usize,
    dk: usize,
    dv: usize,
    c: usize,
    t: usize,
    /// per-head inputs, (T, d) each; keys L2-normalized
    ks: Vec<Mat>,
    vs: Vec<Mat>,
    qs: Vec<Mat>,
    /// per-chunk stacked (H, C, d) views for the engine
    kc: Vec<Vec<f32>>,
    vc: Vec<Vec<f32>>,
    alpha: Vec<f32>,
    beta: Vec<f32>,
    lambda: Vec<f32>,
}

fn build(heads: usize, dk: usize, dv: usize, c: usize, t: usize) -> Fixture {
    let mut rng = Rng::new(0x9F11);
    let mut ks = Vec::new();
    let mut vs = Vec::new();
    let mut qs = Vec::new();
    for _ in 0..heads {
        let mut k = Mat::randn(t, dk, 1.0, &mut rng);
        for i in 0..t {
            let n = loglinear::tensor::ops::l2_norm(k.row(i)).max(1e-6);
            for x in k.row_mut(i) {
                *x /= n;
            }
        }
        ks.push(k);
        vs.push(Mat::randn(t, dv, 1.0, &mut rng));
        qs.push(Mat::randn(t, dk, 1.0, &mut rng));
    }
    let mut kc = Vec::new();
    let mut vc = Vec::new();
    for z in 0..t / c {
        let mut kz = Vec::with_capacity(heads * c * dk);
        let mut vz = Vec::with_capacity(heads * c * dv);
        for h in 0..heads {
            kz.extend_from_slice(ks[h].rows_data(z * c, (z + 1) * c));
            vz.extend_from_slice(vs[h].rows_data(z * c, (z + 1) * c));
        }
        kc.push(kz);
        vc.push(vz);
    }
    let alpha: Vec<f32> = (0..t).map(|_| rng.range_f32(0.99, 1.0)).collect();
    let beta: Vec<f32> = (0..t).map(|_| rng.range_f32(0.1, 0.9)).collect();
    let lambda: Vec<f32> = (0..24).map(|l| 0.5f32.powi(l)).collect();
    Fixture { heads, dk, dv, c, t, ks, vs, qs, kc, vc, alpha, beta, lambda }
}

impl Fixture {
    fn transition(&self, gdn: bool, h: usize, t: usize) -> Transition<'_> {
        if gdn {
            Transition::GatedHouseholder {
                alpha: self.alpha[t],
                beta: self.beta[t],
                k: self.ks[h].row(t),
            }
        } else {
            Transition::Decay(self.alpha[t])
        }
    }

    fn write_scale(&self, gdn: bool, t: usize) -> f32 {
        if gdn {
            self.beta[t]
        } else {
            1.0
        }
    }

    /// The old serving path: every prompt token through the recurrent
    /// advance + λ-read, per head.
    fn ingest_token_by_token(&self, gdn: bool, pool: &mut StatePool) -> Vec<PooledFenwickState> {
        let mut out = Vec::with_capacity(self.heads);
        let mut o = vec![0.0f32; self.dv];
        for h in 0..self.heads {
            let mut seq = PooledFenwickState::new(self.dk, self.dv);
            for t in 0..self.t {
                seq.advance(
                    pool,
                    self.ks[h].row(t),
                    self.vs[h].row(t),
                    self.write_scale(gdn, t),
                    self.transition(gdn, h, t),
                )
                .expect("pool sized for the trace");
                seq.read_into(pool, self.qs[h].row(t), &self.lambda, &mut o);
                std::hint::black_box(&o);
            }
            out.push(seq);
        }
        out
    }

    /// The new path: full chunks through the head-batched engine, then
    /// the export bridge into pool blocks (state-only — the serving
    /// prefill never reads).
    fn ingest_chunkwise(&self, gdn: bool, pool: &mut StatePool) -> Vec<PooledFenwickState> {
        let mut eng = PrefillEngine::new(self.heads, self.dk, self.dv, self.c);
        for z in 0..self.t / self.c {
            let (s, e) = (z * self.c, (z + 1) * self.c);
            if gdn {
                eng.ingest_chunk_gdn(&self.kc[z], &self.vc[z], &self.alpha[s..e], &self.beta[s..e]);
            } else {
                eng.ingest_chunk_mamba2(&self.kc[z], &self.vc[z], &self.alpha[s..e], None);
            }
        }
        eng.finish();
        (0..self.heads)
            .map(|h| export_prefill_head(&eng, h, pool).expect("pool sized for export"))
            .collect()
    }

    /// Both paths must agree: advance one probe token past the boundary
    /// on each and compare the λ-reads within the chunkwise tolerance.
    fn assert_equivalent(&self, gdn: bool, pool: &mut StatePool) {
        let mut a = self.ingest_token_by_token(gdn, pool);
        let mut b = self.ingest_chunkwise(gdn, pool);
        let probe_t = self.t - 1; // reuse the last token as the probe
        for h in 0..self.heads {
            for (seq, path) in [(&mut a[h], "token"), (&mut b[h], "chunkwise")] {
                let o = seq
                    .step(
                        pool,
                        self.qs[h].row(probe_t),
                        self.ks[h].row(probe_t),
                        self.vs[h].row(probe_t),
                        self.write_scale(gdn, probe_t),
                        self.transition(gdn, h, probe_t),
                        &self.lambda,
                    )
                    .unwrap_or_else(|e| panic!("{path} probe step: {e}"));
                std::hint::black_box(o);
            }
        }
        // re-run the probe on fresh clones is overkill; compare directly
        let mut oa = vec![0.0f32; self.dv];
        let mut ob = vec![0.0f32; self.dv];
        for h in 0..self.heads {
            a[h].read_into(pool, self.qs[h].row(0), &self.lambda, &mut oa);
            b[h].read_into(pool, self.qs[h].row(0), &self.lambda, &mut ob);
            for j in 0..self.dv {
                // looser than the unit tests' 2e-3: 4k-token cumulative
                // decay products accumulate ~T·ε of relative f32 error
                assert!(
                    (oa[j] - ob[j]).abs() < 1e-3 + 1e-2 * ob[j].abs(),
                    "gdn={gdn} head={h} j={j}: chunkwise prefill diverged ({} vs {})",
                    ob[j],
                    oa[j]
                );
            }
        }
        for mut seq in a {
            seq.release(pool);
        }
        for mut seq in b {
            seq.release(pool);
        }
        assert_eq!(pool.in_use(), 0);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    if let Some(pos) = args.iter().position(|a| a == "--threads") {
        if let Some(n) = args.get(pos + 1).and_then(|s| s.parse::<usize>().ok()) {
            tensor::gemm_threads(n);
        }
    }

    let (heads, dk, dv, c, t) = (4usize, 64usize, 64usize, 64usize, 4096usize);
    let fx = build(heads, dk, dv, c, t);
    let variants: &[bool] = if quick { &[false] } else { &[false, true] };

    section(&format!(
        "prompt ingestion: chunkwise prefill vs token-by-token (H={heads}, dk=dv={dk}, C={c}, T={t}, gemm_threads={})",
        tensor::current_gemm_threads()
    ));

    // (variant, path, secs_per_ingest)
    let mut rows: Vec<(String, String, f64)> = Vec::new();
    for &gdn in variants {
        let variant = if gdn { "loglinear_gdn" } else { "loglinear_mamba2" };
        let mut pool = StatePool::new(dk * dv, heads * 16);
        fx.assert_equivalent(gdn, &mut pool);

        let r = bench(&format!("token-by-token/{variant}"), 0.3, || {
            let seqs = fx.ingest_token_by_token(gdn, &mut pool);
            for mut seq in seqs {
                seq.release(&mut pool);
            }
        });
        rows.push((variant.into(), "token_by_token".into(), r.secs.mean));

        let r = bench(&format!("chunkwise prefill/{variant}"), 0.3, || {
            let seqs = fx.ingest_chunkwise(gdn, &mut pool);
            for mut seq in seqs {
                seq.release(&mut pool);
            }
        });
        rows.push((variant.into(), "chunkwise".into(), r.secs.mean));
    }

    section("prompt tokens/s and chunkwise speedup");
    println!("{:>18} {:>18} {:>18} {:>10}", "variant", "token-by-token", "chunkwise", "speedup");
    let mut speedups: Vec<(String, f64)> = Vec::new();
    for &gdn in variants {
        let variant = if gdn { "loglinear_gdn" } else { "loglinear_mamba2" };
        let get = |path: &str| {
            rows.iter()
                .find(|(v, p, _)| v == variant && p == path)
                .map(|(_, _, s)| *s)
                .unwrap()
        };
        let tok_s = t as f64 / get("token_by_token");
        let chunk_s = t as f64 / get("chunkwise");
        let speedup = chunk_s / tok_s;
        println!("{variant:>18} {tok_s:>14.0} t/s {chunk_s:>14.0} t/s {speedup:>9.2}x");
        speedups.push((variant.into(), speedup));
    }

    // ---- machine-readable record (BENCH_prefill.json) ----
    let previous = std::fs::read_to_string(OUT_PATH)
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    let prev_tps = |variant: &str, path: &str| -> Option<f64> {
        previous
            .as_ref()?
            .get("points")?
            .as_arr()?
            .iter()
            .find(|p| {
                p.get("variant").and_then(|s| s.as_str()) == Some(variant)
                    && p.get("path").and_then(|s| s.as_str()) == Some(path)
            })?
            .get("tokens_per_s")?
            .as_f64()
    };

    let mut points = Vec::new();
    let mut prev_speedups = Vec::new();
    for (variant, path, secs) in &rows {
        let tps = t as f64 / secs;
        let mut p = Json::obj()
            .set("variant", variant.as_str())
            .set("path", path.as_str())
            .set("secs_per_prompt", *secs)
            .set("tokens_per_s", tps);
        if let Some(old) = prev_tps(variant, path) {
            p = p.set("previous_tokens_per_s", old);
            prev_speedups.push(
                Json::obj()
                    .set("variant", variant.as_str())
                    .set("path", path.as_str())
                    .set("speedup", tps / old),
            );
        }
        points.push(p);
    }
    let speedup_json: Vec<Json> = speedups
        .iter()
        .map(|(v, s)| Json::obj().set("variant", v.as_str()).set("speedup_vs_token_by_token", *s))
        .collect();
    // headline acceptance number: the serving-path (log-linear Mamba-2,
    // the PooledBackend variant) chunkwise-vs-token-by-token speedup
    let headline = speedups
        .iter()
        .find(|(v, _)| v == "loglinear_mamba2")
        .map(|(_, s)| *s)
        .unwrap_or(0.0);
    let mut doc = Json::obj()
        .set("bench", "prefill_throughput")
        .set("quick", quick)
        .set("gemm_threads", tensor::current_gemm_threads())
        .set("heads", heads)
        .set("dk", dk)
        .set("dv", dv)
        .set("chunk", c)
        .set("prompt_tokens", t)
        .set("speedup_vs_token_by_token", headline)
        .set("points", Json::Arr(points))
        .set("chunkwise_speedup", Json::Arr(speedup_json));
    if !prev_speedups.is_empty() {
        doc = doc.set("speedup_vs_previous", Json::Arr(prev_speedups));
    }
    match std::fs::write(OUT_PATH, doc.pretty()) {
        Ok(()) => println!("\nwrote {OUT_PATH}"),
        Err(e) => eprintln!("\nfailed to write {OUT_PATH}: {e}"),
    }
}
