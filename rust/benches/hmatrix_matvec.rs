//! E10 — structured-matrix substrate bench + App. B.4 ablation:
//! quasi-hierarchical matvec is O(T log T) vs dense O(T^2), and strong
//! admissibility costs a constant factor more than weak for marginal
//! benefit (the paper measured ~4x in Triton and chose weak).
//!
//! Run: `cargo bench --bench hmatrix_matvec`

use loglinear::bench::{bench, section};
use loglinear::fenwick;
use loglinear::hmatrix::hodlr::{Admissibility, Hodlr};
use loglinear::hmatrix::QuasiH;
use loglinear::tensor::Mat;
use loglinear::util::stats::scaling_exponent;
use loglinear::util::Rng;

fn main() {
    section("QuasiH (M^S ⊙ M^H) matvec: fast O(T log T) vs dense O(T^2)");
    let mut fast_pts = Vec::new();
    let mut dense_pts = Vec::new();
    for &t in &[512usize, 1024, 2048, 4096, 8192] {
        let mut rng = Rng::new(t as u64);
        let alpha: Vec<f32> = (0..t).map(|_| rng.range_f32(0.85, 1.0)).collect();
        let lambda = Mat::rand_uniform(t, fenwick::num_levels(t), 0.05, 1.0, &mut rng);
        let q = QuasiH::new(&alpha, &lambda);
        let x: Vec<f32> = (0..t).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let r = bench(&format!("quasi-fast/T={t}"), 0.3, || {
            std::hint::black_box(q.matvec(&x));
        });
        fast_pts.push((t, r.secs.mean));
        if t <= 4096 {
            let d = q.dense();
            let r = bench(&format!("quasi-dense/T={t}"), 0.3, || {
                std::hint::black_box(d.matvec(&x));
            });
            dense_pts.push((t, r.secs.mean));
        }
    }
    let pf = scaling_exponent(
        &fast_pts.iter().map(|(t, _)| *t).collect::<Vec<_>>(),
        &fast_pts.iter().map(|(_, s)| *s).collect::<Vec<_>>(),
    );
    let pd = scaling_exponent(
        &dense_pts.iter().map(|(t, _)| *t).collect::<Vec<_>>(),
        &dense_pts.iter().map(|(_, s)| *s).collect::<Vec<_>>(),
    );
    println!("\n  scaling: fast ~ T^{pf:.2} (expect ~1), dense ~ T^{pd:.2} (expect ~2)");

    section("App. B.4 ablation: weak vs strong admissibility (HODLR)");
    println!(
        "{:>6} {:>14} {:>14} {:>8} | {:>12} {:>12}",
        "n", "weak flops", "strong flops", "ratio", "weak us", "strong us"
    );
    for &n in &[128usize, 256, 512] {
        let mut rng = Rng::new(n as u64);
        let r: Vec<f32> = (0..n).map(|_| rng.range_f32(0.5, 1.5)).collect();
        let c: Vec<f32> = (0..n).map(|_| rng.range_f32(0.5, 1.5)).collect();
        let a = Mat::from_fn(n, n, |i, j| r[i] * c[j] + if i == j { 1.0 } else { 0.0 });
        let hw = Hodlr::from_dense(&a, 16, 2, Admissibility::Weak);
        let hs = Hodlr::from_dense(&a, 16, 2, Admissibility::Strong);
        let x: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let tw = bench(&format!("weak/{n}"), 0.2, || {
            std::hint::black_box(hw.matvec(&x));
        });
        let ts = bench(&format!("strong/{n}"), 0.2, || {
            std::hint::black_box(hs.matvec(&x));
        });
        println!(
            "{:>6} {:>14} {:>14} {:>8.2} | {:>12.2} {:>12.2}",
            n,
            hw.matvec_flops(),
            hs.matvec_flops(),
            hs.matvec_flops() as f64 / hw.matvec_flops() as f64,
            tw.secs.mean * 1e6,
            ts.secs.mean * 1e6,
        );
    }
    println!("\n  paper: strong admissibility was ~4x slower for marginal accuracy — weak chosen.");
}
