//! Minimal dense-tensor substrate (no BLAS/ndarray available offline).
//!
//! The attention zoo ([`crate::attention`]), the hierarchical-matrix module
//! ([`crate::hmatrix`]), and the benches all run on [`Mat`]: a row-major
//! `f32` matrix with cache-friendly matmul kernels. Accumulation is f32
//! with an ikj loop order that autovectorizes well; for oracle comparisons
//! the tests use tolerance-based closeness, and `allclose` reports the
//! worst absolute/relative deviation.

pub mod ops;

use crate::util::Rng;

/// A row-major `rows x cols` matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len(), "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    pub fn rand_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_uniform(&mut m.data, lo, hi);
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Contiguous sub-matrix copy: rows [r0, r1), all columns.
    pub fn rows_slice(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// `self @ other` — (m,k) x (k,n). ikj order for row-major locality.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for (p, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[p * n..(p + 1) * n];
                for (o, &b) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self @ other^T` — (m,k) x (n,k) -> (m,n). Dot-product form: both
    /// operands are traversed row-wise, the fastest kernel for QK^T.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for j in 0..n {
                let b_row = &other.data[j * k..(j + 1) * k];
                out_row[j] = dot(a_row, b_row);
            }
        }
        out
    }

    /// `self^T @ other` — (k,m) x (k,n) -> (m,n). Used for K^T V state writes.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for p in 0..k {
            let a_row = &self.data[p * m..(p + 1) * m];
            let b_row = &other.data[p * n..(p + 1) * n];
            for i in 0..m {
                let a = a_row[i];
                if a == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[i * n..(i + 1) * n];
                for (o, &b) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// out = self + other
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (o, &b) in out.data.iter_mut().zip(other.data.iter()) {
            *o += b;
        }
        out
    }

    /// self += scale * other
    pub fn axpy(&mut self, scale: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (o, &b) in self.data.iter_mut().zip(other.data.iter()) {
            *o += scale * b;
        }
    }

    /// self *= s (in place)
    pub fn scale_inplace(&mut self, s: f32) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    pub fn scale(&self, s: f32) -> Mat {
        let mut out = self.clone();
        out.scale_inplace(s);
        out
    }

    /// Elementwise (Hadamard) product.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (o, &b) in out.data.iter_mut().zip(other.data.iter()) {
            *o *= b;
        }
        out
    }

    /// Matrix–vector product `self @ x`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// `self^T @ x`.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, x.len());
        let mut out = vec![0.0f32; self.cols];
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += xi * a;
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |a - b| over entries.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Dot product with 4-way unrolled accumulation (autovectorizes).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// outer-product accumulate: `state += v k^T` where state is (dv, dk).
#[inline]
pub fn outer_acc(state: &mut Mat, v: &[f32], k: &[f32], scale: f32) {
    debug_assert_eq!(state.rows, v.len());
    debug_assert_eq!(state.cols, k.len());
    let dk = k.len();
    for (i, &vi) in v.iter().enumerate() {
        let row = &mut state.data[i * dk..(i + 1) * dk];
        let s = vi * scale;
        for (r, &kj) in row.iter_mut().zip(k.iter()) {
            *r += s * kj;
        }
    }
}

/// Closeness check with combined absolute/relative tolerance; returns the
/// worst offender on failure for debuggable assertions.
pub fn allclose(a: &Mat, b: &Mat, atol: f32, rtol: f32) -> Result<(), String> {
    if (a.rows, a.cols) != (b.rows, b.cols) {
        return Err(format!(
            "shape mismatch: ({},{}) vs ({},{})",
            a.rows, a.cols, b.rows, b.cols
        ));
    }
    let mut worst = 0.0f32;
    let mut worst_idx = 0usize;
    for (i, (&x, &y)) in a.data.iter().zip(b.data.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        let d = (x - y).abs();
        if d > tol && d - tol > worst {
            worst = d - tol;
            worst_idx = i;
        }
    }
    if worst > 0.0 {
        let (i, j) = (worst_idx / a.cols, worst_idx % a.cols);
        return Err(format!(
            "allclose failed at ({},{}): {} vs {} (excess {:.3e})",
            i, j, a.data[worst_idx], b.data[worst_idx], worst
        ));
    }
    Ok(())
}

/// Assert two matrices are close (panics with diagnostics).
pub fn assert_close(a: &Mat, b: &Mat, atol: f32, rtol: f32) {
    if let Err(e) = allclose(a, b, atol, rtol) {
        panic!("{e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(7, 5, 1.0, &mut rng);
        let b = Mat::randn(5, 9, 1.0, &mut rng);
        let c1 = a.matmul(&b);
        let c2 = a.matmul_nt(&b.transpose());
        let c3 = a.transpose().matmul_tn(&b);
        assert_close(&c1, &c2, 1e-5, 1e-5);
        assert_close(&c1, &c3, 1e-5, 1e-5);
    }

    #[test]
    fn matvec_agrees_with_matmul() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(6, 4, 1.0, &mut rng);
        let x: Vec<f32> = (0..4).map(|i| i as f32).collect();
        let y = a.matvec(&x);
        let xm = Mat::from_vec(4, 1, x.clone());
        let ym = a.matmul(&xm);
        for i in 0..6 {
            assert!((y[i] - ym.data[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn matvec_t_agrees() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(6, 4, 1.0, &mut rng);
        let x: Vec<f32> = (0..6).map(|i| (i as f32) * 0.5).collect();
        let y = a.matvec_t(&x);
        let yt = a.transpose().matvec(&x);
        for i in 0..4 {
            assert!((y[i] - yt[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(5, 8, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn outer_acc_matches_matmul() {
        let v = vec![1.0f32, 2.0];
        let k = vec![3.0f32, 4.0, 5.0];
        let mut s = Mat::zeros(2, 3);
        outer_acc(&mut s, &v, &k, 2.0);
        let expect = Mat::from_vec(2, 3, vec![6.0, 8.0, 10.0, 12.0, 16.0, 20.0]);
        assert_eq!(s, expect);
    }

    #[test]
    fn allclose_reports_worst() {
        let a = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Mat::from_vec(1, 2, vec![1.0, 2.5]);
        let err = allclose(&a, &b, 1e-3, 0.0).unwrap_err();
        assert!(err.contains("(0,1)"), "{err}");
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(4, 4, 1.0, &mut rng);
        assert_close(&a.matmul(&Mat::eye(4)), &a, 1e-6, 0.0);
        assert_close(&Mat::eye(4).matmul(&a), &a, 1e-6, 0.0);
    }

    #[test]
    fn rows_slice_copies() {
        let a = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f32);
        let s = a.rows_slice(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.row(0), &[3.0, 4.0, 5.0]);
        assert_eq!(s.row(1), &[6.0, 7.0, 8.0]);
    }
}
