//! Minimal dense-tensor substrate (no BLAS/ndarray available offline).
//!
//! The attention zoo ([`crate::attention`]), the hierarchical-matrix module
//! ([`crate::hmatrix`]), and the benches all run on [`Mat`]: a row-major
//! `f32` matrix backed by cache-blocked, multi-threaded GEMM kernels.
//!
//! # Blocking scheme
//!
//! All three dense GEMM layouts (`A@B`, `A@B^T`, `A^T@B`) reduce to the
//! slice-level kernels [`gemm_into`], [`gemm_nt_into`], [`gemm_tn_into`]:
//!
//! - **NN** (`A@B`): ikj loop order, with the k-dimension tiled into
//!   panels of [`KC`] so the touched rows of `B` stay resident in L1/L2
//!   while a row block of `A` streams past. The innermost j-loop is the
//!   8-wide unrolled [`axpy8`] microkernel over contiguous rows — no
//!   branches, so it autovectorizes. (The old per-element
//!   `if a == 0.0 { continue }` shortcut defeated vectorization on dense
//!   operands; it now lives only in [`gemm_sparse_rows`], used by the
//!   masked paths that really contain structural zeros.)
//! - **NT** (`A@B^T`): pure dot-product form — both operands are
//!   traversed row-wise, the natural kernel for `QK^T`. Uses [`dot`]
//!   (8 independent accumulators via `chunks_exact`).
//! - **TN** (`A^T@B`): rank-1-update form, p outermost; within a row
//!   block, the `B` row is reused across all output rows.
//!
//! # Threading model
//!
//! Output rows are partitioned into contiguous row blocks, one block per
//! worker of the process-resident pool
//! ([`crate::util::threadpool::par_row_chunks_pooled`] dispatching to
//! [`crate::util::threadpool::resident_pool`] — no transient thread
//! spawns per kernel). Blocks are disjoint slices of the output, so
//! workers share nothing mutable and need no synchronization. Every
//! output element is reduced by exactly one worker in a fixed sequential
//! k-order, and the partition depends only on the requested thread count
//! (not on pool size or scheduling), so results are **bit-for-bit
//! identical** for any thread count — see
//! `threaded_gemm_is_deterministic`. The thread count comes from the
//! [`gemm_threads`] knob (0 = one per core); kernels below
//! [`PAR_FLOP_THRESHOLD`] flops stay single-threaded so the queue handoff
//! never dominates tiny products.
//!
//! Accumulation is f32; for oracle comparisons the tests use
//! tolerance-based closeness, and `allclose` reports the worst
//! absolute/relative deviation.

pub mod batch;
pub mod half;
pub mod ops;
#[cfg(feature = "simd")]
pub mod simd;

pub use batch::{gemm_batch_into, gemm_nt_batch_into, gemm_tn_diag_batch_acc, slab_block_dispatch};

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::util::threadpool::par_row_chunks_pooled;
use crate::util::Rng;

/// k-panel depth for the NN kernel: KC rows of B (KC × n floats) are
/// streamed per panel; 256 keeps the panel within L2 for n ≲ 1k.
const KC: usize = 256;

/// Below this many flops (2·m·k·n) a GEMM stays single-threaded: even a
/// resident-pool handoff (~1µs) only amortizes on larger products.
const PAR_FLOP_THRESHOLD: usize = 1 << 18;

/// Requested GEMM worker count; 0 = auto (one per available core).
static GEMM_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Set the number of worker threads the GEMM kernels may use; `0`
/// restores the default (one per available core). Benches use this to
/// compare 1-thread vs N-thread kernels. Results are bit-for-bit
/// identical across settings (see module docs on determinism).
pub fn gemm_threads(n: usize) {
    GEMM_THREADS.store(n, Ordering::Relaxed);
}

/// The currently effective GEMM thread count.
pub fn current_gemm_threads() -> usize {
    match GEMM_THREADS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

/// Worker count for a (m,k,n) product: 1 below the flop threshold, else
/// the knob value capped so every worker amortizes at least one
/// threshold's worth of flops (a barely-threaded GEMM must not fan out
/// to a full core count) and by the output row count.
fn plan_threads(m: usize, k: usize, n: usize) -> usize {
    let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
    if flops < PAR_FLOP_THRESHOLD {
        return 1;
    }
    let by_work = flops / PAR_FLOP_THRESHOLD; // >= 1 here
    current_gemm_threads().min(by_work).clamp(1, m.max(1))
}

// ---------------------------------------------------------------------------
// Determinism sentinel. Every thread-count-invariance promise in this
// module reduces to one fact: a (rows, rows_per_block) dispatch is ALWAYS
// the same contiguous in-order tiling [0,b), [b,2b), …, [.., rows), so
// each output row is written by exactly one worker with a fixed k-order.
// `partition_signature` pins that contract as an FNV-1a hash of the
// block boundaries; the row-block dispatcher
// (`crate::util::threadpool::par_row_chunks_pooled`) hashes the
// partition it actually realizes and debug-asserts equality. A refactor
// that reorders or resizes blocks (work stealing, dynamic splits) trips
// the sentinel instead of silently changing summation order.
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a over realized `(r0, r1)` row-block boundaries.
pub struct PartitionSig(u64);

impl PartitionSig {
    pub fn new() -> PartitionSig {
        PartitionSig(FNV_OFFSET)
    }

    /// Fold one block's global row range, in dispatch order.
    pub fn fold(&mut self, r0: usize, r1: usize) {
        for v in [r0 as u64, r1 as u64] {
            // Hash whole u64s (not bytes): boundaries are row indices
            // and the sentinel only needs order/coverage sensitivity.
            self.0 ^= v;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for PartitionSig {
    fn default() -> Self {
        PartitionSig::new()
    }
}

/// The pinned row partition for a `rows`-row output tiled in
/// `rows_per_block`-row blocks: contiguous, in order, last block ragged.
/// This is the *contract*; the dispatcher must realize exactly this.
pub fn partition_signature(rows: usize, rows_per_block: usize) -> u64 {
    assert!(rows_per_block > 0);
    let mut sig = PartitionSig::new();
    let mut r0 = 0usize;
    while r0 < rows {
        let r1 = rows.min(r0 + rows_per_block);
        sig.fold(r0, r1);
        r0 = r1;
    }
    sig.finish()
}

/// The GEMM microkernel: `out_row += a * b_row`. Dispatches to the AVX2
/// kernel when `--features simd` is on and the CPU supports it
/// ([`simd::active`]), otherwise runs the scalar oracle
/// [`axpy8_scalar`]. The two are bit-exact (see `tensor/simd.rs` module
/// docs), so dispatch never changes results — only throughput.
// xtask: deny_alloc
#[inline(always)]
pub fn axpy8(out_row: &mut [f32], b_row: &[f32], a: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd::active() {
        simd::axpy8(out_row, b_row, a);
        return;
    }
    axpy8_scalar(out_row, b_row, a);
}

/// Scalar oracle for [`axpy8`]: 8-wide unrolled via `chunks_exact` so
/// the eight mul/adds autovectorize. Kept public so the SIMD
/// equivalence tests and the pre-bench bit-exactness assertions can
/// reach it regardless of dispatch state.
// xtask: deny_alloc
#[inline(always)]
pub fn axpy8_scalar(out_row: &mut [f32], b_row: &[f32], a: f32) {
    debug_assert_eq!(out_row.len(), b_row.len());
    let n8 = out_row.len() - out_row.len() % 8;
    let (c8, cr) = out_row.split_at_mut(n8);
    let (b8, br) = b_row.split_at(n8);
    for (c, b) in c8.chunks_exact_mut(8).zip(b8.chunks_exact(8)) {
        c[0] += a * b[0];
        c[1] += a * b[1];
        c[2] += a * b[2];
        c[3] += a * b[3];
        c[4] += a * b[4];
        c[5] += a * b[5];
        c[6] += a * b[6];
        c[7] += a * b[7];
    }
    for (c, b) in cr.iter_mut().zip(br.iter()) {
        *c += a * b;
    }
}

// ---------------------------------------------------------------------------
// Slice-level GEMM kernels. `a`, `b`, `out` are row-major; `out` covers
// rows [r0, r1) of the logical output with *local* indexing (row r0 is
// out[0..n]) so a parallel row block can pass its own sub-slice.
// ---------------------------------------------------------------------------

/// One output row × one KC-deep B panel: `out_row += Σ_dp coeffs[dp] *
/// b_panel[dp*n..]`, `dp` ascending. This is the packed row-block kernel
/// of the NN-family GEMMs — under `--features simd` the whole panel goes
/// to [`simd::nn_panel`] (each 8-wide output strip held in a register
/// across the panel), otherwise it replays as sequential scalar axpys.
/// Both orders are per-element identical, so the paths are bit-exact.
// xtask: deny_alloc
#[inline(always)]
fn nn_panel_row(out_row: &mut [f32], b_panel: &[f32], n: usize, coeffs: &[f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        const _: () = assert!(KC <= simd::PANEL_MAX);
        if simd::active() {
            simd::nn_panel(out_row, b_panel, n, coeffs);
            return;
        }
    }
    for (dp, &c) in coeffs.iter().enumerate() {
        axpy8_scalar(out_row, &b_panel[dp * n..(dp + 1) * n], c);
    }
}

// xtask: deny_alloc
fn block_nn(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize, r0: usize, r1: usize) {
    for p0 in (0..k).step_by(KC) {
        let p1 = (p0 + KC).min(k);
        for i in r0..r1 {
            let a_row = &a[i * k + p0..i * k + p1];
            let out_row = &mut out[(i - r0) * n..(i - r0 + 1) * n];
            nn_panel_row(out_row, &b[p0 * n..p1 * n], n, a_row);
        }
    }
}

// xtask: deny_alloc
#[allow(clippy::too_many_arguments)]
fn block_nn_diag(
    a: &[f32],
    b: &[f32],
    w: &[f32],
    out: &mut [f32],
    k: usize,
    n: usize,
    r0: usize,
    r1: usize,
) {
    // Staged per-row coefficients (`wi * av`, same single multiply the
    // scalar loop performed) so the weighted kernel rides the same
    // packed panel path as `block_nn`. Stack buffer — no allocation.
    let mut coeffs = [0f32; KC];
    for p0 in (0..k).step_by(KC) {
        let p1 = (p0 + KC).min(k);
        for i in r0..r1 {
            let wi = w[i];
            let a_row = &a[i * k + p0..i * k + p1];
            let out_row = &mut out[(i - r0) * n..(i - r0 + 1) * n];
            for (c, &av) in coeffs.iter_mut().zip(a_row.iter()) {
                *c = wi * av;
            }
            nn_panel_row(out_row, &b[p0 * n..p1 * n], n, &coeffs[..a_row.len()]);
        }
    }
}

// xtask: deny_alloc
fn block_nt(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize, r0: usize, r1: usize) {
    for i in r0..r1 {
        let a_row = &a[i * k..(i + 1) * k];
        let out_row = &mut out[(i - r0) * n..(i - r0 + 1) * n];
        for (j, o) in out_row.iter_mut().enumerate() {
            *o += dot(a_row, &b[j * k..(j + 1) * k]);
        }
    }
}

// xtask: deny_alloc
#[allow(clippy::too_many_arguments)]
fn block_tn(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize, r0: usize, r1: usize) {
    for p in 0..k {
        let a_row = &a[p * m..(p + 1) * m];
        let b_row = &b[p * n..(p + 1) * n];
        for i in r0..r1 {
            axpy8(&mut out[(i - r0) * n..(i - r0 + 1) * n], b_row, a_row[i]);
        }
    }
}

// xtask: deny_alloc
#[allow(clippy::too_many_arguments)]
fn block_tn_diag(
    a: &[f32],
    b: &[f32],
    w: &[f32],
    out: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
    r0: usize,
    r1: usize,
) {
    for p in 0..k {
        let wp = w[p];
        let a_row = &a[p * m..(p + 1) * m];
        let b_row = &b[p * n..(p + 1) * n];
        for i in r0..r1 {
            axpy8(&mut out[(i - r0) * n..(i - r0 + 1) * n], b_row, wp * a_row[i]);
        }
    }
}

// xtask: deny_alloc
fn block_sparse(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize, r0: usize, r1: usize) {
    for i in r0..r1 {
        let a_row = &a[i * k..(i + 1) * k];
        let out_row = &mut out[(i - r0) * n..(i - r0 + 1) * n];
        for (p, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            axpy8(out_row, &b[p * n..(p + 1) * n], av);
        }
    }
}

/// Kernel flop/byte attribution for one `(m,k)·(k,n)` GEMM dispatch —
/// the obs hook every entry point below reports through (2·m·k·n flops,
/// operand + output traffic in bytes; `gemm_sparse_rows` reports its
/// dense upper bound). One relaxed atomic load when tracing is off.
// xtask: deny_alloc
#[inline]
fn account_gemm(m: usize, k: usize, n: usize) {
    crate::obs::account_flops(
        2 * (m as u64) * (k as u64) * (n as u64),
        4 * ((m * k) as u64 + (k * n) as u64 + (m * n) as u64),
    );
}

/// `out (+)= A @ B` on raw row-major slices: `a` is (m,k), `b` (k,n),
/// `out` (m,n). With `accumulate = false` the output is overwritten.
/// Blocked + threaded per the module docs.
// xtask: deny_alloc
pub fn gemm_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32], accumulate: bool) {
    assert_eq!(a.len(), m * k, "gemm a shape");
    assert_eq!(b.len(), k * n, "gemm b shape");
    assert_eq!(out.len(), m * n, "gemm out shape");
    if !accumulate {
        out.fill(0.0);
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    account_gemm(m, k, n);
    let threads = plan_threads(m, k, n);
    if threads <= 1 {
        block_nn(a, b, out, k, n, 0, m);
    } else {
        par_row_chunks_pooled(out, n, m.div_ceil(threads), |r0, r1, chunk| {
            block_nn(a, b, chunk, k, n, r0, r1)
        });
    }
}

/// `out (+)= A @ B^T`: `a` is (m,k), `b` (n,k), `out` (m,n). The `QK^T`
/// kernel: both operands traversed row-wise.
// xtask: deny_alloc
pub fn gemm_nt_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32], accumulate: bool) {
    assert_eq!(a.len(), m * k, "gemm_nt a shape");
    assert_eq!(b.len(), n * k, "gemm_nt b shape");
    assert_eq!(out.len(), m * n, "gemm_nt out shape");
    if !accumulate {
        out.fill(0.0);
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    account_gemm(m, k, n);
    let threads = plan_threads(m, k, n);
    if threads <= 1 {
        block_nt(a, b, out, k, n, 0, m);
    } else {
        par_row_chunks_pooled(out, n, m.div_ceil(threads), |r0, r1, chunk| {
            block_nt(a, b, chunk, k, n, r0, r1)
        });
    }
}

/// `out (+)= A^T @ B`: `a` is (k,m), `b` (k,n), `out` (m,n). The `K^T V`
/// state-write kernel.
// xtask: deny_alloc
pub fn gemm_tn_into(k: usize, m: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32], accumulate: bool) {
    assert_eq!(a.len(), k * m, "gemm_tn a shape");
    assert_eq!(b.len(), k * n, "gemm_tn b shape");
    assert_eq!(out.len(), m * n, "gemm_tn out shape");
    if !accumulate {
        out.fill(0.0);
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    account_gemm(m, k, n);
    let threads = plan_threads(m, k, n);
    if threads <= 1 {
        block_tn(a, b, out, k, m, n, 0, m);
    } else {
        par_row_chunks_pooled(out, n, m.div_ceil(threads), |r0, r1, chunk| {
            block_tn(a, b, chunk, k, m, n, r0, r1)
        });
    }
}

/// Fused `out += diag(w) · (A @ B)`: row `i` of the product is scaled by
/// `w[i]` as it accumulates (the decay-weighted inter-chunk read, done
/// without materializing the product).
// xtask: deny_alloc
pub fn gemm_diag_acc(m: usize, k: usize, n: usize, w: &[f32], a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(w.len(), m, "gemm_diag_acc w shape");
    assert_eq!(a.len(), m * k, "gemm_diag_acc a shape");
    assert_eq!(b.len(), k * n, "gemm_diag_acc b shape");
    assert_eq!(out.len(), m * n, "gemm_diag_acc out shape");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    account_gemm(m, k, n);
    let threads = plan_threads(m, k, n);
    if threads <= 1 {
        block_nn_diag(a, b, w, out, k, n, 0, m);
    } else {
        par_row_chunks_pooled(out, n, m.div_ceil(threads), |r0, r1, chunk| {
            block_nn_diag(a, b, w, chunk, k, n, r0, r1)
        });
    }
}

/// Fused `out += A^T diag(w) B`: `a` is (k,m), `b` (k,n), `w` length k.
/// Batched outer-product accumulate — the decay-weighted chunk state
/// write `Σ_p w[p] · a_p b_p^T` as one kernel.
// xtask: deny_alloc
pub fn gemm_tn_diag_acc(k: usize, m: usize, n: usize, w: &[f32], a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(w.len(), k, "gemm_tn_diag_acc w shape");
    assert_eq!(a.len(), k * m, "gemm_tn_diag_acc a shape");
    assert_eq!(b.len(), k * n, "gemm_tn_diag_acc b shape");
    assert_eq!(out.len(), m * n, "gemm_tn_diag_acc out shape");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    account_gemm(m, k, n);
    let threads = plan_threads(m, k, n);
    if threads <= 1 {
        block_tn_diag(a, b, w, out, k, m, n, 0, m);
    } else {
        par_row_chunks_pooled(out, n, m.div_ceil(threads), |r0, r1, chunk| {
            block_tn_diag(a, b, w, chunk, k, m, n, r0, r1)
        });
    }
}

/// `out (+)= A @ B` skipping zero entries of `A` — the sparsity shortcut
/// for *masked* operands (lower-triangular attention weights, λ-masked
/// local attention) where ~half the entries are structural zeros. Dense
/// operands should use [`gemm_into`]: the branch defeats vectorization.
// xtask: deny_alloc
pub fn gemm_sparse_rows(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32], accumulate: bool) {
    assert_eq!(a.len(), m * k, "gemm_sparse_rows a shape");
    assert_eq!(b.len(), k * n, "gemm_sparse_rows b shape");
    assert_eq!(out.len(), m * n, "gemm_sparse_rows out shape");
    if !accumulate {
        out.fill(0.0);
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    account_gemm(m, k, n);
    let threads = plan_threads(m, k, n);
    if threads <= 1 {
        block_sparse(a, b, out, k, n, 0, m);
    } else {
        par_row_chunks_pooled(out, n, m.div_ceil(threads), |r0, r1, chunk| {
            block_sparse(a, b, chunk, k, n, r0, r1)
        });
    }
}

/// A row-major `rows x cols` matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len(), "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    pub fn rand_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_uniform(&mut m.data, lo, hi);
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Borrow of the row-major data for rows [r0, r1) — a zero-copy view
    /// for the slice-level GEMM kernels.
    #[inline]
    pub fn rows_data(&self, r0: usize, r1: usize) -> &[f32] {
        debug_assert!(r0 <= r1 && r1 <= self.rows);
        &self.data[r0 * self.cols..r1 * self.cols]
    }

    /// Mutable counterpart of [`rows_data`](Mat::rows_data).
    #[inline]
    pub fn rows_data_mut(&mut self, r0: usize, r1: usize) -> &mut [f32] {
        debug_assert!(r0 <= r1 && r1 <= self.rows);
        let c = self.cols;
        &mut self.data[r0 * c..r1 * c]
    }

    /// Contiguous sub-matrix copy: rows [r0, r1), all columns.
    pub fn rows_slice(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// `self @ other` — (m,k) x (k,n). Blocked + threaded dense kernel.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        gemm_into(self.rows, self.cols, other.cols, &self.data, &other.data, &mut out.data, false);
        out
    }

    /// `out = self @ other` into an existing buffer (no allocation).
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul_into shape mismatch");
        assert_eq!((out.rows, out.cols), (self.rows, other.cols), "matmul_into out shape");
        gemm_into(self.rows, self.cols, other.cols, &self.data, &other.data, &mut out.data, false);
    }

    /// `self @ other` where rows of `self` are mostly structural zeros
    /// (masked attention weights): keeps the zero-skip shortcut that the
    /// dense kernel dropped.
    pub fn matmul_sparse_rows(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul_sparse_rows shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        gemm_sparse_rows(self.rows, self.cols, other.cols, &self.data, &other.data, &mut out.data, false);
        out
    }

    /// `self @ other^T` — (m,k) x (n,k) -> (m,n). Dot-product form: both
    /// operands are traversed row-wise, the fastest kernel for QK^T.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let mut out = Mat::zeros(self.rows, other.rows);
        gemm_nt_into(self.rows, self.cols, other.rows, &self.data, &other.data, &mut out.data, false);
        out
    }

    /// `self^T @ other` — (k,m) x (k,n) -> (m,n). Used for K^T V state writes.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn shape mismatch");
        let mut out = Mat::zeros(self.cols, other.cols);
        gemm_tn_into(self.rows, self.cols, other.cols, &self.data, &other.data, &mut out.data, false);
        out
    }

    /// out = self + other
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (o, &b) in out.data.iter_mut().zip(other.data.iter()) {
            *o += b;
        }
        out
    }

    /// self += scale * other
    pub fn axpy(&mut self, scale: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        axpy8(&mut self.data, &other.data, scale);
    }

    /// self *= s (in place)
    pub fn scale_inplace(&mut self, s: f32) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    pub fn scale(&self, s: f32) -> Mat {
        let mut out = self.clone();
        out.scale_inplace(s);
        out
    }

    /// Elementwise (Hadamard) product.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (o, &b) in out.data.iter_mut().zip(other.data.iter()) {
            *o *= b;
        }
        out
    }

    /// Matrix–vector product `self @ x`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// `self^T @ x`.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        self.matvec_t_acc(x, 1.0, &mut out);
        out
    }

    /// `out += scale * self^T x` — the zero-alloc fused read used by the
    /// decode-time Fenwick state machine (one pass, no temporary).
    pub fn matvec_t_acc(&self, x: &[f32], scale: f32, out: &mut [f32]) {
        assert_eq!(self.rows, x.len());
        assert_eq!(self.cols, out.len());
        matvec_t_acc_slice(&self.data, self.cols, x, scale, out);
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |a - b| over entries.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// `out += diag(w) · (a @ b)` on [`Mat`]s — see [`gemm_diag_acc`].
pub fn scaled_matmul_acc(out: &mut Mat, w: &[f32], a: &Mat, b: &Mat) {
    assert_eq!(a.cols, b.rows, "scaled_matmul_acc shape mismatch");
    assert_eq!((out.rows, out.cols), (a.rows, b.cols), "scaled_matmul_acc out shape");
    gemm_diag_acc(a.rows, a.cols, b.cols, w, &a.data, &b.data, &mut out.data);
}

/// `out += scale * S^T x` for a row-major `(x.len(), cols)` slice `s` —
/// THE weighted-accumulate primitive of the decode read path. Every
/// consumer ([`Mat::matvec_t_acc`], the per-sequence
/// `attention::loglinear::level_read_acc`, the pooled batched decoder,
/// and the Householder `k^T S` pass) delegates here, so the bit-exactness
/// guarantees between those paths survive any future change to this one
/// op sequence (e.g. a SIMD microkernel).
#[inline]
pub fn matvec_t_acc_slice(s: &[f32], cols: usize, x: &[f32], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(s.len(), x.len() * cols);
    debug_assert_eq!(out.len(), cols);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd::active() {
        simd::matvec_t_acc(s, cols, x, scale, out);
        return;
    }
    matvec_t_acc_slice_scalar(s, cols, x, scale, out);
}

/// Scalar oracle for [`matvec_t_acc_slice`]: one axpy per state row,
/// coefficient `scale * x[i]` — the exact op sequence the SIMD
/// strip-major kernel must reproduce per element.
// xtask: deny_alloc
#[inline]
pub fn matvec_t_acc_slice_scalar(s: &[f32], cols: usize, x: &[f32], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(s.len(), x.len() * cols);
    debug_assert_eq!(out.len(), cols);
    for (i, &xi) in x.iter().enumerate() {
        axpy8_scalar(out, &s[i * cols..(i + 1) * cols], scale * xi);
    }
}

/// bf16-storage variant of [`matvec_t_acc_slice`]: `s` holds the state
/// block as bf16 bits; every element is widened to f32 on the fly and
/// the accumulation runs entirely at f32 (widening is exact, so the
/// only precision loss in the read path is whatever narrowing produced
/// the stored block — see docs/PRECISION.md). Row loop and per-element
/// order match the f32 scalar oracle.
// xtask: deny_alloc
#[inline]
pub fn matvec_t_acc_slice_bf16(s: &[u16], cols: usize, x: &[f32], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(s.len(), x.len() * cols);
    debug_assert_eq!(out.len(), cols);
    for (i, &xi) in x.iter().enumerate() {
        let a = scale * xi;
        let row = &s[i * cols..(i + 1) * cols];
        for (o, &h) in out.iter_mut().zip(row.iter()) {
            *o += a * half::bf16_to_f32(h);
        }
    }
}

/// Dot product. Dispatches like [`axpy8`]: AVX2 kernel when available
/// and enabled, scalar oracle otherwise — bit-exact either way.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd::active() {
        return simd::dot(a, b);
    }
    dot_scalar(a, b)
}

/// Scalar oracle for [`dot`]: 8 independent accumulators over
/// `chunks_exact(8)` blocks (autovectorizes to wide lanes) and a pinned
/// reduction tree — the SIMD kernel reproduces both exactly.
// xtask: deny_alloc
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n8 = a.len() - a.len() % 8;
    let (a8, ar) = a.split_at(n8);
    let (b8, br) = b.split_at(n8);
    let mut acc = [0.0f32; 8];
    for (x, y) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
        acc[4] += x[4] * y[4];
        acc[5] += x[5] * y[5];
        acc[6] += x[6] * y[6];
        acc[7] += x[7] * y[7];
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for (x, y) in ar.iter().zip(br.iter()) {
        s += x * y;
    }
    s
}

/// outer-product accumulate: `state += v k^T` where state is (dv, dk).
#[inline]
pub fn outer_acc(state: &mut Mat, v: &[f32], k: &[f32], scale: f32) {
    debug_assert_eq!(state.rows, v.len());
    debug_assert_eq!(state.cols, k.len());
    let dk = k.len();
    for (i, &vi) in v.iter().enumerate() {
        axpy8(&mut state.data[i * dk..(i + 1) * dk], k, vi * scale);
    }
}

/// Closeness check with combined absolute/relative tolerance; returns the
/// worst offender on failure for debuggable assertions.
pub fn allclose(a: &Mat, b: &Mat, atol: f32, rtol: f32) -> Result<(), String> {
    if (a.rows, a.cols) != (b.rows, b.cols) {
        return Err(format!(
            "shape mismatch: ({},{}) vs ({},{})",
            a.rows, a.cols, b.rows, b.cols
        ));
    }
    let mut worst = 0.0f32;
    let mut worst_idx = 0usize;
    for (i, (&x, &y)) in a.data.iter().zip(b.data.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        let d = (x - y).abs();
        if d > tol && d - tol > worst {
            worst = d - tol;
            worst_idx = i;
        }
    }
    if worst > 0.0 {
        let (i, j) = (worst_idx / a.cols, worst_idx % a.cols);
        return Err(format!(
            "allclose failed at ({},{}): {} vs {} (excess {:.3e})",
            i, j, a.data[worst_idx], b.data[worst_idx], worst
        ));
    }
    Ok(())
}

/// Assert two matrices are close (panics with diagnostics).
pub fn assert_close(a: &Mat, b: &Mat, atol: f32, rtol: f32) {
    if let Err(e) = allclose(a, b, atol, rtol) {
        panic!("{e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_signature_pins_order_and_coverage() {
        // Folding the realized chunks of a ragged tiling reproduces the
        // contract signature…
        let mut sig = PartitionSig::new();
        for (r0, r1) in [(0usize, 4usize), (4, 8), (8, 13)] {
            sig.fold(r0, r1);
        }
        assert_eq!(sig.finish(), partition_signature(13, 4));
        // …and any deviation — reordered blocks, a gap, a different
        // block size, a different row count — hashes differently.
        let mut swapped = PartitionSig::new();
        for (r0, r1) in [(4usize, 8usize), (0, 4), (8, 13)] {
            swapped.fold(r0, r1);
        }
        assert_ne!(swapped.finish(), partition_signature(13, 4));
        assert_ne!(partition_signature(13, 4), partition_signature(13, 5));
        assert_ne!(partition_signature(13, 4), partition_signature(12, 4));
        // Exact tilings and single-block tilings are well-defined too.
        assert_eq!(partition_signature(8, 4), {
            let mut s = PartitionSig::new();
            s.fold(0, 4);
            s.fold(4, 8);
            s.finish()
        });
        assert_eq!(partition_signature(3, 64), {
            let mut s = PartitionSig::new();
            s.fold(0, 3);
            s.finish()
        });
    }

    /// Unblocked, untiled, single-threaded triple loop — the reference the
    /// blocked/threaded kernels are checked against.
    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols, b.rows);
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f32;
                for p in 0..a.cols {
                    s += a.at(i, p) * b.at(p, j);
                }
                *out.at_mut(i, j) = s;
            }
        }
        out
    }

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(7, 5, 1.0, &mut rng);
        let b = Mat::randn(5, 9, 1.0, &mut rng);
        let c1 = a.matmul(&b);
        let c2 = a.matmul_nt(&b.transpose());
        let c3 = a.transpose().matmul_tn(&b);
        assert_close(&c1, &c2, 1e-5, 1e-5);
        assert_close(&c1, &c3, 1e-5, 1e-5);
    }

    /// Blocked/threaded GEMM vs the naive loop on ragged shapes: 1x1,
    /// 1xN, odd sizes, k spanning multiple KC panels, and sizes above the
    /// parallel threshold.
    #[test]
    fn blocked_gemm_matches_naive_on_ragged_shapes() {
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 1, 17),
            (1, 9, 1),
            (3, 7, 5),
            (5, 1, 9),
            (17, 13, 11),
            (2, 300, 3), // k crosses a KC panel boundary
            (64, 64, 64),
            (70, 65, 66), // above PAR_FLOP_THRESHOLD, odd everything
        ] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let want = naive_matmul(&a, &b);
            assert_close(&a.matmul(&b), &want, 1e-4, 1e-4);
            assert_close(&a.matmul_nt(&b.transpose()), &want, 1e-4, 1e-4);
            assert_close(&a.transpose().matmul_tn(&b), &want, 1e-4, 1e-4);
            assert_close(&a.matmul_sparse_rows(&b), &want, 1e-4, 1e-4);
            let mut into = Mat::randn(m, n, 1.0, &mut rng); // dirty buffer
            a.matmul_into(&b, &mut into);
            assert_close(&into, &want, 1e-4, 1e-4);
        }
    }

    /// The GEMM is deterministic across thread counts: each output row is
    /// reduced by one thread in a fixed k-order, so 1 thread and 8
    /// threads agree bit-for-bit.
    #[test]
    fn threaded_gemm_is_deterministic() {
        let mut rng = Rng::new(8);
        // big enough to clear PAR_FLOP_THRESHOLD
        let a = Mat::randn(96, 80, 1.0, &mut rng);
        let b = Mat::randn(80, 72, 1.0, &mut rng);
        gemm_threads(1);
        let c1 = a.matmul(&b);
        let t1 = a.transpose().matmul_tn(&b);
        let n1 = a.matmul_nt(&b.transpose());
        gemm_threads(8);
        let c8 = a.matmul(&b);
        let t8 = a.transpose().matmul_tn(&b);
        let n8 = a.matmul_nt(&b.transpose());
        gemm_threads(0); // restore auto
        assert_eq!(c1.data, c8.data, "NN kernel not deterministic across threads");
        assert_eq!(t1.data, t8.data, "TN kernel not deterministic across threads");
        assert_eq!(n1.data, n8.data, "NT kernel not deterministic across threads");
    }

    /// `dot` against an f64 reference on random lengths (covers the
    /// chunks_exact remainder path for every residue mod 8).
    #[test]
    fn dot_matches_f64_reference_property() {
        let mut rng = Rng::new(9);
        for len in 0..64usize {
            let a: Vec<f32> = (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect();
            let want: f64 = a.iter().zip(b.iter()).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = dot(&a, &b) as f64;
            let tol = 1e-4 * (1.0 + want.abs());
            assert!((got - want).abs() < tol, "len={len}: {got} vs {want}");
        }
    }

    #[test]
    fn sparse_rows_matches_dense_on_masked_operand() {
        let mut rng = Rng::new(10);
        let t = 33;
        let mut a = Mat::randn(t, t, 1.0, &mut rng);
        for i in 0..t {
            for j in i + 1..t {
                *a.at_mut(i, j) = 0.0; // lower-triangular mask
            }
        }
        let b = Mat::randn(t, 12, 1.0, &mut rng);
        assert_close(&a.matmul_sparse_rows(&b), &naive_matmul(&a, &b), 1e-4, 1e-4);
    }

    #[test]
    fn scaled_matmul_acc_matches_composition() {
        let mut rng = Rng::new(11);
        let (m, k, n) = (6, 5, 7);
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let w: Vec<f32> = (0..m).map(|_| rng.range_f32(0.1, 2.0)).collect();
        let base = Mat::randn(m, n, 1.0, &mut rng);
        let mut out = base.clone();
        scaled_matmul_acc(&mut out, &w, &a, &b);
        let mut want = base.clone();
        let prod = a.matmul(&b);
        for i in 0..m {
            for j in 0..n {
                *want.at_mut(i, j) += w[i] * prod.at(i, j);
            }
        }
        assert_close(&out, &want, 1e-4, 1e-4);
    }

    #[test]
    fn gemm_tn_diag_acc_matches_outer_products() {
        let mut rng = Rng::new(12);
        let (kdim, m, n) = (9, 6, 7);
        let a = Mat::randn(kdim, m, 1.0, &mut rng); // rows a_p
        let b = Mat::randn(kdim, n, 1.0, &mut rng); // rows b_p
        let w: Vec<f32> = (0..kdim).map(|_| rng.range_f32(0.1, 2.0)).collect();
        let mut out = Mat::zeros(m, n);
        gemm_tn_diag_acc(kdim, m, n, &w, &a.data, &b.data, &mut out.data);
        let mut want = Mat::zeros(m, n);
        for p in 0..kdim {
            outer_acc(&mut want, a.row(p), b.row(p), w[p]);
        }
        assert_close(&out, &want, 1e-4, 1e-4);
    }

    #[test]
    fn matvec_agrees_with_matmul() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(6, 4, 1.0, &mut rng);
        let x: Vec<f32> = (0..4).map(|i| i as f32).collect();
        let y = a.matvec(&x);
        let xm = Mat::from_vec(4, 1, x.clone());
        let ym = a.matmul(&xm);
        for i in 0..6 {
            assert!((y[i] - ym.data[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn matvec_t_agrees() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(6, 4, 1.0, &mut rng);
        let x: Vec<f32> = (0..6).map(|i| (i as f32) * 0.5).collect();
        let y = a.matvec_t(&x);
        let yt = a.transpose().matvec(&x);
        for i in 0..4 {
            assert!((y[i] - yt[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn matvec_t_acc_accumulates_scaled() {
        let mut rng = Rng::new(13);
        let a = Mat::randn(5, 4, 1.0, &mut rng);
        let x: Vec<f32> = (0..5).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut out = vec![1.0f32; 4];
        a.matvec_t_acc(&x, 0.5, &mut out);
        let plain = a.matvec_t(&x);
        for i in 0..4 {
            assert!((out[i] - (1.0 + 0.5 * plain[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(5, 8, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn outer_acc_matches_matmul() {
        let v = vec![1.0f32, 2.0];
        let k = vec![3.0f32, 4.0, 5.0];
        let mut s = Mat::zeros(2, 3);
        outer_acc(&mut s, &v, &k, 2.0);
        let expect = Mat::from_vec(2, 3, vec![6.0, 8.0, 10.0, 12.0, 16.0, 20.0]);
        assert_eq!(s, expect);
    }

    #[test]
    fn allclose_reports_worst() {
        let a = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Mat::from_vec(1, 2, vec![1.0, 2.5]);
        let err = allclose(&a, &b, 1e-3, 0.0).unwrap_err();
        assert!(err.contains("(0,1)"), "{err}");
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(4, 4, 1.0, &mut rng);
        assert_close(&a.matmul(&Mat::eye(4)), &a, 1e-6, 0.0);
        assert_close(&Mat::eye(4).matmul(&a), &a, 1e-6, 0.0);
    }

    #[test]
    fn rows_slice_copies() {
        let a = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f32);
        let s = a.rows_slice(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.row(0), &[3.0, 4.0, 5.0]);
        assert_eq!(s.row(1), &[6.0, 7.0, 8.0]);
    }

    #[test]
    fn dispatched_kernels_match_scalar_oracles_bitwise() {
        // Whatever path `axpy8`/`dot`/`matvec_t_acc_slice` dispatch to
        // (scalar always; AVX2 when `--features simd` is on and the CPU
        // has it) must be bit-identical with the scalar oracle. With the
        // feature off this pins dispatcher == oracle; with it on it is
        // the kernel-level half of the SIMD equivalence contract.
        let mut rng = Rng::new(0x51D1);
        for n in [0usize, 1, 5, 8, 13, 16, 31, 64, 65] {
            let mut b = vec![0f32; n];
            rng.fill_uniform(&mut b, -2.0, 2.0);
            let mut want = vec![0f32; n];
            rng.fill_uniform(&mut want, -1.0, 1.0);
            let mut got = want.clone();
            axpy8_scalar(&mut want, &b, -1.375);
            axpy8(&mut got, &b, -1.375);
            assert!(got.iter().zip(&want).all(|(g, w)| g.to_bits() == w.to_bits()), "axpy8 n={n}");

            let mut x = vec![0f32; n];
            rng.fill_uniform(&mut x, -2.0, 2.0);
            assert_eq!(dot(&x, &b).to_bits(), dot_scalar(&x, &b).to_bits(), "dot n={n}");

            for rows in [0usize, 1, 3, 9] {
                let mut s = vec![0f32; rows * n];
                rng.fill_uniform(&mut s, -2.0, 2.0);
                let mut xs = vec![0f32; rows];
                rng.fill_uniform(&mut xs, -2.0, 2.0);
                let mut mw = vec![0f32; n];
                rng.fill_uniform(&mut mw, -1.0, 1.0);
                let mut mg = mw.clone();
                matvec_t_acc_slice_scalar(&s, n, &xs, 0.5, &mut mw);
                matvec_t_acc_slice(&s, n, &xs, 0.5, &mut mg);
                assert!(
                    mg.iter().zip(&mw).all(|(g, w)| g.to_bits() == w.to_bits()),
                    "matvec rows={rows} n={n}"
                );
            }
        }
    }

    #[test]
    fn gemm_entry_points_bit_exact_across_dispatch_paths() {
        // GEMM-level half of the SIMD contract: the blocked entry points
        // produce bit-identical outputs whether dispatch takes the SIMD
        // or the forced-scalar path, at every thread count. With the
        // `simd` feature off both runs take the scalar path and this
        // degenerates to a determinism re-check — still worth pinning.
        let force = |on: bool| {
            #[cfg(feature = "simd")]
            simd::set_forced_scalar(on);
            let _ = on;
        };
        let mut rng = Rng::new(0x51D2);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (2, 300, 3), (7, 8, 9), (70, 65, 66)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let bt = b.transpose();
            let at = a.transpose();
            let mut w = vec![0f32; m];
            rng.fill_uniform(&mut w, -1.0, 1.0);
            for threads in [1usize, 2, 8] {
                gemm_threads(threads);
                let run = |scalar_only: bool| {
                    force(scalar_only);
                    let mut nn = vec![0f32; m * n];
                    gemm_into(m, k, n, &a.data, &b.data, &mut nn, false);
                    let mut nt = vec![0f32; m * n];
                    gemm_nt_into(m, k, n, &a.data, &bt.data, &mut nt, false);
                    let mut tn = vec![0f32; m * n];
                    gemm_tn_into(k, m, n, &at.data, &b.data, &mut tn, false);
                    let mut diag = vec![0f32; m * n];
                    gemm_diag_acc(m, k, n, &w, &a.data, &b.data, &mut diag);
                    force(false);
                    (nn, nt, tn, diag)
                };
                let simd_out = run(false);
                let scalar_out = run(true);
                let pairs = [
                    (&simd_out.0, &scalar_out.0, "nn"),
                    (&simd_out.1, &scalar_out.1, "nt"),
                    (&simd_out.2, &scalar_out.2, "tn"),
                    (&simd_out.3, &scalar_out.3, "diag"),
                ];
                for (g, want, tag) in pairs {
                    assert!(
                        g.iter().zip(want.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                        "{tag} differs scalar-vs-dispatch at ({m},{k},{n}) threads={threads}"
                    );
                }
            }
        }
        gemm_threads(0);
    }

    /// Satellite lock for the single-threaded inline guarantee: with
    /// `gemm_threads(1)` no GEMM entry point and no slab dispatch ever
    /// enters the resident pool, whichever kernel layer (scalar or SIMD)
    /// sits underneath — SIMD dispatch lives *below* blocking and thread
    /// planning, so it cannot reintroduce a pool hop. Verified with the
    /// per-thread [`crate::util::threadpool::scope_dispatch_count`]
    /// observable, in both forced-scalar and dispatched modes, on a shape
    /// large enough that granted threads genuinely would dispatch.
    #[test]
    fn single_threaded_config_never_enters_the_resident_pool() {
        use crate::util::threadpool::{resident_pool, scope_dispatch_count};
        let force = |on: bool| {
            #[cfg(feature = "simd")]
            simd::set_forced_scalar(on);
            let _ = on;
        };
        let mut rng = Rng::new(0x51D3);
        // comfortably above PAR_FLOP_THRESHOLD, so this shape WOULD
        // thread if threads were granted
        let (m, k, n) = (70usize, 65, 66);
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let bt = b.transpose();
        let at = a.transpose();
        let mut out = vec![0.0f32; m * n];
        let mut slab = vec![0.0f32; 64 * 32];
        let blocks: Vec<usize> = (0..64).collect();
        for forced_scalar in [false, true] {
            force(forced_scalar);
            gemm_threads(1);
            let c0 = scope_dispatch_count();
            gemm_into(m, k, n, &a.data, &b.data, &mut out, false);
            gemm_nt_into(m, k, n, &a.data, &bt.data, &mut out, false);
            gemm_tn_into(m, k, n, &at.data, &b.data, &mut out, false);
            batch::slab_block_dispatch(&mut slab, 32, &blocks, 1, |_j, blk| {
                for x in blk.iter_mut() {
                    *x += 1.0;
                }
            });
            assert_eq!(
                scope_dispatch_count(),
                c0,
                "single-threaded config entered the resident pool (forced_scalar {forced_scalar})"
            );
            // prove the observable bites: the same shape dispatches once
            // threads are granted (only visible with >1 resident worker)
            if resident_pool().size() > 1 {
                gemm_threads(8);
                gemm_into(m, k, n, &a.data, &b.data, &mut out, false);
                assert!(
                    scope_dispatch_count() > c0,
                    "threaded run on a parallel-worthy shape never dispatched \
                     (forced_scalar {forced_scalar})"
                );
            }
        }
        force(false);
        gemm_threads(0);
    }
}
