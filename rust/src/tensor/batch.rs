//! Head-batched GEMM dispatch (the multi-head batching item of the
//! ROADMAP): run `batch` independent same-shape GEMM problems through
//! **one** pooled row-block dispatch instead of `batch` separate kernel
//! launches.
//!
//! A multi-head chunkwise step issues the same per-chunk product once per
//! head (`Q_c S_cat`, `K_c^T diag(w) V_c`, `Φ S` …) with head-specific
//! operands, so no single dense GEMM can cover all heads without an H×
//! zero-padding waste. What *can* be shared is the scheduling: the stacked
//! output `(batch·m, n)` is partitioned into contiguous row blocks exactly
//! like a single `(batch·m, k, n)` product would be, each worker resolves
//! the heads its rows intersect, and the per-head inner kernels are the
//! same `block_*` microkernels the dense entry points use. The effective
//! product the thread planner sees is therefore **widened by `batch`**:
//! a per-chunk product too small to amortize a dispatch on its own
//! (`plan_threads` would run it inline) crosses the threshold once H heads
//! ride in one call, and a chunk's worth of per-head GEMMs pays one queue
//! handoff total instead of H.
//!
//! Determinism: each output row is reduced by exactly one worker in the
//! same sequential k-order as the single-problem kernels, so every batched
//! entry point is **bit-exact** with `batch` separate calls to its dense
//! counterpart, for any thread count (asserted by the tests below).

use super::{block_nn, block_nt, block_tn_diag, plan_threads};
use crate::util::threadpool::par_row_chunks_pooled;
#[cfg(not(loom))]
use crate::util::threadpool::resident_pool;

/// Flop/byte accounting for `batch` independent `(m, k, n)` products —
/// the batched analogue of the dense entry points' hook (attributed to
/// the caller's innermost open span; see `crate::obs`).
// xtask: deny_alloc
#[inline]
fn account_batch(batch: usize, m: usize, k: usize, n: usize) {
    crate::obs::account_flops(
        2 * (batch as u64) * (m as u64) * (k as u64) * (n as u64),
        4 * (batch as u64) * ((m * k) as u64 + (k * n) as u64 + (m * n) as u64),
    );
}

/// Dispatch a batch of same-shape row-major problems as one pooled
/// row-block parallel-for over the stacked `(batch·m, n)` output.
/// `kernel(h, lr0, lr1, chunk)` computes rows `[lr0, lr1)` of problem
/// `h`'s output into `chunk` (locally indexed from `lr0`).
fn batch_dispatch<F>(batch: usize, m: usize, n: usize, threads: usize, out: &mut [f32], kernel: F)
where
    F: Fn(usize, usize, usize, &mut [f32]) + Sync,
{
    if threads <= 1 {
        for (h, out_h) in out.chunks_mut(m * n).enumerate() {
            kernel(h, 0, m, out_h);
        }
        return;
    }
    let rows = batch * m;
    par_row_chunks_pooled(out, n, rows.div_ceil(threads), |r0, r1, chunk| {
        // a worker's rows may span several heads: split at head borders
        let (h0, h1) = (r0 / m, (r1 - 1) / m);
        for h in h0..=h1 {
            let lr0 = r0.max(h * m) - h * m;
            let lr1 = r1.min((h + 1) * m) - h * m;
            let sub = &mut chunk[(h * m + lr0 - r0) * n..(h * m + lr1 - r0) * n];
            kernel(h, lr0, lr1, sub);
        }
    });
}

/// `out_h (+)= A_h @ B_h` for `batch` independent problems in one
/// dispatch: `a` is `(batch, m, k)`, `b` `(batch, k, n)`, `out`
/// `(batch, m, n)`, all contiguous row-major stacks. Bit-exact with
/// `batch` calls to [`super::gemm_into`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_batch_into(
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    accumulate: bool,
) {
    assert_eq!(a.len(), batch * m * k, "gemm_batch a shape");
    assert_eq!(b.len(), batch * k * n, "gemm_batch b shape");
    assert_eq!(out.len(), batch * m * n, "gemm_batch out shape");
    if !accumulate {
        out.fill(0.0);
    }
    if batch == 0 || m == 0 || n == 0 || k == 0 {
        return;
    }
    account_batch(batch, m, k, n);
    let threads = plan_threads(batch * m, k, n);
    batch_dispatch(batch, m, n, threads, out, |h, lr0, lr1, sub| {
        block_nn(&a[h * m * k..(h + 1) * m * k], &b[h * k * n..(h + 1) * k * n], sub, k, n, lr0, lr1)
    });
}

/// `out_h (+)= A_h @ B_h^T` for `batch` independent problems in one
/// dispatch: `a` is `(batch, m, k)`, `b` `(batch, n, k)`, `out`
/// `(batch, m, n)`. The head-batched `Q_c K_c^T` kernel. Bit-exact with
/// `batch` calls to [`super::gemm_nt_into`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_batch_into(
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    accumulate: bool,
) {
    assert_eq!(a.len(), batch * m * k, "gemm_nt_batch a shape");
    assert_eq!(b.len(), batch * n * k, "gemm_nt_batch b shape");
    assert_eq!(out.len(), batch * m * n, "gemm_nt_batch out shape");
    if !accumulate {
        out.fill(0.0);
    }
    if batch == 0 || m == 0 || n == 0 || k == 0 {
        return;
    }
    account_batch(batch, m, k, n);
    let threads = plan_threads(batch * m, k, n);
    batch_dispatch(batch, m, n, threads, out, |h, lr0, lr1, sub| {
        block_nt(&a[h * m * k..(h + 1) * m * k], &b[h * n * k..(h + 1) * n * k], sub, k, n, lr0, lr1)
    });
}

/// `out_h += A_h^T diag(w_h) B_h` for `batch` independent problems in one
/// dispatch: `a` is `(batch, k, m)`, `b` `(batch, k, n)`, `w`
/// `(batch, k)`, `out` `(batch, m, n)`. The head-batched
/// `K_c^T diag(w) V_c` chunk-state write. Bit-exact with `batch` calls to
/// [`super::gemm_tn_diag_acc`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn_diag_batch_acc(
    batch: usize,
    k: usize,
    m: usize,
    n: usize,
    w: &[f32],
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
) {
    assert_eq!(w.len(), batch * k, "gemm_tn_diag_batch w shape");
    assert_eq!(a.len(), batch * k * m, "gemm_tn_diag_batch a shape");
    assert_eq!(b.len(), batch * k * n, "gemm_tn_diag_batch b shape");
    assert_eq!(out.len(), batch * m * n, "gemm_tn_diag_batch out shape");
    if batch == 0 || m == 0 || n == 0 || k == 0 {
        return;
    }
    account_batch(batch, m, k, n);
    let threads = plan_threads(batch * m, k, n);
    batch_dispatch(batch, m, n, threads, out, |h, lr0, lr1, sub| {
        block_tn_diag(
            &a[h * k * m..(h + 1) * k * m],
            &b[h * k * n..(h + 1) * k * n],
            &w[h * k..(h + 1) * k],
            sub,
            k,
            m,
            n,
            lr0,
            lr1,
        )
    });
}

/// Dispatch per-block work over a **scattered** subset of a slab's
/// fixed-size blocks as one pooled pass: `blocks` names the slab rows to
/// touch (sorted, strictly increasing — i.e. each block at most once),
/// and `kernel(j, block)` runs once for job `j` on block `blocks[j]`'s
/// `block_elems`-sized slice. Jobs are partitioned into contiguous runs,
/// one resident worker per run, with the slab split at run borders so
/// workers hold disjoint sub-slices (no locks, no unsafe).
///
/// This is the scheduling half of the pool-wide batched Fenwick advance
/// ([`crate::state::batched_advance`]): where [`gemm_batch_into`] batches
/// H same-shape GEMMs over one *contiguous* stacked output, this batches
/// per-block state ops (transition, sentinel write) over the
/// [`crate::state::pool::StatePool`] slab's *allocated* blocks, which are
/// scattered. Each block is touched by exactly one worker running the
/// same per-block primitive as the per-sequence path, so results are
/// bit-exact for any thread count.
///
/// Generic over the slab element type so the same scheduling serves the
/// f32 slab and the bf16 (`u16`-bit) slab of a reduced-precision
/// [`crate::state::pool::StatePool`] — the kernel, not the dispatcher,
/// decides how to widen/narrow (see docs/PRECISION.md).
pub fn slab_block_dispatch<T, F>(
    slab: &mut [T],
    block_elems: usize,
    blocks: &[usize],
    threads: usize,
    kernel: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = blocks.len();
    if n == 0 {
        return;
    }
    debug_assert!(block_elems > 0);
    debug_assert!(
        blocks.windows(2).all(|w| w[0] < w[1]),
        "blocks must be sorted and unique"
    );
    debug_assert!((blocks[n - 1] + 1) * block_elems <= slab.len(), "block out of slab range");
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for (j, &b) in blocks.iter().enumerate() {
            kernel(j, &mut slab[b * block_elems..(b + 1) * block_elems]);
        }
        return;
    }
    let per = n.div_ceil(threads);
    let kernel = &kernel;
    let mut rest: &mut [T] = slab;
    let mut consumed_rows = 0usize;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
    for (run_idx, run) in blocks.chunks(per).enumerate() {
        let (first, last) = (run[0], *run.last().unwrap());
        // skip untouched rows before this run, then carve the run's span
        let tail = std::mem::take(&mut rest);
        let (_, tail) = tail.split_at_mut((first - consumed_rows) * block_elems);
        let (span, tail) = tail.split_at_mut((last + 1 - first) * block_elems);
        rest = tail;
        consumed_rows = last + 1;
        let j0 = run_idx * per;
        jobs.push(Box::new(move || {
            for (lj, &b) in run.iter().enumerate() {
                let s = (b - first) * block_elems;
                kernel(j0 + lj, &mut span[s..s + block_elems]);
            }
        }));
    }
    #[cfg(not(loom))]
    resident_pool().scope(jobs);
    // loom has no process-wide resident pool (no OnceLock double); the
    // dispatch itself is what the models exercise, so run jobs inline.
    #[cfg(loom)]
    for job in jobs {
        job();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{self, Mat};
    use crate::util::Rng;

    /// Every batched entry point against per-problem dense calls, on
    /// shapes below and above the parallel threshold, bit-exact for
    /// 1 and 8 threads.
    #[test]
    fn batched_gemms_match_per_problem_calls_bit_exact() {
        let mut rng = Rng::new(0xBA7C);
        for &(batch, m, k, n) in &[
            (1usize, 3usize, 4usize, 5usize),
            (4, 8, 8, 8),
            (3, 1, 7, 9),
            (8, 33, 64, 40), // crosses PAR_FLOP_THRESHOLD only when batched
            (2, 130, 17, 19),
        ] {
            let a: Vec<f32> = (0..batch * m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..batch * k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let bt: Vec<f32> = (0..batch * n * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let wa: Vec<f32> = (0..batch * k * m).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w: Vec<f32> = (0..batch * k).map(|_| rng.range_f32(0.1, 2.0)).collect();

            let mut want_nn = vec![0.0f32; batch * m * n];
            let mut want_nt = vec![0.0f32; batch * m * n];
            let mut want_tn = vec![0.1f32; batch * m * n];
            for h in 0..batch {
                let o = &mut want_nn[h * m * n..(h + 1) * m * n];
                tensor::gemm_into(m, k, n, &a[h * m * k..(h + 1) * m * k], &b[h * k * n..(h + 1) * k * n], o, false);
                let o = &mut want_nt[h * m * n..(h + 1) * m * n];
                tensor::gemm_nt_into(m, k, n, &a[h * m * k..(h + 1) * m * k], &bt[h * n * k..(h + 1) * n * k], o, false);
                let o = &mut want_tn[h * m * n..(h + 1) * m * n];
                tensor::gemm_tn_diag_acc(
                    k,
                    m,
                    n,
                    &w[h * k..(h + 1) * k],
                    &wa[h * k * m..(h + 1) * k * m],
                    &b[h * k * n..(h + 1) * k * n],
                    o,
                );
            }

            for threads in [1usize, 8] {
                tensor::gemm_threads(threads);
                let mut got = vec![1.0f32; batch * m * n]; // dirty: overwritten
                gemm_batch_into(batch, m, k, n, &a, &b, &mut got, false);
                assert_eq!(got, want_nn, "NN batch={batch} m={m} k={k} n={n} threads={threads}");
                let mut got = vec![1.0f32; batch * m * n];
                gemm_nt_batch_into(batch, m, k, n, &a, &bt, &mut got, false);
                assert_eq!(got, want_nt, "NT batch={batch} m={m} k={k} n={n} threads={threads}");
                let mut got = vec![0.1f32; batch * m * n]; // accumulate onto same base
                gemm_tn_diag_batch_acc(batch, k, m, n, &w, &wa, &b, &mut got);
                assert_eq!(got, want_tn, "TN-diag batch={batch} m={m} k={k} n={n} threads={threads}");
            }
            tensor::gemm_threads(0);
        }
    }

    /// The scattered-block dispatcher touches exactly the named blocks,
    /// hands each job its own block, and is deterministic across thread
    /// counts (each block is owned by one worker).
    #[test]
    fn slab_block_dispatch_covers_each_block_once_any_threads() {
        let (cap, be) = (17usize, 6usize);
        // a scattered, sorted subset of the slab's blocks
        let blocks = [0usize, 2, 3, 7, 11, 12, 16];
        for threads in [1usize, 2, 3, 8] {
            let mut slab = vec![-1.0f32; cap * be];
            slab_block_dispatch(&mut slab, be, &blocks, threads, |j, block| {
                assert_eq!(block.len(), be);
                for (e, x) in block.iter_mut().enumerate() {
                    assert_eq!(*x, -1.0, "block touched twice (job {j})");
                    *x = (j * be + e) as f32;
                }
            });
            for (row, chunk) in slab.chunks(be).enumerate() {
                match blocks.iter().position(|&b| b == row) {
                    Some(j) => {
                        for (e, &x) in chunk.iter().enumerate() {
                            assert_eq!(x, (j * be + e) as f32, "threads={threads} row={row}");
                        }
                    }
                    None => assert!(
                        chunk.iter().all(|&x| x == -1.0),
                        "untouched block {row} was written (threads={threads})"
                    ),
                }
            }
        }
    }

    /// Accumulate mode adds onto the existing output.
    #[test]
    fn batch_accumulate_adds() {
        let mut rng = Rng::new(0xACC);
        let (batch, m, k, n) = (2usize, 3usize, 4usize, 5usize);
        let a = Mat::randn(batch * m, k, 1.0, &mut rng);
        let b = Mat::randn(batch * k, n, 1.0, &mut rng);
        let mut out = vec![2.0f32; batch * m * n];
        gemm_batch_into(batch, m, k, n, &a.data, &b.data, &mut out, true);
        let mut want = vec![0.0f32; batch * m * n];
        gemm_batch_into(batch, m, k, n, &a.data, &b.data, &mut want, false);
        for (o, w) in out.iter().zip(want.iter()) {
            assert!((o - (w + 2.0)).abs() < 1e-5);
        }
    }
}
