//! Elementwise / reduction ops on [`Mat`] and slices used by the attention
//! zoo and the eval harness: softmax, logsumexp, silu/softplus/sigmoid,
//! cross-entropy, argmax.

use super::Mat;

/// Numerically-stable softmax over each row, in place.
pub fn softmax_rows(m: &mut Mat) {
    for i in 0..m.rows {
        softmax_inplace(m.row_mut(i));
    }
}

/// Stable softmax on a slice.
pub fn softmax_inplace(x: &mut [f32]) {
    let mx = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if mx == f32::NEG_INFINITY {
        // All -inf: define as uniform zeros (masked-out row).
        for v in x.iter_mut() {
            *v = 0.0;
        }
        return;
    }
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// log(sum(exp(x))) stable.
pub fn logsumexp(x: &[f32]) -> f32 {
    let mx = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if mx == f32::NEG_INFINITY {
        return f32::NEG_INFINITY;
    }
    let s: f32 = x.iter().map(|&v| (v - mx).exp()).sum();
    mx + s.ln()
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// softplus with linear tail for stability; used for λ parameterization.
#[inline]
pub fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

/// Cross-entropy of a logits row against a target index (natural log).
pub fn cross_entropy(logits: &[f32], target: usize) -> f32 {
    logsumexp(logits) - logits[target]
}

/// Index of max element.
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in x.iter().enumerate() {
        if v > x[best] {
            best = i;
        }
    }
    best
}

/// RMS-normalize a slice with learned gain (used by the Rust-side model).
pub fn rmsnorm(x: &mut [f32], gain: &[f32], eps: f32) {
    debug_assert_eq!(x.len(), gain.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for (v, &g) in x.iter_mut().zip(gain.iter()) {
        *v *= inv * g;
    }
}

/// L2 norm of a slice.
pub fn l2_norm(x: &[f32]) -> f32 {
    x.iter().map(|v| v * v).sum::<f32>().sqrt()
}

/// Solve `L X = B` where `L` is *unit* lower-triangular (diagonal == 1,
/// entries above the diagonal ignored). Forward substitution, O(n^2 m).
/// This is the UT-transform solve of the DeltaNet parallel form.
pub fn solve_unit_lower(l: &Mat, b: &Mat) -> Mat {
    assert_eq!(l.rows, l.cols);
    assert_eq!(l.rows, b.rows);
    let (n, m) = (b.rows, b.cols);
    let mut x = b.clone();
    for i in 0..n {
        for j in 0..i {
            let lij = l.at(i, j);
            if lij == 0.0 {
                continue;
            }
            // x[i] -= l[i][j] * x[j]
            let (head, tail) = x.data.split_at_mut(i * m);
            let xj = &head[j * m..(j + 1) * m];
            let xi = &mut tail[..m];
            for (a, &b_) in xi.iter_mut().zip(xj.iter()) {
                *a -= lij * b_;
            }
        }
    }
    x
}

/// Solve `U X = B` where `U` is *unit* upper-triangular. Back substitution.
pub fn solve_unit_upper(u: &Mat, b: &Mat) -> Mat {
    assert_eq!(u.rows, u.cols);
    assert_eq!(u.rows, b.rows);
    let (n, m) = (b.rows, b.cols);
    let mut x = b.clone();
    for i in (0..n).rev() {
        for j in i + 1..n {
            let uij = u.at(i, j);
            if uij == 0.0 {
                continue;
            }
            let (head, tail) = x.data.split_at_mut(j * m);
            let xi = &mut head[i * m..(i + 1) * m];
            let xj = &tail[..m];
            for (a, &b_) in xi.iter_mut().zip(xj.iter()) {
                *a -= uij * b_;
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut x = vec![1.0f32, 2.0, 3.0];
        softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn softmax_stable_for_large_inputs() {
        let mut x = vec![1000.0f32, 1000.0];
        softmax_inplace(&mut x);
        assert!((x[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn softmax_all_masked_row() {
        let mut x = vec![f32::NEG_INFINITY; 3];
        softmax_inplace(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn logsumexp_matches_naive_in_safe_range() {
        let x = vec![0.1f32, -0.3, 0.7];
        let naive = x.iter().map(|v| v.exp()).sum::<f32>().ln();
        assert!((logsumexp(&x) - naive).abs() < 1e-6);
    }

    #[test]
    fn cross_entropy_of_confident_logits_is_small() {
        let mut logits = vec![0.0f32; 10];
        logits[3] = 20.0;
        assert!(cross_entropy(&logits, 3) < 1e-3);
        assert!(cross_entropy(&logits, 4) > 10.0);
    }

    #[test]
    fn softplus_positive_and_tail() {
        assert!(softplus(-10.0) > 0.0);
        assert!((softplus(30.0) - 30.0).abs() < 1e-6);
        assert!((softplus(0.0) - (2.0f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let mut x = vec![3.0f32, 4.0];
        let gain = vec![1.0f32, 1.0];
        rmsnorm(&mut x, &gain, 1e-6);
        let ms = x.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-4);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
    }

    #[test]
    fn unit_lower_solve_roundtrip() {
        use crate::util::Rng;
        let mut rng = Rng::new(11);
        let n = 12;
        let mut l = Mat::randn(n, n, 0.3, &mut rng);
        for i in 0..n {
            *l.at_mut(i, i) = 1.0;
            for j in i + 1..n {
                *l.at_mut(i, j) = 0.0;
            }
        }
        let b = Mat::randn(n, 5, 1.0, &mut rng);
        let x = solve_unit_lower(&l, &b);
        crate::tensor::assert_close(&l.matmul(&x), &b, 1e-4, 1e-4);
    }

    #[test]
    fn unit_upper_solve_roundtrip() {
        use crate::util::Rng;
        let mut rng = Rng::new(12);
        let n = 12;
        let mut u = Mat::randn(n, n, 0.3, &mut rng);
        for i in 0..n {
            *u.at_mut(i, i) = 1.0;
            for j in 0..i {
                *u.at_mut(i, j) = 0.0;
            }
        }
        let b = Mat::randn(n, 5, 1.0, &mut rng);
        let x = solve_unit_upper(&u, &b);
        crate::tensor::assert_close(&u.matmul(&x), &b, 1e-4, 1e-4);
    }
}
