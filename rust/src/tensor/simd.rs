//! Explicit AVX2 microkernels for the tensor substrate's inner loops
//! (`--features simd`; see docs/PRECISION.md for the feature matrix).
//!
//! Every kernel here is **bit-exact at f32** with its scalar oracle in
//! [`crate::tensor`] (`axpy8_scalar`, `dot_scalar`,
//! `matvec_t_acc_slice_scalar`). That is a hard invariant — the trace
//! harness, the partition-signature determinism sentinel, and the
//! pre-bench assertions all rely on it — and it constrains the
//! implementation in two ways:
//!
//! 1. **No FMA.** The scalar loops round the multiply and the add
//!    separately (`c += a * b` without FP contraction — rustc does not
//!    contract by default), so the vector kernels use
//!    `_mm256_add_ps(acc, _mm256_mul_ps(..))`, never `_mm256_fmadd_ps`,
//!    even though the fused form would be faster and *more* accurate.
//!    The win here is instruction-level parallelism and halved
//!    load/store traffic, not rounding shortcuts.
//! 2. **Same per-element accumulation order.** Each output element must
//!    see the identical sequence of rounded operations as the scalar
//!    path: `dot` keeps the scalar's 8-lane accumulator layout and
//!    reduction tree, and the strip-major kernels walk rows in the same
//!    ascending order the scalar row loop does.
//!
//! Dispatch is runtime-gated: [`active`] caches
//! `is_x86_feature_detected!("avx2")` and honours the
//! [`set_forced_scalar`] override (used by benches to time the scalar
//! path on SIMD-capable hardware, and by tests to exercise both sides
//! of the dispatcher). On non-x86_64 targets `active()` is always
//! `false` and the portable scalar path runs unconditionally.

use std::sync::atomic::{AtomicBool, Ordering};

/// When set, [`active`] reports `false` even on AVX2 hardware, forcing
/// every dispatcher in [`crate::tensor`] down the scalar path. Both
/// paths are bit-exact, so flipping this mid-run never changes results —
/// only which instructions produce them.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Force (or un-force) scalar dispatch. Used by the benches for the
/// `simd_speedup_vs_scalar` headline and by dual-path tests.
pub fn set_forced_scalar(force: bool) {
    FORCE_SCALAR.store(force, Ordering::Relaxed);
}

/// Raw runtime capability: does this machine support the AVX2 kernels?
/// Ignores the forced-scalar override (benches use this to decide
/// whether a speedup headline is meaningful).
#[inline]
pub fn runtime_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        // 0 = unprobed, 1 = unavailable, 2 = available. Probing twice is
        // harmless (same answer), so Relaxed is enough.
        static DETECTED: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(0);
        match DETECTED.load(Ordering::Relaxed) {
            2 => true,
            1 => false,
            _ => {
                let yes = std::is_x86_feature_detected!("avx2");
                DETECTED.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
                yes
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Should the dispatchers take the AVX2 path right now?
// xtask: deny_alloc
#[inline]
pub fn active() -> bool {
    runtime_available() && !FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Maximum panel depth accepted by [`nn_panel`] — matches the GEMM
/// cache-blocking depth `KC` in [`crate::tensor`], so a stack-allocated
/// coefficient buffer of this size always suffices.
pub const PANEL_MAX: usize = 256;

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::{
        __m256, _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps,
        _mm256_storeu_ps,
    };

    /// `out[j] += a * b[j]` over the full slice. Bit-exact with
    /// `axpy8_scalar`: one rounded mul then one rounded add per element,
    /// vector head in 8-wide chunks and a scalar tail, exactly like the
    /// scalar split at `len - len % 8`.
    ///
    /// SAFETY: caller must guarantee AVX2 is available on this CPU and
    /// `out.len() == b.len()`. All memory access is `loadu`/`storeu` on
    /// in-bounds slice elements.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy8(out: &mut [f32], b: &[f32], a: f32) {
        debug_assert_eq!(out.len(), b.len());
        let n = out.len();
        let n8 = n - n % 8;
        // SAFETY: `_mm256_set1_ps` touches no memory.
        let va = unsafe { _mm256_set1_ps(a) };
        let mut j = 0;
        while j < n8 {
            // SAFETY: j + 8 <= n8 <= out.len() == b.len(), so both
            // 8-element loads and the store stay inside the slices.
            unsafe {
                let vb = _mm256_loadu_ps(b.as_ptr().add(j));
                let vo = _mm256_loadu_ps(out.as_ptr().add(j));
                _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_add_ps(vo, _mm256_mul_ps(va, vb)));
            }
            j += 8;
        }
        for (c, &bv) in out[n8..].iter_mut().zip(b[n8..].iter()) {
            *c += a * bv;
        }
    }

    /// Dot product, bit-exact with `dot_scalar`: lane `l` of the vector
    /// accumulator sees elements `l, l+8, l+16, …` — the same partial
    /// sums as the scalar path's 8 named accumulators — and the final
    /// reduction replays the scalar tree
    /// `((a0+a4)+(a1+a5))+((a2+a6)+(a3+a7))` before the scalar tail.
    ///
    /// SAFETY: caller must guarantee AVX2 is available and
    /// `x.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let n8 = n - n % 8;
        // SAFETY: `_mm256_set1_ps` touches no memory.
        let mut acc: __m256 = unsafe { _mm256_set1_ps(0.0) };
        let mut j = 0;
        while j < n8 {
            // SAFETY: j + 8 <= n8 <= x.len() == y.len().
            unsafe {
                let vx = _mm256_loadu_ps(x.as_ptr().add(j));
                let vy = _mm256_loadu_ps(y.as_ptr().add(j));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(vx, vy));
            }
            j += 8;
        }
        let mut lanes = [0f32; 8];
        // SAFETY: `lanes` is exactly 8 f32s, the store is in-bounds.
        unsafe { _mm256_storeu_ps(lanes.as_mut_ptr(), acc) };
        let mut s = ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
            + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]));
        for (xv, yv) in x[n8..].iter().zip(y[n8..].iter()) {
            s += xv * yv;
        }
        s
    }

    /// Strip-major row-panel accumulate:
    /// `out[j] += Σ_p coeffs[p] * b[p*n + j]`, `p` ascending — the same
    /// per-element op sequence as `coeffs.len()` successive scalar axpys,
    /// but each 8-wide output strip stays in a register across the whole
    /// panel, cutting output traffic by the panel depth.
    ///
    /// SAFETY: caller must guarantee AVX2 is available,
    /// `out.len() == n`, and `b.len() >= coeffs.len() * n`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn nn_panel(out: &mut [f32], b: &[f32], n: usize, coeffs: &[f32]) {
        debug_assert_eq!(out.len(), n);
        debug_assert!(b.len() >= coeffs.len() * n);
        let n8 = n - n % 8;
        let mut j = 0;
        while j < n8 {
            // SAFETY: j + 8 <= n8 <= out.len(); for every p the load at
            // p*n + j + 8 <= coeffs.len()*n <= b.len() stays in-bounds.
            unsafe {
                let mut vo = _mm256_loadu_ps(out.as_ptr().add(j));
                for (p, &c) in coeffs.iter().enumerate() {
                    let vb = _mm256_loadu_ps(b.as_ptr().add(p * n + j));
                    vo = _mm256_add_ps(vo, _mm256_mul_ps(_mm256_set1_ps(c), vb));
                }
                _mm256_storeu_ps(out.as_mut_ptr().add(j), vo);
            }
            j += 8;
        }
        for j in n8..n {
            let mut s = out[j];
            for (p, &c) in coeffs.iter().enumerate() {
                s += c * b[p * n + j];
            }
            out[j] = s;
        }
    }

    /// Strip-major `out[j] += Σ_i (scale * x[i]) * s[i*cols + j]`, `i`
    /// ascending — bit-exact with the scalar row loop of
    /// `matvec_t_acc_slice_scalar` (which computes the per-row
    /// coefficient as the single product `scale * x[i]` and then does
    /// mul-then-add per element, exactly as here).
    ///
    /// SAFETY: caller must guarantee AVX2 is available,
    /// `out.len() == cols`, and `s.len() == x.len() * cols`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn matvec_t_acc(s: &[f32], cols: usize, x: &[f32], scale: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), cols);
        debug_assert_eq!(s.len(), x.len() * cols);
        let n8 = cols - cols % 8;
        let mut j = 0;
        while j < n8 {
            // SAFETY: j + 8 <= n8 <= out.len(); for every row i the load
            // at i*cols + j + 8 <= x.len()*cols == s.len() is in-bounds.
            unsafe {
                let mut vo = _mm256_loadu_ps(out.as_ptr().add(j));
                for (i, &xi) in x.iter().enumerate() {
                    let vs = _mm256_loadu_ps(s.as_ptr().add(i * cols + j));
                    vo = _mm256_add_ps(vo, _mm256_mul_ps(_mm256_set1_ps(scale * xi), vs));
                }
                _mm256_storeu_ps(out.as_mut_ptr().add(j), vo);
            }
            j += 8;
        }
        for j in n8..cols {
            let mut acc = out[j];
            for (i, &xi) in x.iter().enumerate() {
                acc += (scale * xi) * s[i * cols + j];
            }
            out[j] = acc;
        }
    }
}

/// `out[j] += a * b[j]`. Caller must have checked [`active`].
// xtask: deny_alloc
#[cfg(target_arch = "x86_64")]
#[inline]
pub fn axpy8(out: &mut [f32], b: &[f32], a: f32) {
    debug_assert!(active());
    debug_assert_eq!(out.len(), b.len());
    // SAFETY: `active()` verified AVX2 is available at runtime; slice
    // lengths are equal per the assert above.
    unsafe { avx2::axpy8(out, b, a) }
}

/// Dot product. Caller must have checked [`active`].
// xtask: deny_alloc
#[cfg(target_arch = "x86_64")]
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert!(active());
    debug_assert_eq!(x.len(), y.len());
    // SAFETY: `active()` verified AVX2 is available at runtime; slice
    // lengths are equal per the assert above.
    unsafe { avx2::dot(x, y) }
}

/// Row-panel accumulate for the packed GEMM kernels. Caller must have
/// checked [`active`] and pass `coeffs.len() <= PANEL_MAX`.
// xtask: deny_alloc
#[cfg(target_arch = "x86_64")]
#[inline]
pub fn nn_panel(out: &mut [f32], b: &[f32], n: usize, coeffs: &[f32]) {
    debug_assert!(active());
    assert!(coeffs.len() <= PANEL_MAX);
    assert_eq!(out.len(), n);
    assert!(b.len() >= coeffs.len() * n);
    // SAFETY: `active()` verified AVX2 is available at runtime; the
    // shape contract (out.len() == n, b holds coeffs.len() rows of n)
    // is asserted above.
    unsafe { avx2::nn_panel(out, b, n, coeffs) }
}

/// Transposed matrix-vector accumulate. Caller must have checked
/// [`active`].
// xtask: deny_alloc
#[cfg(target_arch = "x86_64")]
#[inline]
pub fn matvec_t_acc(s: &[f32], cols: usize, x: &[f32], scale: f32, out: &mut [f32]) {
    debug_assert!(active());
    assert_eq!(out.len(), cols);
    assert_eq!(s.len(), x.len() * cols);
    // SAFETY: `active()` verified AVX2 is available at runtime; the
    // shape contract is asserted above.
    unsafe { avx2::matvec_t_acc(s, cols, x, scale, out) }
}

#[cfg(all(test, target_arch = "x86_64"))]
mod tests {
    use super::*;
    use crate::tensor::{axpy8_scalar, dot_scalar, matvec_t_acc_slice_scalar};
    use crate::util::rng::Rng;

    fn ragged_lens() -> impl Iterator<Item = usize> {
        // Every tail class: empty, sub-vector, exact multiples, and
        // multiples plus each possible remainder.
        (0..=9).chain([15, 16, 17, 23, 24, 25, 31, 32, 33, 40, 63, 64, 65])
    }

    #[test]
    fn axpy8_bit_exact_with_scalar_on_all_tail_classes() {
        if !runtime_available() {
            return;
        }
        let mut rng = Rng::new(0xA2B2);
        for n in ragged_lens() {
            let mut b = vec![0f32; n];
            rng.fill_uniform(&mut b, -2.0, 2.0);
            let mut base = vec![0f32; n];
            rng.fill_uniform(&mut base, -2.0, 2.0);
            for a in [0.0f32, -0.0, 1.0, -1.75, 3.0e-39, 7.25e8] {
                let mut want = base.clone();
                let mut got = base.clone();
                axpy8_scalar(&mut want, &b, a);
                axpy8(&mut got, &b, a);
                for j in 0..n {
                    assert_eq!(got[j].to_bits(), want[j].to_bits(), "n={n} a={a} j={j}");
                }
            }
        }
    }

    #[test]
    fn dot_bit_exact_with_scalar_on_all_tail_classes() {
        if !runtime_available() {
            return;
        }
        let mut rng = Rng::new(0xD07);
        for n in ragged_lens() {
            let mut x = vec![0f32; n];
            let mut y = vec![0f32; n];
            rng.fill_uniform(&mut x, -3.0, 3.0);
            rng.fill_uniform(&mut y, -3.0, 3.0);
            assert_eq!(dot(&x, &y).to_bits(), dot_scalar(&x, &y).to_bits(), "n={n}");
        }
    }

    #[test]
    fn nn_panel_bit_exact_with_sequential_axpys() {
        if !runtime_available() {
            return;
        }
        let mut rng = Rng::new(0x9A9E1);
        for n in ragged_lens() {
            for depth in [0usize, 1, 2, 3, 7, 8, 13] {
                let mut b = vec![0f32; depth * n];
                rng.fill_uniform(&mut b, -1.5, 1.5);
                let mut coeffs = vec![0f32; depth];
                rng.fill_uniform(&mut coeffs, -2.0, 2.0);
                if depth > 2 {
                    coeffs[1] = 0.0; // zero coefficients must still round-trip
                }
                let mut base = vec![0f32; n];
                rng.fill_uniform(&mut base, -1.0, 1.0);
                let mut want = base.clone();
                for (p, &c) in coeffs.iter().enumerate() {
                    axpy8_scalar(&mut want, &b[p * n..(p + 1) * n], c);
                }
                let mut got = base.clone();
                nn_panel(&mut got, &b, n, &coeffs);
                for j in 0..n {
                    assert_eq!(got[j].to_bits(), want[j].to_bits(), "n={n} depth={depth} j={j}");
                }
            }
        }
    }

    #[test]
    fn matvec_t_acc_bit_exact_with_scalar() {
        if !runtime_available() {
            return;
        }
        let mut rng = Rng::new(0x3A7);
        for cols in ragged_lens() {
            for rows in [0usize, 1, 2, 5, 16, 33] {
                let mut s = vec![0f32; rows * cols];
                rng.fill_uniform(&mut s, -2.0, 2.0);
                let mut x = vec![0f32; rows];
                rng.fill_uniform(&mut x, -2.0, 2.0);
                let mut want = vec![0f32; cols];
                rng.fill_uniform(&mut want, -1.0, 1.0);
                let mut got = want.clone();
                let scale = 0.37f32;
                matvec_t_acc_slice_scalar(&s, cols, &x, scale, &mut want);
                matvec_t_acc(&s, cols, &x, scale, &mut got);
                for j in 0..cols {
                    assert_eq!(got[j].to_bits(), want[j].to_bits(), "rows={rows} cols={cols} j={j}");
                }
            }
        }
    }

    #[test]
    fn forced_scalar_disables_active_but_not_availability() {
        let avail = runtime_available();
        set_forced_scalar(true);
        assert!(!active());
        assert_eq!(runtime_available(), avail);
        set_forced_scalar(false);
        assert_eq!(active(), avail);
    }
}
