//! Dependency-free bf16 (bfloat16) conversion primitives for the
//! reduced-precision state slab ([`crate::state::pool::StatePool`] in
//! `Precision::Bf16` mode — see docs/PRECISION.md).
//!
//! bf16 is the top 16 bits of an IEEE-754 binary32: 1 sign bit, the same
//! 8-bit exponent, and a 7-bit mantissa. Widening (`bf16 → f32`) is
//! therefore exact (a shift), and narrowing (`f32 → bf16`) is a single
//! rounding step. We round to nearest, ties to even (RNE), matching the
//! hardware convert instructions (`VCVTNEPS2BF16`, TPU native bf16) so a
//! future accelerated slab is bit-compatible with this software path.
//!
//! Policy decisions (pinned by tests below):
//! - **NaN**: narrowing any NaN quiets it (`| 0x0040`) so a signalling
//!   NaN can never be fabricated by truncation of a payload whose low
//!   bits carried all the set mantissa bits. Payload top bits and sign
//!   are preserved. Consequence: bf16 *signalling*-NaN bit patterns are
//!   not round-trip fixed points (they widen to an sNaN f32 which
//!   re-narrows to the quieted pattern); quiet NaNs round-trip exactly.
//! - **Overflow**: finite f32 values above the bf16-representable range
//!   (only possible via rounding at the very top, e.g. `f32::MAX`)
//!   narrow to ±inf, exactly as RNE on the shortened mantissa dictates.
//! - **Subnormals / ±0**: handled by the same integer-rounding path, no
//!   flush-to-zero. `-0.0` narrows to `0x8000` and survives round-trips.

/// Narrow an `f32` to bf16 bits, round-to-nearest-even.
#[inline(always)]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Truncate, then force the quiet bit so the result is a NaN even
        // when every set mantissa bit lived in the discarded low half.
        return ((bits >> 16) as u16) | 0x0040;
    }
    // RNE on the low 16 bits: add 0x7FFF plus the round-to-even tiebreak
    // (bit 16 of the input), then truncate. Carries propagate into the
    // exponent, which is exactly what rounding up at a binade boundary
    // (or at f32::MAX → +inf) requires.
    ((bits.wrapping_add(((bits >> 16) & 1) + 0x7FFF)) >> 16) as u16
}

/// Widen bf16 bits to `f32`. Exact — bf16 is a prefix of binary32.
#[inline(always)]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Widen a bf16 slice into an f32 slice of the same length.
// xtask: deny_alloc
#[inline]
pub fn widen_into(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d = bf16_to_f32(s);
    }
}

/// Narrow an f32 slice into a bf16 slice of the same length (RNE).
// xtask: deny_alloc
#[inline]
pub fn narrow_into(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d = f32_to_bf16(s);
    }
}

/// bf16 unit roundoff: the worst-case relative error of one RNE
/// narrowing of a normal value is `2^-9` (7 mantissa bits + hidden bit).
/// Used by the tolerance-bound derivation in docs/PRECISION.md and the
/// trace harness.
pub const BF16_UNIT_ROUNDOFF: f32 = 1.0 / 512.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widen_is_exact_prefix() {
        for h in [0u16, 1, 0x0080, 0x3F80, 0x8000, 0x7F80, 0xFF80, 0x7FC0] {
            assert_eq!(bf16_to_f32(h).to_bits(), (h as u32) << 16);
        }
    }

    /// Exhaustive over all 65536 bf16 patterns: widen→narrow is the
    /// identity for every pattern except signalling NaNs, which map to
    /// their quieted counterpart (policy above).
    #[test]
    fn round_trip_is_identity_for_all_non_snan_patterns() {
        for h in 0..=u16::MAX {
            let back = f32_to_bf16(bf16_to_f32(h));
            let exp = (h >> 7) & 0xFF;
            let mantissa = h & 0x7F;
            let is_snan = exp == 0xFF && mantissa != 0 && (h & 0x0040) == 0;
            if is_snan {
                assert_eq!(back, h | 0x0040, "sNaN {h:#06x} must quiet, got {back:#06x}");
            } else {
                assert_eq!(back, h, "pattern {h:#06x} not a round-trip fixed point");
            }
        }
    }

    #[test]
    fn rne_tie_vectors() {
        // 1.0 + 2^-8 exactly between 1.0 (0x3F80) and nextafter: tie,
        // low kept bit even → rounds down.
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80);
        // next representable up: tie with odd kept bit → rounds up to even.
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_8000)), 0x3F82);
        // just above a tie → always up.
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8001)), 0x3F81);
        // just below a tie → always down.
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_7FFF)), 0x3F80);
    }

    #[test]
    fn signed_zero_and_infinities() {
        assert_eq!(f32_to_bf16(0.0), 0x0000);
        assert_eq!(f32_to_bf16(-0.0), 0x8000);
        assert!(bf16_to_f32(0x8000).is_sign_negative());
        assert_eq!(f32_to_bf16(f32::INFINITY), 0x7F80);
        assert_eq!(f32_to_bf16(f32::NEG_INFINITY), 0xFF80);
        // f32::MAX rounds up past the largest finite bf16 → +inf.
        assert_eq!(f32_to_bf16(f32::MAX), 0x7F80);
        assert_eq!(f32_to_bf16(f32::MIN), 0xFF80);
        // Largest f32 that still narrows to the top finite bf16.
        let top_finite = bf16_to_f32(0x7F7F);
        assert_eq!(f32_to_bf16(top_finite), 0x7F7F);
    }

    #[test]
    fn subnormals_round_not_flush() {
        // Smallest positive f32 subnormal is far below half the smallest
        // bf16 subnormal → rounds to +0, sign preserved for the negative.
        assert_eq!(f32_to_bf16(f32::from_bits(0x0000_0001)), 0x0000);
        assert_eq!(f32_to_bf16(f32::from_bits(0x8000_0001)), 0x8000);
        // Exactly half the smallest bf16 subnormal: tie to even → 0.
        assert_eq!(f32_to_bf16(f32::from_bits(0x0000_8000)), 0x0000);
        // Just above the tie → smallest bf16 subnormal.
        assert_eq!(f32_to_bf16(f32::from_bits(0x0000_8001)), 0x0001);
        // bf16 subnormals are representable f32 values → exact round-trip
        // (covered exhaustively above, spot-check semantics here).
        let sub = bf16_to_f32(0x0001);
        assert!(sub > 0.0 && !sub.is_normal());
    }

    #[test]
    fn nan_payloads_quiet_and_preserve_sign() {
        let q = f32_to_bf16(f32::NAN);
        assert_eq!(q & 0x7FC0 & 0x0040, 0x0040);
        assert!(bf16_to_f32(q).is_nan());
        // An f32 sNaN whose payload lives only in the low 16 bits would
        // truncate to an infinity without the quiet-bit force.
        let snan_low = f32::from_bits(0x7F80_0001);
        let h = f32_to_bf16(snan_low);
        assert!(bf16_to_f32(h).is_nan(), "low-payload sNaN must stay NaN");
        // Negative NaN keeps its sign bit.
        let neg = f32_to_bf16(f32::from_bits(0xFFC0_1234));
        assert_eq!(neg & 0x8000, 0x8000);
        assert!(bf16_to_f32(neg).is_nan());
    }

    /// Property: narrowing error of a random normal f32 is bounded by
    /// the unit roundoff, and narrowing is idempotent (a second
    /// narrow of the widened value is a no-op).
    #[test]
    fn narrow_error_bounded_and_idempotent_property() {
        let mut rng = crate::util::rng::Rng::new(0x51D0_BF16);
        for _ in 0..20_000 {
            let x = (rng.f32() - 0.5) * f32::exp2(rng.range(0, 120) as f32 - 60.0);
            if !x.is_finite() || x == 0.0 {
                continue;
            }
            let h = f32_to_bf16(x);
            let w = bf16_to_f32(h);
            if w.is_finite() && x.abs() >= f32::MIN_POSITIVE {
                let rel = ((w - x) / x).abs();
                assert!(rel <= BF16_UNIT_ROUNDOFF, "x={x:e} w={w:e} rel={rel:e}");
            }
            assert_eq!(f32_to_bf16(w), h, "narrow not idempotent at x={x:e}");
        }
    }

    #[test]
    fn slice_helpers_match_scalar() {
        let xs = [1.5f32, -0.0, 2.5e-40, f32::MIN_POSITIVE, 3.14159, -1e30];
        let mut hs = [0u16; 6];
        narrow_into(&xs, &mut hs);
        let mut back = [0f32; 6];
        widen_into(&hs, &mut back);
        for i in 0..xs.len() {
            assert_eq!(hs[i], f32_to_bf16(xs[i]));
            assert_eq!(back[i].to_bits(), bf16_to_f32(hs[i]).to_bits());
        }
    }
}
