//! Fenwick-tree prefix partitioning (paper §3.1, footnote 8).
//!
//! For a query at (0-indexed) position `t`, the prefix `[0, t]` is
//! partitioned into a sentinel bucket `B^(0) = {t}` plus at most
//! `⌈log2 t⌉` power-of-two buckets: greedily subtract the largest power
//! of two dividing the remaining boundary (`lssb`). Bucket at level
//! `ℓ ≥ 1` has size `2^(ℓ-1)`.
//!
//! Example, `t = 6` (binary 110): buckets `{6}` (ℓ=0), `{4,5}` (ℓ=2),
//! `{0..3}` (ℓ=3) — recent tokens at fine resolution, distant tokens
//! coarse.
//!
//! Everything else in the repo (the `M^H` mask, the chunkwise algorithm's
//! level masks, the decode-time state manager, the Pallas kernels' python
//! twin `fenwick.py`) is derived from the three functions here:
//! [`lssb`], [`buckets`], [`level_of`].

/// Index of the least significant set bit of `t` (`t > 0`), i.e. the
/// largest `ℓ` with `2^ℓ | t`.
#[inline]
pub fn lssb(t: usize) -> u32 {
    debug_assert!(t > 0, "lssb(0) is undefined");
    t.trailing_zeros()
}

/// A contiguous bucket `[start, end)` at hierarchy level `level`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bucket {
    pub level: usize,
    pub start: usize,
    pub end: usize, // exclusive
}

impl Bucket {
    pub fn len(&self) -> usize {
        self.end - self.start
    }
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
    pub fn contains(&self, s: usize) -> bool {
        (self.start..self.end).contains(&s)
    }
}

/// The Fenwick partition of `[0, t]` for a query at position `t`,
/// ordered from the sentinel (level 0) to the coarsest bucket.
pub fn buckets(t: usize) -> Vec<Bucket> {
    let mut out = vec![Bucket { level: 0, start: t, end: t + 1 }];
    let mut b = t;
    while b > 0 {
        let l = lssb(b);
        let size = 1usize << l;
        out.push(Bucket {
            level: l as usize + 1,
            start: b - size,
            end: b,
        });
        b -= size;
    }
    out
}

/// Level `ℓ(t, s)` of the bucket containing `s` in the partition for a
/// query at `t`. Requires `s <= t`.
pub fn level_of(t: usize, s: usize) -> usize {
    debug_assert!(s <= t, "level_of requires s <= t");
    if s == t {
        return 0;
    }
    let mut b = t;
    loop {
        debug_assert!(b > 0);
        let l = lssb(b);
        let size = 1usize << l;
        if s >= b - size {
            return l as usize + 1;
        }
        b -= size;
    }
}

/// Number of distinct levels needed for sequences of length `seq_len`
/// (positions `0..seq_len`): levels `0 ..= ceil_log2(seq_len)`, matching
/// the paper's `num_levels = log2(T) + 1` for power-of-two `T`.
pub fn num_levels(seq_len: usize) -> usize {
    assert!(seq_len >= 1);
    ceil_log2(seq_len) + 1
}

/// Smallest `k` with `2^k >= n`.
pub fn ceil_log2(n: usize) -> usize {
    assert!(n >= 1);
    (usize::BITS - (n - 1).leading_zeros()) as usize
}

/// The set of levels whose bucket is non-empty at position `t`
/// (`popcount(t) + 1` of them — roughly half of all levels, App. B.4).
pub fn active_levels(t: usize) -> Vec<usize> {
    buckets(t).iter().map(|b| b.level).collect()
}

/// Boolean level mask at granularity `n`: `mask[i][j] = (level_of(i,j) == level)`,
/// zero above the diagonal. This is the `level_mask` of the paper's
/// Appendix-C reference code; at chunk granularity it selects which
/// chunk-to-chunk state transfers belong to inter-chunk level `level`.
pub fn level_mask(level: usize, n: usize) -> Vec<Vec<bool>> {
    let mut m = vec![vec![false; n]; n];
    for (i, row) in m.iter_mut().enumerate() {
        for (j, cell) in row.iter_mut().enumerate().take(i + 1) {
            *cell = level_of(i, j) == level;
        }
    }
    m
}

/// `M^H` scalar mask (Eq. 4): `M[t][s] = lambda[t][level_of(t,s)]` for
/// `s <= t`, else 0. `lambda` is `(T, num_levels)` row-major.
pub fn hmask(lambda: &crate::tensor::Mat, seq_len: usize) -> crate::tensor::Mat {
    assert!(lambda.rows >= seq_len);
    let nl = lambda.cols;
    crate::tensor::Mat::from_fn(seq_len, seq_len, |t, s| {
        if s > t {
            0.0
        } else {
            let l = level_of(t, s);
            assert!(l < nl, "lambda has too few levels: need {l}, have {nl}");
            lambda.at(t, l)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, UsizeIn};

    #[test]
    fn lssb_known_values() {
        assert_eq!(lssb(1), 0);
        assert_eq!(lssb(2), 1);
        assert_eq!(lssb(6), 1);
        assert_eq!(lssb(8), 3);
        assert_eq!(lssb(12), 2);
    }

    #[test]
    fn buckets_t6_matches_paper_figure() {
        // t=6 -> {6} (l=0), {4,5} (l=2), {0..3} (l=3)
        let bs = buckets(6);
        assert_eq!(
            bs,
            vec![
                Bucket { level: 0, start: 6, end: 7 },
                Bucket { level: 2, start: 4, end: 6 },
                Bucket { level: 3, start: 0, end: 4 },
            ]
        );
    }

    #[test]
    fn buckets_partition_prefix_property() {
        check("buckets partition [0,t]", 300, &UsizeIn(0, 5000), |&t| {
            let bs = buckets(t);
            // Disjoint cover of [0, t]: sort by start and check contiguity.
            let mut sorted = bs.clone();
            sorted.sort_by_key(|b| b.start);
            let mut pos = 0;
            for b in &sorted {
                if b.start != pos {
                    return false;
                }
                pos = b.end;
            }
            pos == t + 1
        });
    }

    #[test]
    fn bucket_sizes_are_powers_of_two_property() {
        check("bucket sizes 2^(l-1)", 300, &UsizeIn(0, 5000), |&t| {
            buckets(t).iter().all(|b| {
                if b.level == 0 {
                    b.len() == 1
                } else {
                    b.len() == (1 << (b.level - 1))
                }
            })
        });
    }

    #[test]
    fn bucket_count_is_logarithmic_property() {
        check("O(log t) buckets", 300, &UsizeIn(1, 100_000), |&t| {
            let n = buckets(t).len();
            n == t.count_ones() as usize + 1 && n <= ceil_log2(t + 1) + 2
        });
    }

    #[test]
    fn level_of_agrees_with_buckets_property() {
        check("level_of == bucket membership", 100, &UsizeIn(0, 600), |&t| {
            let bs = buckets(t);
            (0..=t).all(|s| {
                let l = level_of(t, s);
                bs.iter().any(|b| b.contains(s) && b.level == l)
            })
        });
    }

    #[test]
    fn level_zero_iff_sentinel() {
        for t in 0..100 {
            assert_eq!(level_of(t, t), 0);
            for s in 0..t {
                assert_ne!(level_of(t, s), 0);
            }
        }
    }

    #[test]
    fn num_levels_matches_paper() {
        // T power of two: log2(T) + 1
        assert_eq!(num_levels(1), 1);
        assert_eq!(num_levels(8), 4);
        assert_eq!(num_levels(256), 9);
        // covers every level that can occur for t < T
        for t in 0..256 {
            for b in buckets(t) {
                assert!(b.level < num_levels(256));
            }
        }
    }

    #[test]
    fn active_levels_has_popcount_plus_one() {
        for t in 0..2000 {
            assert_eq!(active_levels(t).len(), t.count_ones() as usize + 1);
        }
    }

    #[test]
    fn level_mask_partitions_lower_triangle() {
        let n = 32;
        let masks: Vec<_> = (0..num_levels(n)).map(|l| level_mask(l, n)).collect();
        for i in 0..n {
            for j in 0..n {
                let hits = masks.iter().filter(|m| m[i][j]).count();
                if j <= i {
                    assert_eq!(hits, 1, "({i},{j}) not covered exactly once");
                } else {
                    assert_eq!(hits, 0, "({i},{j}) above diagonal");
                }
            }
        }
    }

    #[test]
    fn chunk_level_correspondence() {
        // level_of at token granularity for cross-chunk (t,s) equals
        // log2(C) + level_of at chunk granularity -- the identity that
        // makes Algorithm 1 correct.
        let c: usize = 8; // chunk size
        let lc = c.trailing_zeros() as usize; // log2(C)
        let t_max = 16 * c;
        for t in 0..t_max {
            for s in 0..=t {
                let (tc, sc) = (t / c, s / c);
                if tc != sc {
                    assert_eq!(
                        level_of(t, s),
                        lc + level_of(tc, sc),
                        "t={t} s={s} tc={tc} sc={sc}"
                    );
                }
            }
        }
    }

    #[test]
    fn intra_chunk_levels_are_local() {
        // Within a chunk, level_of(t,s) only depends on chunk-local offsets.
        let c: usize = 16;
        for chunk in 0..8 {
            for dt in 0..c {
                for ds in 0..=dt {
                    let (t, s) = (chunk * c + dt, chunk * c + ds);
                    assert_eq!(level_of(t, s), level_of(dt, ds));
                }
            }
        }
    }

    #[test]
    fn hmask_selects_lambda_by_level() {
        use crate::tensor::Mat;
        let t_len = 8;
        let nl = num_levels(t_len);
        // lambda[t][l] = 100*t + l so we can read indices back.
        let lambda = Mat::from_fn(t_len, nl, |t, l| (100 * t + l) as f32);
        let m = hmask(&lambda, t_len);
        for t in 0..t_len {
            for s in 0..t_len {
                if s > t {
                    assert_eq!(m.at(t, s), 0.0);
                } else {
                    assert_eq!(m.at(t, s), (100 * t + level_of(t, s)) as f32);
                }
            }
        }
    }
}
