//! Evaluation harness: perplexity, per-position loss (Fig. 5), and
//! task-batch accuracy (Tables 2/4/7/8) over the compiled `eval` artifact;
//! plus fixed-width table printers shared by all experiment commands.

use anyhow::Result;

use crate::data::TaskBatch;
use crate::runtime::ModelHandle;

/// Mean loss + perplexity over `n_batches` held-out batches.
pub fn perplexity(
    model: &ModelHandle,
    mut next_batch: impl FnMut() -> Vec<i32>,
    n_batches: usize,
) -> Result<(f64, f64)> {
    let mut total = 0.0;
    for _ in 0..n_batches {
        let tokens = next_batch();
        let out = model.eval(&tokens)?;
        total += out.loss as f64;
    }
    let mean = total / n_batches as f64;
    Ok((mean, mean.exp()))
}

/// Average per-position loss curve over batches (Fig. 5 input).
pub fn per_position_loss(
    model: &ModelHandle,
    mut next_batch: impl FnMut() -> Vec<i32>,
    n_batches: usize,
) -> Result<Vec<f64>> {
    let b = model.manifest.batch;
    let t = model.manifest.cfg("seq_len");
    let mut acc = vec![0.0f64; t - 1];
    for _ in 0..n_batches {
        let tokens = next_batch();
        let out = model.eval(&tokens)?;
        for bi in 0..b {
            for p in 0..t - 1 {
                acc[p] += out.per_pos[bi * (t - 1) + p] as f64;
            }
        }
    }
    for v in acc.iter_mut() {
        *v /= (n_batches * b) as f64;
    }
    Ok(acc)
}

/// Accuracy of the model's argmax predictions on a task batch. The batch
/// shape must match the compiled eval artifact.
pub fn task_accuracy(model: &ModelHandle, tb: &TaskBatch) -> Result<f64> {
    assert_eq!(tb.batch, model.manifest.batch, "batch mismatch");
    assert_eq!(tb.seq, model.manifest.cfg("seq_len"), "seq mismatch");
    let out = model.eval(&tb.tokens)?;
    Ok(tb.accuracy(&out.preds))
}

/// Accuracy averaged over several generated batches.
pub fn task_accuracy_n(
    model: &ModelHandle,
    mut gen: impl FnMut() -> TaskBatch,
    n: usize,
) -> Result<f64> {
    let mut acc = 0.0;
    for _ in 0..n {
        acc += task_accuracy(model, &gen())?;
    }
    Ok(acc / n as f64)
}

/// Fixed-width table printer used by every experiment command.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    s.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    s.push_str(&format!("  {:>w$}", c, w = widths[i]));
                }
            }
            s
        };
        println!("{}", line(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut obj = Json::obj();
                for (h, c) in self.headers.iter().zip(r) {
                    obj = obj.set(h, c.as_str());
                }
                obj
            })
            .collect();
        Json::Arr(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_prints_and_serializes() {
        let mut t = Table::new(&["model", "acc"]);
        t.row(vec!["mamba2".into(), "0.93".into()]);
        t.row(vec!["loglinear".into(), "0.97".into()]);
        t.print();
        let j = t.to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[1].get("acc").unwrap().as_str(), Some("0.97"));
    }
}
