//! PJRT runtime bridge (Layer 3 ↔ Layer 2).
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py`
//! (`artifacts/*.hlo.txt` + `manifest_*.json` + `params_*.bin`), compiles
//! them once on the PJRT CPU client, and exposes typed entry points:
//! [`ModelHandle::eval`], [`ModelHandle::train_step`],
//! [`ModelHandle::decode_step`].
//!
//! Interchange is HLO **text** — xla_extension 0.5.1 rejects jax≥0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids (see
//! DESIGN.md §2).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Shared PJRT client (one per process).
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Runtime { client })
    }

    /// Load + compile an HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
    }
}

/// One named tensor (host side).
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape {}: {e:?}", self.name))
    }
}

/// Parsed `manifest_<name>.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub variant: String,
    pub config: BTreeMap<String, usize>,
    pub num_levels: usize,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub param_count: usize,
    pub batch: usize,
    pub decode_batches: Vec<usize>,
    pub state_shapes: Vec<Vec<usize>>, // per layer, without batch dim
    pub artifact_paths: BTreeMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path, name: &str) -> Result<Manifest> {
        let path = dir.join(format!("manifest_{name}.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let params = j
            .get("params")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow!("manifest missing params"))?;
        let mut param_names = Vec::new();
        let mut param_shapes = Vec::new();
        for p in params {
            param_names.push(p.get("name").and_then(|n| n.as_str()).unwrap().to_string());
            param_shapes.push(
                p.get("shape")
                    .and_then(|s| s.as_arr())
                    .unwrap()
                    .iter()
                    .map(|d| d.as_usize().unwrap())
                    .collect(),
            );
        }
        let mut artifact_paths = BTreeMap::new();
        if let Some(Json::Obj(arts)) = j.get("artifacts") {
            for (k, v) in arts {
                if let Some(p) = v.get("path").and_then(|p| p.as_str()) {
                    artifact_paths.insert(k.clone(), p.to_string());
                }
            }
        }
        let mut config = BTreeMap::new();
        if let Some(Json::Obj(c)) = j.get("config") {
            for (k, v) in c {
                config.insert(k.clone(), v.as_usize().unwrap_or(0));
            }
        }
        let state_shapes: Vec<Vec<usize>> = j
            .get("state_shapes")
            .and_then(|s| s.as_arr())
            .map(|arr| {
                arr.iter()
                    .map(|s| {
                        s.as_arr()
                            .unwrap()
                            .iter()
                            .map(|d| d.as_usize().unwrap())
                            .collect()
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(Manifest {
            name: name.to_string(),
            variant: j.get("variant").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
            config,
            num_levels: j.get("num_levels").and_then(|v| v.as_usize()).unwrap_or(0),
            param_names,
            param_shapes,
            param_count: j.get("param_count").and_then(|v| v.as_usize()).unwrap_or(0),
            batch: j.get("batch").and_then(|v| v.as_usize()).unwrap_or(1),
            decode_batches: j
                .get("decode_batches")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            state_shapes,
            artifact_paths,
        })
    }

    pub fn cfg(&self, key: &str) -> usize {
        *self.config.get(key).unwrap_or(&0)
    }

    /// Read `params_<name>.bin` into named host tensors (manifest order).
    pub fn load_params(&self, dir: &Path) -> Result<Vec<HostTensor>> {
        let path = dir.join(format!("params_{}.bin", self.name));
        let raw = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let total: usize = self.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        if floats.len() != total {
            bail!("params.bin has {} floats, manifest wants {}", floats.len(), total);
        }
        let mut out = Vec::with_capacity(self.param_names.len());
        let mut off = 0;
        for (name, shape) in self.param_names.iter().zip(&self.param_shapes) {
            let n: usize = shape.iter().product();
            out.push(HostTensor {
                name: name.clone(),
                shape: shape.clone(),
                data: floats[off..off + n].to_vec(),
            });
            off += n;
        }
        Ok(out)
    }
}

/// Outputs of one training step.
pub struct TrainOut {
    pub loss: f32,
}

/// Outputs of one eval call.
pub struct EvalOut {
    pub loss: f32,
    /// per-position loss, (batch, seq-1) row-major
    pub per_pos: Vec<f32>,
    /// argmax predictions, (batch, seq) row-major
    pub preds: Vec<i32>,
}

/// A loaded model: manifest + host-mirrored params (+ optimizer state)
/// + compiled executables.
pub struct ModelHandle {
    pub manifest: Manifest,
    dir: PathBuf,
    /// current parameters (host mirror, manifest order)
    pub params: Vec<HostTensor>,
    /// Adam moments (host mirrors), allocated lazily by `ensure_train`
    opt_m: Option<Vec<HostTensor>>,
    opt_v: Option<Vec<HostTensor>>,
    exe_eval: Option<xla::PjRtLoadedExecutable>,
    exe_eval_seqs: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    exe_train: Option<xla::PjRtLoadedExecutable>,
    exe_decode: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

impl ModelHandle {
    pub fn load(rt: &Runtime, dir: &Path, name: &str) -> Result<ModelHandle> {
        let manifest = Manifest::load(dir, name)?;
        let params = manifest.load_params(dir)?;
        let mut h = ModelHandle {
            manifest,
            dir: dir.to_path_buf(),
            params,
            opt_m: None,
            opt_v: None,
            exe_eval: None,
            exe_eval_seqs: BTreeMap::new(),
            exe_train: None,
            exe_decode: BTreeMap::new(),
        };
        if h.manifest.artifact_paths.contains_key("eval") {
            h.exe_eval = Some(rt.load(&h.dir.join(&h.manifest.artifact_paths["eval"]))?);
        }
        Ok(h)
    }

    pub fn ensure_train(&mut self, rt: &Runtime) -> Result<()> {
        if self.exe_train.is_none() {
            let p = self
                .manifest
                .artifact_paths
                .get("train_step")
                .ok_or_else(|| anyhow!("no train_step artifact"))?
                .clone();
            self.exe_train = Some(rt.load(&self.dir.join(p))?);
        }
        if self.opt_m.is_none() {
            self.opt_m = Some(zeros_like(&self.params));
            self.opt_v = Some(zeros_like(&self.params));
        }
        Ok(())
    }

    pub fn ensure_decode(&mut self, rt: &Runtime, batch: usize) -> Result<()> {
        if !self.exe_decode.contains_key(&batch) {
            let key = format!("decode_step_b{batch}");
            let p = self
                .manifest
                .artifact_paths
                .get(&key)
                .ok_or_else(|| anyhow!("no decode artifact for batch {batch}"))?
                .clone();
            let exe = rt.load(&self.dir.join(p))?;
            self.exe_decode.insert(batch, exe);
        }
        Ok(())
    }

    pub fn decode_batches_available(&self) -> Vec<usize> {
        self.manifest.decode_batches.clone()
    }

    /// Compile the eval artifact for a specific sequence length
    /// (`eval_s<seq>`; the primary seq length aliases the main artifact).
    pub fn ensure_eval_seq(&mut self, rt: &Runtime, seq: usize) -> Result<()> {
        if seq == self.manifest.cfg("seq_len") || self.exe_eval_seqs.contains_key(&seq) {
            return Ok(());
        }
        let key = format!("eval_s{seq}");
        let p = self
            .manifest
            .artifact_paths
            .get(&key)
            .ok_or_else(|| anyhow!("no eval artifact for seq {seq}"))?
            .clone();
        let exe = rt.load(&self.dir.join(p))?;
        self.exe_eval_seqs.insert(seq, exe);
        Ok(())
    }

    /// Evaluate at a specific sequence length (must be compiled via
    /// `ensure_eval_seq`, or the primary length).
    pub fn eval_at(&self, seq: usize, tokens: &[i32]) -> Result<EvalOut> {
        if seq == self.manifest.cfg("seq_len") {
            return self.eval(tokens);
        }
        let exe = self
            .exe_eval_seqs
            .get(&seq)
            .ok_or_else(|| anyhow!("eval seq {seq} not compiled"))?;
        let b = self.manifest.batch;
        if tokens.len() != b * seq {
            bail!("eval_at expects {}x{} tokens, got {}", b, seq, tokens.len());
        }
        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.params.len() + 1);
        for p in &self.params {
            args.push(p.to_literal()?);
        }
        args.push(
            xla::Literal::vec1(tokens)
                .reshape(&[b as i64, seq as i64])
                .map_err(|e| anyhow!("{e:?}"))?,
        );
        let result = exe.execute::<xla::Literal>(&args).map_err(|e| anyhow!("{e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("{e:?}"))?;
        let loss = tuple[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        let per_pos = tuple[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let preds = tuple[2].to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(EvalOut { loss, per_pos, preds })
    }

    /// Evaluate on a token batch (shape must match the compiled (B, T)).
    pub fn eval(&self, tokens: &[i32]) -> Result<EvalOut> {
        let exe = self.exe_eval.as_ref().ok_or_else(|| anyhow!("eval not compiled"))?;
        let b = self.manifest.batch;
        let t = self.manifest.cfg("seq_len");
        if tokens.len() != b * t {
            bail!("eval expects {}x{} tokens, got {}", b, t, tokens.len());
        }
        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.params.len() + 1);
        for p in &self.params {
            args.push(p.to_literal()?);
        }
        args.push(
            xla::Literal::vec1(tokens)
                .reshape(&[b as i64, t as i64])
                .map_err(|e| anyhow!("{e:?}"))?,
        );
        let result = exe.execute::<xla::Literal>(&args).map_err(|e| anyhow!("{e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("{e:?}"))?;
        let loss = tuple[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        let per_pos = tuple[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let preds = tuple[2].to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(EvalOut { loss, per_pos, preds })
    }

    /// One fused Adam step. Updates the host param/moment mirrors.
    pub fn train_step(&mut self, step: i32, tokens: &[i32], lr: f32) -> Result<TrainOut> {
        let exe = self.exe_train.as_ref().ok_or_else(|| anyhow!("train not compiled"))?;
        let b = self.manifest.batch;
        let t = self.manifest.cfg("seq_len");
        if tokens.len() != b * t {
            bail!("train expects {}x{} tokens, got {}", b, t, tokens.len());
        }
        let m = self.opt_m.as_ref().unwrap();
        let v = self.opt_v.as_ref().unwrap();
        let n = self.params.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(3 * n + 3);
        for p in self.params.iter().chain(m.iter()).chain(v.iter()) {
            args.push(p.to_literal()?);
        }
        args.push(xla::Literal::scalar(step));
        args.push(
            xla::Literal::vec1(tokens)
                .reshape(&[b as i64, t as i64])
                .map_err(|e| anyhow!("{e:?}"))?,
        );
        args.push(xla::Literal::scalar(lr));
        let result = exe.execute::<xla::Literal>(&args).map_err(|e| anyhow!("{e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("{e:?}"))?;
        debug_assert_eq!(tuple.len(), 3 * n + 1);
        for (i, lit) in tuple.iter().take(n).enumerate() {
            self.params[i].data = lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        }
        let m = self.opt_m.as_mut().unwrap();
        for (i, lit) in tuple[n..2 * n].iter().enumerate() {
            m[i].data = lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        }
        let v = self.opt_v.as_mut().unwrap();
        for (i, lit) in tuple[2 * n..3 * n].iter().enumerate() {
            v[i].data = lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        }
        let loss = tuple[3 * n].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        Ok(TrainOut { loss })
    }

    /// One decode step for a batch of sequences. `states` is one flat f32
    /// buffer per layer with shape (B, *state_shape); `tokens`/`pos` are
    /// per-sequence. Returns the logits (B, vocab) and mutates `states`.
    pub fn decode_step(
        &self,
        batch: usize,
        states: &mut [Vec<f32>],
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<f32>> {
        let exe = self
            .exe_decode
            .get(&batch)
            .ok_or_else(|| anyhow!("decode batch {batch} not compiled"))?;
        if tokens.len() != batch || pos.len() != batch {
            bail!("decode batch mismatch");
        }
        let n = self.params.len();
        let layers = self.manifest.state_shapes.len();
        if states.len() != layers {
            bail!("expected {} state buffers", layers);
        }
        let mut args: Vec<xla::Literal> = Vec::with_capacity(n + layers + 2);
        for p in &self.params {
            args.push(p.to_literal()?);
        }
        for (i, st) in states.iter().enumerate() {
            let mut dims: Vec<i64> = vec![batch as i64];
            dims.extend(self.manifest.state_shapes[i].iter().map(|&d| d as i64));
            args.push(
                xla::Literal::vec1(st)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("state {i}: {e:?}"))?,
            );
        }
        args.push(xla::Literal::vec1(tokens));
        args.push(xla::Literal::vec1(pos));
        let result = exe.execute::<xla::Literal>(&args).map_err(|e| anyhow!("{e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("{e:?}"))?;
        let logits = tuple[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        for (i, st) in states.iter_mut().enumerate() {
            *st = tuple[1 + i].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        }
        Ok(logits)
    }

    /// Zeroed decode state buffers for a batch.
    pub fn zero_states(&self, batch: usize) -> Vec<Vec<f32>> {
        self.manifest
            .state_shapes
            .iter()
            .map(|s| vec![0.0; batch * s.iter().product::<usize>()])
            .collect()
    }

    /// Save current params as a checkpoint (raw f32, manifest order).
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let mut raw = Vec::new();
        for p in &self.params {
            for x in &p.data {
                raw.extend_from_slice(&x.to_le_bytes());
            }
        }
        std::fs::write(path, raw)?;
        Ok(())
    }

    /// Load params from a checkpoint produced by `save_checkpoint`.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let raw = std::fs::read(path)?;
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let total: usize = self.params.iter().map(|p| p.numel()).sum();
        if floats.len() != total {
            bail!("checkpoint size mismatch: {} vs {}", floats.len(), total);
        }
        let mut off = 0;
        for p in self.params.iter_mut() {
            let n = p.numel();
            p.data = floats[off..off + n].to_vec();
            off += n;
        }
        Ok(())
    }
}

fn zeros_like(params: &[HostTensor]) -> Vec<HostTensor> {
    params
        .iter()
        .map(|p| HostTensor {
            name: p.name.clone(),
            shape: p.shape.clone(),
            data: vec![0.0; p.numel()],
        })
        .collect()
}

/// Locate the artifacts directory (env override, then repo default).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("LOGLINEAR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest_tiny_loglinear_mamba2.json").exists()
    }

    #[test]
    fn manifest_roundtrip() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&artifacts_dir(), "tiny_loglinear_mamba2").unwrap();
        assert_eq!(m.variant, "loglinear_mamba2");
        assert!(m.param_count > 0);
        let params = m.load_params(&artifacts_dir()).unwrap();
        let total: usize = params.iter().map(|p| p.numel()).sum();
        assert_eq!(total, m.param_count);
        assert_eq!(m.state_shapes.len(), m.cfg("n_layers"));
    }

    #[test]
    fn host_tensor_literal_roundtrip() {
        let t = HostTensor {
            name: "x".into(),
            shape: vec![2, 3],
            data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        };
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), t.data);
    }
}
