//! LongBench-style task families (paper Table 8) — synthetic analogues
//! per DESIGN.md §6. Each family keeps the *mechanism* its LongBench
//! counterparts probe:
//!
//! - `QaSingle`  (NarrativeQA/Qasper/MultiFieldQA): one fact, deep in a
//!   long document, queried at the end.
//! - `QaMulti`   (HotpotQA/2WikiMulti/Musique): 2-hop composition — facts
//!   `k→a` and `a→b` planted far apart; query `k` expects `b`.
//! - `Summarize` (GovReport/QMSum/MultiNews): global aggregation — the
//!   probe asks for the document's dominant topic token.
//! - `FewShot`   (TREC/TriviaQA/SamSum): pattern induction from in-context
//!   examples of an input→label mapping.
//! - `Code`      (LCC/RepoBench-P): bracket/identifier matching — predict
//!   the identifier bound to an "opening" token seen long before.

use crate::util::{rng::Zipf, Rng};

use super::{Query, TaskBatch};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LongBenchTask {
    QaSingle,
    QaMulti,
    Summarize,
    FewShot,
    Code,
}

impl LongBenchTask {
    pub fn name(&self) -> &'static str {
        match self {
            LongBenchTask::QaSingle => "QA-single",
            LongBenchTask::QaMulti => "QA-multi",
            LongBenchTask::Summarize => "Summarize",
            LongBenchTask::FewShot => "FewShot",
            LongBenchTask::Code => "Code",
        }
    }

    pub fn all() -> &'static [LongBenchTask] {
        &[
            LongBenchTask::QaSingle,
            LongBenchTask::QaMulti,
            LongBenchTask::Summarize,
            LongBenchTask::FewShot,
            LongBenchTask::Code,
        ]
    }
}

#[derive(Debug, Clone)]
pub struct LongBenchConfig {
    pub seq: usize,
    pub vocab: usize,
}

const QUERY_MARK: i32 = 2;
const BIND_MARK: i32 = 3;

pub fn generate(task: LongBenchTask, cfg: &LongBenchConfig, batch: usize, rng: &mut Rng) -> TaskBatch {
    let key_lo = cfg.vocab * 3 / 4;
    let key_n = (cfg.vocab - key_lo) / 2;
    let val_lo = key_lo + key_n;
    let val_n = cfg.vocab - val_lo;
    let filler = Zipf::new(key_lo - 4, 1.1);
    let seq = cfg.seq;

    let mut tokens = Vec::with_capacity(batch * seq);
    let mut queries = Vec::new();
    for b in 0..batch {
        let mut row: Vec<i32> = (0..seq).map(|_| (4 + filler.sample(rng)) as i32).collect();
        match task {
            LongBenchTask::QaSingle => {
                let key = (key_lo + rng.below(key_n)) as i32;
                let val = (val_lo + rng.below(val_n)) as i32;
                let depth = rng.range(seq / 16, seq / 3);
                row[depth] = BIND_MARK;
                row[depth + 1] = key;
                row[depth + 2] = val;
                row[seq - 3] = QUERY_MARK;
                row[seq - 2] = key;
                row[seq - 1] = val;
                queries.push(Query { batch_idx: b, pos: seq - 2, answer: val });
            }
            LongBenchTask::QaMulti => {
                // k -> a planted early; a -> v planted mid; query k expects v
                let k = (key_lo + rng.below(key_n)) as i32;
                let a = (key_lo + rng.below(key_n)) as i32;
                let v = (val_lo + rng.below(val_n)) as i32;
                let p1 = rng.range(4, seq / 4);
                let p2 = rng.range(seq / 2, 3 * seq / 4);
                row[p1] = BIND_MARK;
                row[p1 + 1] = k;
                row[p1 + 2] = a;
                row[p2] = BIND_MARK;
                row[p2 + 1] = a;
                row[p2 + 2] = v;
                row[seq - 3] = QUERY_MARK;
                row[seq - 2] = k;
                row[seq - 1] = v;
                queries.push(Query { batch_idx: b, pos: seq - 2, answer: v });
            }
            LongBenchTask::Summarize => {
                // a "topic" value token is repeated throughout; the probe
                // asks for it. Global frequency, not a single position.
                let topic = (val_lo + rng.below(val_n)) as i32;
                let reps = seq / 8;
                for _ in 0..reps {
                    let p = rng.below(seq - 2);
                    row[p] = topic;
                }
                row[seq - 2] = QUERY_MARK;
                row[seq - 1] = topic;
                queries.push(Query { batch_idx: b, pos: seq - 2, answer: topic });
            }
            LongBenchTask::FewShot => {
                // consistent mapping f(key_class) = label shown n times,
                // then a fresh instance of a seen key must get its label.
                let n_classes = 4.min(key_n);
                let classes = rng.sample_indices(key_n, n_classes);
                let labels: Vec<i32> =
                    (0..n_classes).map(|_| (val_lo + rng.below(val_n)) as i32).collect();
                let n_examples = 6;
                let mut pos = rng.range(2, 6);
                for _ in 0..n_examples {
                    if pos + 3 >= seq - 3 {
                        break;
                    }
                    let c = rng.below(n_classes);
                    row[pos] = BIND_MARK;
                    row[pos + 1] = (key_lo + classes[c]) as i32;
                    row[pos + 2] = labels[c];
                    pos += rng.range(4, (seq / n_examples).max(5));
                }
                let c = rng.below(n_classes);
                row[seq - 3] = QUERY_MARK;
                row[seq - 2] = (key_lo + classes[c]) as i32;
                row[seq - 1] = labels[c];
                queries.push(Query { batch_idx: b, pos: seq - 2, answer: labels[c] });
            }
            LongBenchTask::Code => {
                // "open" binds an identifier; much later the matching
                // "close" (QUERY) must name it — scope matching.
                let ident = (val_lo + rng.below(val_n)) as i32;
                let p = rng.range(2, seq / 4);
                row[p] = BIND_MARK;
                row[p + 1] = ident;
                row[seq - 2] = QUERY_MARK;
                row[seq - 1] = ident;
                queries.push(Query { batch_idx: b, pos: seq - 2, answer: ident });
            }
        }
        tokens.extend_from_slice(&row);
    }
    TaskBatch { tokens, batch, seq, queries }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_families_consistent() {
        let cfg = LongBenchConfig { seq: 256, vocab: 256 };
        let mut rng = Rng::new(1);
        for &task in LongBenchTask::all() {
            let tb = generate(task, &cfg, 3, &mut rng);
            assert!(tb.queries_consistent(), "{}", task.name());
            assert_eq!(tb.queries.len(), 3);
            assert!(tb.tokens.iter().all(|&t| (t as usize) < cfg.vocab));
        }
    }

    #[test]
    fn qa_multi_requires_both_hops() {
        // the answer token must NOT directly co-occur with the query key
        // except at the probe (forcing 2-hop composition).
        let cfg = LongBenchConfig { seq: 128, vocab: 256 };
        let mut rng = Rng::new(2);
        let tb = generate(LongBenchTask::QaMulti, &cfg, 1, &mut rng);
        let q = tb.queries[0];
        let key = tb.token(0, q.pos);
        // find first binding of key: next token is the bridge, not answer
        for t in 0..q.pos - 1 {
            if tb.token(0, t) == key && tb.token(0, t - 1) == 3 {
                assert_ne!(tb.token(0, t + 1), q.answer, "shortcut leak at {t}");
                return;
            }
        }
        panic!("key binding not found");
    }

    #[test]
    fn summarize_topic_is_dominant() {
        let cfg = LongBenchConfig { seq: 256, vocab: 256 };
        let mut rng = Rng::new(3);
        let tb = generate(LongBenchTask::Summarize, &cfg, 1, &mut rng);
        let topic = tb.queries[0].answer;
        let count = (0..tb.seq).filter(|&t| tb.token(0, t) == topic).count();
        assert!(count >= 256 / 8 / 2, "topic appears only {count} times");
    }
}
