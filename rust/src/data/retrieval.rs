//! In-context retrieval suite (paper Table 7: SWDE, SQuAD, FDA, TriviaQA,
//! Drop, NQ) — synthetic analogues with the benchmark-defining knobs:
//! evidence position, distractor count, answer length, and the input
//! *truncation sweep* (512 / 1024 / 2048 / 16k in the paper).
//!
//! Each profile plants a queried fact at a controlled depth inside a
//! filler+distractor document, then truncates **from the left** (as the
//! paper does) — once the evidence falls outside the window, accuracy
//! drops to chance, which is exactly the state-size effect Table 7 probes.

use crate::util::{rng::Zipf, Rng};

use super::{Query, TaskBatch};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetrievalTask {
    Swde,
    Squad,
    Fda,
    TriviaQa,
    Drop,
    Nq,
}

impl RetrievalTask {
    pub fn name(&self) -> &'static str {
        match self {
            RetrievalTask::Swde => "SWDE",
            RetrievalTask::Squad => "SQuAD",
            RetrievalTask::Fda => "FDA",
            RetrievalTask::TriviaQa => "TriviaQA",
            RetrievalTask::Drop => "Drop",
            RetrievalTask::Nq => "NQ",
        }
    }

    pub fn all() -> &'static [RetrievalTask] {
        &[
            RetrievalTask::Swde,
            RetrievalTask::Squad,
            RetrievalTask::Fda,
            RetrievalTask::TriviaQa,
            RetrievalTask::Drop,
            RetrievalTask::Nq,
        ]
    }

    /// (n_distractor_fields, answer_len, evidence_depth_frac)
    /// depth_frac = where in the document the evidence sits (0 = oldest).
    fn spec(&self) -> (usize, usize, f64) {
        match self {
            RetrievalTask::Swde => (8, 1, 0.2),      // many fields, shallow
            RetrievalTask::Squad => (4, 2, 0.5),     // mid-document span
            RetrievalTask::Fda => (12, 1, 0.1),      // long docs, early field
            RetrievalTask::TriviaQa => (2, 1, 0.5),  // sparse evidence
            RetrievalTask::Drop => (6, 2, 0.7),      // late, multi-token
            RetrievalTask::Nq => (3, 1, 0.3),
        }
    }
}

#[derive(Debug, Clone)]
pub struct RetrievalConfig {
    /// full (untruncated) document length incl. probe
    pub doc_len: usize,
    /// evaluated window (truncate from the left, keep the probe)
    pub window: usize,
    pub vocab: usize,
}

const QUERY_MARK: i32 = 2;
const FIELD_MARK: i32 = 3;

/// Generate a batch for one task at one truncation window.
pub fn generate(task: RetrievalTask, cfg: &RetrievalConfig, batch: usize, rng: &mut Rng) -> TaskBatch {
    let (n_distract, ans_len, depth_frac) = task.spec();
    let key_lo = cfg.vocab * 3 / 4;
    let key_n = (cfg.vocab - key_lo) / 2;
    let val_lo = key_lo + key_n;
    let val_n = cfg.vocab - val_lo;
    let filler = Zipf::new(key_lo - 4, 1.1);

    let probe_len = 2 + ans_len;
    let body = cfg.doc_len - probe_len;
    let mut tokens = Vec::with_capacity(batch * cfg.window);
    let mut queries = Vec::new();
    for b in 0..batch {
        let mut row: Vec<i32> = (0..body).map(|_| (4 + filler.sample(rng)) as i32).collect();
        // fields: FIELD key val...  ; one is the target
        let keys = rng.sample_indices(key_n, n_distract + 1);
        let target = 0usize;
        let mut answer = Vec::new();
        for (fi, &key) in keys.iter().enumerate() {
            let vals: Vec<i32> = (0..ans_len).map(|_| (val_lo + rng.below(val_n)) as i32).collect();
            let seg_len = 2 + ans_len;
            // the target field sits at its task-defined depth; distractors random
            let start = if fi == target {
                ((body - seg_len) as f64 * depth_frac) as usize
            } else {
                rng.below(body - seg_len)
            };
            // allow overlap for distractors (filler anyway); rewrite target last
            if fi != target {
                row[start] = FIELD_MARK;
                row[start + 1] = (key_lo + key) as i32;
                for (j, &v) in vals.iter().enumerate() {
                    row[start + 2 + j] = v;
                }
            } else {
                answer = vals;
            }
        }
        // write target field after distractors so it is never clobbered
        let seg_len = 2 + ans_len;
        let tstart = ((body - seg_len) as f64 * depth_frac) as usize;
        row[tstart] = FIELD_MARK;
        row[tstart + 1] = (key_lo + keys[target]) as i32;
        for (j, &v) in answer.iter().enumerate() {
            row[tstart + 2 + j] = v;
        }
        // probe
        row.push(QUERY_MARK);
        row.push((key_lo + keys[target]) as i32);
        let qpos_full = row.len() - 1;
        for &v in &answer {
            row.push(v);
        }
        debug_assert_eq!(row.len(), cfg.doc_len);
        // truncate from the left to `window`
        let cut = cfg.doc_len.saturating_sub(cfg.window);
        let win = &row[cut..];
        for (j, &v) in answer.iter().enumerate() {
            let pos = qpos_full - cut + j;
            queries.push(Query { batch_idx: b, pos, answer: v });
        }
        tokens.extend_from_slice(win);
    }
    TaskBatch { tokens, batch, seq: cfg.window, queries }
}

/// Whether the evidence survives the truncation (used to compute the
/// expected ceiling of a perfect-recall model).
pub fn evidence_survives(task: RetrievalTask, cfg: &RetrievalConfig) -> bool {
    let (_, ans_len, depth_frac) = task.spec();
    let body = cfg.doc_len - (2 + ans_len);
    let tstart = ((body - (2 + ans_len)) as f64 * depth_frac) as usize;
    let cut = cfg.doc_len.saturating_sub(cfg.window);
    tstart >= cut
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_consistent_untruncated() {
        let cfg = RetrievalConfig { doc_len: 512, window: 512, vocab: 256 };
        let mut rng = Rng::new(1);
        for &task in RetrievalTask::all() {
            let tb = generate(task, &cfg, 2, &mut rng);
            assert!(tb.queries_consistent(), "{}", task.name());
            assert!(evidence_survives(task, &cfg));
        }
    }

    #[test]
    fn truncation_can_remove_evidence() {
        // FDA plants evidence at 10% depth; a half-doc window cuts it off,
        // while Drop's late (70%) evidence survives the same window.
        let cfg = RetrievalConfig { doc_len: 1024, window: 512, vocab: 256 };
        assert!(!evidence_survives(RetrievalTask::Fda, &cfg));
        assert!(evidence_survives(RetrievalTask::Drop, &cfg));
    }

    #[test]
    fn oracle_scores_one_when_evidence_survives() {
        let cfg = RetrievalConfig { doc_len: 256, window: 256, vocab: 256 };
        let mut rng = Rng::new(2);
        let tb = generate(RetrievalTask::Squad, &cfg, 2, &mut rng);
        let mut preds = vec![0i32; tb.tokens.len()];
        for b in 0..tb.batch {
            for t in 0..tb.seq - 1 {
                preds[b * tb.seq + t] = tb.token(b, t + 1);
            }
        }
        assert!((tb.accuracy(&preds) - 1.0).abs() < 1e-9);
    }
}
