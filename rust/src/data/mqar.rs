//! Multi-Query Associative Recall (Arora et al., 2023) — Table 2 / Fig. 9.
//!
//! Layout (paper §4.1 / App. D setup): a 256-token sequence opens with
//! `n_pairs` key–value bindings `k_i v_i`, followed by filler and the
//! queries: each re-occurrence of `k_i` must be answered with `v_i` at the
//! next position. Keys/values/filler live in disjoint vocabulary ranges
//! so chance accuracy is ~1/n_values.

use crate::util::Rng;

use super::{Query, TaskBatch};

#[derive(Debug, Clone)]
pub struct MqarConfig {
    pub seq: usize,
    pub n_pairs: usize,
    pub n_keys: usize,
    pub n_values: usize,
    /// vocabulary layout: [0,2) specials, [2, 2+n_keys) keys,
    /// [2+n_keys, 2+n_keys+n_values) values, rest filler
    pub vocab: usize,
}

impl Default for MqarConfig {
    fn default() -> Self {
        MqarConfig { seq: 256, n_pairs: 16, n_keys: 64, n_values: 64, vocab: 192 }
    }
}

impl MqarConfig {
    pub fn key_token(&self, i: usize) -> i32 {
        (2 + i) as i32
    }
    pub fn value_token(&self, i: usize) -> i32 {
        (2 + self.n_keys + i) as i32
    }
    fn filler_range(&self) -> (usize, usize) {
        (2 + self.n_keys + self.n_values, self.vocab)
    }
}

/// Generate one batch of MQAR instances.
pub fn generate(cfg: &MqarConfig, batch: usize, rng: &mut Rng) -> TaskBatch {
    assert!(2 * cfg.n_pairs * 2 <= cfg.seq, "sequence too short for pairs+queries");
    let (flo, fhi) = cfg.filler_range();
    assert!(fhi > flo, "no filler tokens available");
    let mut tokens = Vec::with_capacity(batch * cfg.seq);
    let mut queries = Vec::new();
    for b in 0..batch {
        // distinct keys, random values
        let keys = rng.sample_indices(cfg.n_keys, cfg.n_pairs);
        let values: Vec<usize> = (0..cfg.n_pairs).map(|_| rng.below(cfg.n_values)).collect();
        let mut row = Vec::with_capacity(cfg.seq);
        // binding prefix
        for i in 0..cfg.n_pairs {
            row.push(cfg.key_token(keys[i]));
            row.push(cfg.value_token(values[i]));
        }
        // queries at random positions in the remainder (each takes 2 slots)
        let remaining = cfg.seq - row.len();
        let n_queries = cfg.n_pairs.min(remaining / 2);
        // choose which pairs to query (shuffled, possibly all)
        let mut order: Vec<usize> = (0..cfg.n_pairs).collect();
        rng.shuffle(&mut order);
        let mut slots: Vec<bool> = vec![false; remaining];
        // reserve n_queries random 2-aligned slots
        let mut starts: Vec<usize> = (0..remaining / 2).collect();
        rng.shuffle(&mut starts);
        for &s in starts.iter().take(n_queries) {
            slots[2 * s] = true;
        }
        let base = row.len();
        let mut qi = 0;
        let mut pos = 0;
        while pos < remaining {
            if slots[pos] && qi < n_queries && pos + 1 < remaining {
                let pair = order[qi];
                qi += 1;
                queries.push(Query {
                    batch_idx: b,
                    pos: base + pos,
                    answer: cfg.value_token(values[pair]),
                });
                row.push(cfg.key_token(keys[pair]));
                row.push(cfg.value_token(values[pair]));
                pos += 2;
            } else {
                row.push(rng.range(flo, fhi) as i32);
                pos += 1;
            }
        }
        debug_assert_eq!(row.len(), cfg.seq);
        tokens.extend_from_slice(&row);
    }
    TaskBatch { tokens, batch, seq: cfg.seq, queries }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queries_are_consistent_and_counted() {
        let cfg = MqarConfig::default();
        let mut rng = Rng::new(7);
        for n_pairs in [4usize, 16, 64] {
            let c = MqarConfig { n_pairs, ..cfg.clone() };
            let tb = generate(&c, 4, &mut rng);
            assert!(tb.queries_consistent());
            assert_eq!(tb.tokens.len(), 4 * c.seq);
            assert!(!tb.queries.is_empty());
            // all tokens in vocab
            assert!(tb.tokens.iter().all(|&t| (t as usize) < c.vocab));
        }
    }

    #[test]
    fn perfect_predictor_scores_one() {
        let cfg = MqarConfig::default();
        let mut rng = Rng::new(8);
        let tb = generate(&cfg, 2, &mut rng);
        // oracle: predict token at pos+1 for every position
        let mut preds = vec![0i32; tb.tokens.len()];
        for b in 0..tb.batch {
            for t in 0..tb.seq - 1 {
                preds[b * tb.seq + t] = tb.token(b, t + 1);
            }
        }
        assert!((tb.accuracy(&preds) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn keys_bound_once_per_sequence() {
        // distinct keys in the binding prefix
        let cfg = MqarConfig { n_pairs: 32, ..Default::default() };
        let mut rng = Rng::new(9);
        let tb = generate(&cfg, 1, &mut rng);
        let prefix: Vec<i32> = (0..32).map(|i| tb.token(0, 2 * i)).collect();
        let mut sorted = prefix.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 32);
    }
}
