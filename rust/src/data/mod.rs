//! Synthetic workload generators for every evaluation in the paper.
//!
//! The paper's data (50B-token Long-Data-Collections, Book-3, RULER,
//! LongBench, SWDE/SQuAD/FDA/...) is hardware/data-gated at this scale;
//! per DESIGN.md §6 we substitute *controlled synthetic analogues* that
//! exercise the same mechanism the benchmarks probe — recall over long
//! context as a function of state size — with difficulty knobs (number of
//! facts, evidence depth, distractors, truncation).
//!
//! | module | paper benchmark |
//! |--------|-----------------|
//! | [`corpus`]    | LM pretraining corpus + WikiText/LAMBADA-style eval (Tab. 3/6, Fig. 5) |
//! | [`mqar`]      | multi-query associative recall (Tab. 2, Fig. 9) |
//! | [`niah`]      | RULER needle-in-a-haystack suite (Tab. 4, Fig. 10) |
//! | [`retrieval`] | SWDE / SQuAD / FDA / TriviaQA / Drop / NQ-style (Tab. 7) |
//! | [`longbench`] | LongBench families (Tab. 8) |

pub mod corpus;
pub mod mqar;
pub mod niah;
pub mod retrieval;
pub mod longbench;

/// A scored query inside a batch: the model must predict `answer` at
/// sequence position `pos + 1`, i.e. its argmax prediction *at* `pos`
/// is compared to `answer`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Query {
    pub batch_idx: usize,
    pub pos: usize,
    pub answer: i32,
}

/// A generated evaluation batch.
#[derive(Debug, Clone)]
pub struct TaskBatch {
    pub tokens: Vec<i32>, // (batch, seq) row-major
    pub batch: usize,
    pub seq: usize,
    pub queries: Vec<Query>,
}

impl TaskBatch {
    pub fn token(&self, b: usize, t: usize) -> i32 {
        self.tokens[b * self.seq + t]
    }

    /// Accuracy of argmax predictions (shape (batch, seq) row-major,
    /// the `preds` output of the eval artifact) on this batch's queries.
    pub fn accuracy(&self, preds: &[i32]) -> f64 {
        assert_eq!(preds.len(), self.batch * self.seq);
        if self.queries.is_empty() {
            return 0.0;
        }
        let correct = self
            .queries
            .iter()
            .filter(|q| preds[q.batch_idx * self.seq + q.pos] == q.answer)
            .count();
        correct as f64 / self.queries.len() as f64
    }

    /// Sanity invariant used by generator tests: each query's answer is
    /// the token actually present at pos+1.
    pub fn queries_consistent(&self) -> bool {
        self.queries.iter().all(|q| {
            q.pos + 1 < self.seq && self.token(q.batch_idx, q.pos + 1) == q.answer
        })
    }
}

/// Task-pretraining mixture (for the `task` config models, vocab 256 /
/// seq 256): each batch is drawn from one of the evaluation families so
/// the models *can* learn the retrieval formats — the synthetic analogue
/// of the paper's long-context pretraining corpus (DESIGN.md §6).
pub fn mixture_batch(batch: usize, seq: usize, vocab: usize, rng: &mut crate::util::Rng) -> Vec<i32> {
    let pick = rng.below(8);
    let tb = match pick {
        0 | 1 => {
            let task = niah::NiahTask::all()[rng.below(6)];
            niah::generate(task, &niah::NiahConfig { seq, vocab }, batch, rng)
        }
        2 | 3 => {
            let task = retrieval::RetrievalTask::all()[rng.below(6)];
            retrieval::generate(
                task,
                &retrieval::RetrievalConfig { doc_len: seq, window: seq, vocab },
                batch,
                rng,
            )
        }
        4 | 5 => {
            let task = longbench::LongBenchTask::all()[rng.below(5)];
            longbench::generate(task, &longbench::LongBenchConfig { seq, vocab }, batch, rng)
        }
        _ => {
            let c = corpus::Corpus::new(
                corpus::CorpusConfig {
                    vocab,
                    seq,
                    recall_band: (8, seq * 3 / 4),
                    ..Default::default()
                },
                rng.next_u64() % 16, // a few distinct corpus flavors
            );
            return c.train_batch(batch, rng);
        }
    };
    tb.tokens
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixture_batches_have_right_shape() {
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..16 {
            let b = mixture_batch(4, 256, 256, &mut rng);
            assert_eq!(b.len(), 4 * 256);
            assert!(b.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn accuracy_counts_matches() {
        let tb = TaskBatch {
            tokens: vec![1, 2, 3, 4, 5, 6, 7, 8],
            batch: 2,
            seq: 4,
            queries: vec![
                Query { batch_idx: 0, pos: 1, answer: 3 },
                Query { batch_idx: 1, pos: 2, answer: 8 },
            ],
        };
        assert!(tb.queries_consistent());
        // preds: model predicts 3 at (0,1) -> correct; 0 at (1,2) -> wrong
        let mut preds = vec![0i32; 8];
        preds[0 * 4 + 1] = 3;
        assert!((tb.accuracy(&preds) - 0.5).abs() < 1e-9);
    }
}
