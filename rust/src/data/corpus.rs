//! Synthetic pretraining corpus + held-out LM evals (DESIGN.md §6
//! substitution for Long-Data-Collections / WikiText / LAMBADA).
//!
//! Documents mix three processes so that *both* local statistics and
//! long-range recall carry signal:
//!
//! 1. an order-1 Markov chain over content tokens (local syntax),
//! 2. a Zipf unigram background (function words),
//! 3. planted key→value *facts*: bindings introduced early in the
//!    document are re-queried later — exactly the mechanism behind the
//!    paper's per-position-loss analysis (Fig. 5): a model that can still
//!    access distant context keeps improving at late positions.
//!
//! The same generator with held-out seeds provides the "WikiText-style"
//! perplexity set; `lambada_batch` builds a cloze-style final-token
//! recall eval ("LAMBADA-style").

use crate::util::{rng::Zipf, Rng};

use super::{Query, TaskBatch};

#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub vocab: usize,
    pub seq: usize,
    /// fraction of positions drawn from the Markov content chain
    pub markov_weight: f64,
    /// number of fact bindings planted per sequence
    pub n_facts: usize,
    /// distance band (min, max) between binding and re-query
    pub recall_band: (usize, usize),
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 256,
            seq: 64,
            markov_weight: 0.6,
            n_facts: 3,
            recall_band: (8, 48),
        }
    }
}

/// Deterministic synthetic corpus sampler.
pub struct Corpus {
    cfg: CorpusConfig,
    zipf: Zipf,
    /// Markov successor table: next[token][slot] -> token
    next: Vec<[usize; 4]>,
    key_lo: usize,
    key_n: usize,
    val_lo: usize,
    val_n: usize,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        let content_n = cfg.vocab * 3 / 4;
        let next = (0..content_n)
            .map(|_| {
                [
                    rng.below(content_n),
                    rng.below(content_n),
                    rng.below(content_n),
                    rng.below(content_n),
                ]
            })
            .collect();
        let key_lo = content_n;
        let key_n = (cfg.vocab - content_n) / 2;
        let val_lo = key_lo + key_n;
        let val_n = cfg.vocab - val_lo;
        Corpus {
            zipf: Zipf::new(content_n, 1.05),
            cfg,
            next,
            key_lo,
            key_n,
            val_lo,
            val_n,
        }
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    /// Sample one document of `seq` tokens; returns (tokens, recall queries).
    pub fn sample_doc(&self, rng: &mut Rng) -> (Vec<i32>, Vec<(usize, i32)>) {
        let seq = self.cfg.seq;
        let mut row = Vec::with_capacity(seq);
        let mut state = self.zipf.sample(rng);
        for _ in 0..seq {
            state = if rng.chance(self.cfg.markov_weight) {
                self.next[state][rng.below(4)]
            } else {
                self.zipf.sample(rng)
            };
            row.push(state as i32);
        }
        // plant facts: k v at p, re-query k -> v at p + gap
        let mut recalls = Vec::new();
        for _ in 0..self.cfg.n_facts {
            let (lo, hi) = self.cfg.recall_band;
            let gap = rng.range(lo, hi.min(seq - 3).max(lo + 1));
            if seq < gap + 4 {
                continue;
            }
            let p = rng.below(seq - gap - 3);
            let key = (self.key_lo + rng.below(self.key_n)) as i32;
            let val = (self.val_lo + rng.below(self.val_n)) as i32;
            row[p] = key;
            row[p + 1] = val;
            row[p + gap] = key;
            row[p + gap + 1] = val;
            recalls.push((p + gap, val));
        }
        (row, recalls)
    }

    /// A training batch (tokens only).
    pub fn train_batch(&self, batch: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * self.cfg.seq);
        for _ in 0..batch {
            out.extend(self.sample_doc(rng).0);
        }
        out
    }

    /// Held-out eval batch with recall queries attached (for recall
    /// accuracy and per-position loss).
    pub fn eval_batch(&self, batch: usize, rng: &mut Rng) -> TaskBatch {
        let mut tokens = Vec::with_capacity(batch * self.cfg.seq);
        let mut queries = Vec::new();
        for b in 0..batch {
            let (row, recalls) = self.sample_doc(rng);
            for (pos, val) in recalls {
                queries.push(Query { batch_idx: b, pos, answer: val });
            }
            tokens.extend(row);
        }
        TaskBatch { tokens, batch, seq: self.cfg.seq, queries }
    }

    /// LAMBADA-style cloze: the final token repeats a content token that
    /// appeared exactly once, early in the document.
    pub fn lambada_batch(&self, batch: usize, rng: &mut Rng) -> TaskBatch {
        let mut tokens = Vec::with_capacity(batch * self.cfg.seq);
        let mut queries = Vec::new();
        let seq = self.cfg.seq;
        for b in 0..batch {
            let (mut row, _) = self.sample_doc(rng);
            let key = (self.key_lo + rng.below(self.key_n)) as i32;
            let val = (self.val_lo + rng.below(self.val_n)) as i32;
            let p = rng.range(1, seq / 4);
            row[p] = key;
            row[p + 1] = val;
            row[seq - 2] = key;
            row[seq - 1] = val;
            queries.push(Query { batch_idx: b, pos: seq - 2, answer: val });
            tokens.extend(row);
        }
        TaskBatch { tokens, batch, seq, queries }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn docs_have_right_shape_and_vocab() {
        let c = Corpus::new(CorpusConfig::default(), 42);
        let mut rng = Rng::new(1);
        let (doc, recalls) = c.sample_doc(&mut rng);
        assert_eq!(doc.len(), 64);
        assert!(doc.iter().all(|&t| (t as usize) < c.vocab()));
        assert!(!recalls.is_empty());
    }

    #[test]
    fn eval_batches_are_consistent() {
        let c = Corpus::new(CorpusConfig::default(), 42);
        let mut rng = Rng::new(2);
        let tb = c.eval_batch(4, &mut rng);
        assert!(tb.queries_consistent());
        let lb = c.lambada_batch(4, &mut rng);
        assert!(lb.queries_consistent());
        assert_eq!(lb.queries.len(), 4);
    }

    #[test]
    fn same_seed_same_corpus() {
        let c1 = Corpus::new(CorpusConfig::default(), 7);
        let c2 = Corpus::new(CorpusConfig::default(), 7);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_eq!(c1.train_batch(2, &mut r1), c2.train_batch(2, &mut r2));
    }

    #[test]
    fn markov_structure_is_learnable() {
        // successors repeat: the conditional entropy of the chain is far
        // below log2(vocab) — quick statistical check that the corpus has
        // learnable local structure.
        let c = Corpus::new(CorpusConfig::default(), 9);
        let mut rng = Rng::new(3);
        let toks = c.train_batch(64, &mut rng);
        // BTreeMap keeps even this count deterministic-by-iteration-order
        // (the tree-wide no-HashMap convention the xtask determinism lint
        // enforces on serving paths)
        let mut bigram = std::collections::BTreeMap::new();
        for w in toks.chunks(64) {
            for pair in w.windows(2) {
                *bigram.entry((pair[0], pair[1])).or_insert(0usize) += 1;
            }
        }
        let distinct = bigram.len() as f64;
        let total: usize = bigram.values().sum();
        // random tokens would give ~total distinct bigrams
        assert!(distinct < 0.8 * total as f64, "{distinct} vs {total}");
    }
}
