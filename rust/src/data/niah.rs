//! Needle-In-A-Haystack suite (RULER; paper Table 4 / Fig. 10).
//!
//! Six tasks, each a synthetic analogue of the RULER variant (DESIGN.md
//! §6): a haystack of Zipf-distributed filler hides needles of the form
//! `NEEDLE_MARK key value…`; the probe `QUERY_MARK key` at the end must be
//! answered with the value token(s). Variants differ in needle count,
//! value length, number of queried needles and number of values per key:
//!
//! - `SNiah1` — pass-key: 1 needle, 1-token value
//! - `SNiah2` — number-in-haystack: 1 needle, 3-token value
//! - `SNiah3` — uuid-in-haystack: 1 needle, 6-token value
//! - `MkNiah` — multi-key: 4 needles, 1 queried
//! - `MqNiah` — multi-query: 4 needles, 2 queried
//! - `MvNiah` — multi-value: 1 key bound to 3 values, all queried

use crate::util::{rng::Zipf, Rng};

use super::{Query, TaskBatch};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NiahTask {
    SNiah1,
    SNiah2,
    SNiah3,
    MkNiah,
    MqNiah,
    MvNiah,
}

impl NiahTask {
    pub fn name(&self) -> &'static str {
        match self {
            NiahTask::SNiah1 => "S-NIAH-1",
            NiahTask::SNiah2 => "S-NIAH-2",
            NiahTask::SNiah3 => "S-NIAH-3",
            NiahTask::MkNiah => "MK-NIAH-1",
            NiahTask::MqNiah => "MQ-NIAH",
            NiahTask::MvNiah => "MV-NIAH",
        }
    }

    pub fn all() -> &'static [NiahTask] {
        &[
            NiahTask::SNiah1,
            NiahTask::SNiah2,
            NiahTask::SNiah3,
            NiahTask::MkNiah,
            NiahTask::MqNiah,
            NiahTask::MvNiah,
        ]
    }

    fn spec(&self) -> (usize, usize, usize, usize) {
        // (n_needles, value_len, n_queried, values_per_key)
        match self {
            NiahTask::SNiah1 => (1, 1, 1, 1),
            NiahTask::SNiah2 => (1, 3, 1, 1),
            NiahTask::SNiah3 => (1, 6, 1, 1),
            NiahTask::MkNiah => (4, 1, 1, 1),
            NiahTask::MqNiah => (4, 1, 2, 1),
            NiahTask::MvNiah => (1, 1, 1, 3),
        }
    }
}

#[derive(Debug, Clone)]
pub struct NiahConfig {
    pub seq: usize,
    pub vocab: usize,
}

impl Default for NiahConfig {
    fn default() -> Self {
        NiahConfig { seq: 512, vocab: 512 }
    }
}

const NEEDLE_MARK: i32 = 1;
const QUERY_MARK: i32 = 2;

/// Generate one batch. Needle depth is uniform over the haystack.
pub fn generate(task: NiahTask, cfg: &NiahConfig, batch: usize, rng: &mut Rng) -> TaskBatch {
    let (n_needles, value_len, n_queried, vals_per_key) = task.spec();
    // vocabulary layout: [0,4) specials; keys/values from the top quarter;
    // filler from the bulk.
    let key_lo = cfg.vocab * 3 / 4;
    let key_n = (cfg.vocab - key_lo) / 2;
    let val_lo = key_lo + key_n;
    let val_n = cfg.vocab - val_lo;
    let filler = Zipf::new(key_lo - 4, 1.1);

    let mut tokens = Vec::with_capacity(batch * cfg.seq);
    let mut queries = Vec::new();
    for b in 0..batch {
        let keys = rng.sample_indices(key_n, n_needles);
        // values: per needle, vals_per_key sequences of value_len tokens
        let needle_vals: Vec<Vec<Vec<i32>>> = (0..n_needles)
            .map(|_| {
                (0..vals_per_key)
                    .map(|_| (0..value_len).map(|_| (val_lo + rng.below(val_n)) as i32).collect())
                    .collect()
            })
            .collect();

        // needle segments: MARK key v...v  (per value binding)
        let mut segments: Vec<Vec<i32>> = Vec::new();
        for (ni, &key) in keys.iter().enumerate() {
            for vi in 0..vals_per_key {
                let mut seg = vec![NEEDLE_MARK, (key_lo + key) as i32];
                seg.extend(&needle_vals[ni][vi]);
                segments.push(seg);
            }
        }

        // probe: for each queried needle (+each value), QUERY key -> answer
        let queried: Vec<usize> = (0..n_queried).collect();
        let probe_len: usize = queried
            .iter()
            .map(|_| vals_per_key * (2 + value_len))
            .sum();
        let hay_len = cfg.seq - probe_len;
        let seg_total: usize = segments.iter().map(|s| s.len()).sum();
        assert!(seg_total < hay_len, "needles don't fit");

        // place segments at random non-overlapping depths
        let mut row: Vec<i32> = (0..hay_len).map(|_| (4 + filler.sample(rng)) as i32).collect();
        let mut placed: Vec<(usize, usize)> = Vec::new(); // (start, len)
        for seg in &segments {
            loop {
                let start = rng.below(hay_len - seg.len());
                if placed.iter().all(|&(s, l)| start + seg.len() <= s || start >= s + l) {
                    row[start..start + seg.len()].copy_from_slice(seg);
                    placed.push((start, seg.len()));
                    break;
                }
            }
        }

        // probes at the end
        for &ni in &queried {
            for vi in 0..vals_per_key {
                row.push(QUERY_MARK);
                row.push((key_lo + keys[ni]) as i32);
                let qpos = row.len() - 1; // predict first value token from key pos
                for (j, &vt) in needle_vals[ni][vi].iter().enumerate() {
                    queries.push(Query { batch_idx: b, pos: qpos + j, answer: vt });
                    row.push(vt);
                }
            }
        }
        debug_assert_eq!(row.len(), cfg.seq, "row len {} != seq {}", row.len(), cfg.seq);
        tokens.extend_from_slice(&row);
    }
    TaskBatch { tokens, batch, seq: cfg.seq, queries }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_consistent_batches() {
        let cfg = NiahConfig { seq: 256, vocab: 256 };
        let mut rng = Rng::new(1);
        for &task in NiahTask::all() {
            let tb = generate(task, &cfg, 3, &mut rng);
            assert!(tb.queries_consistent(), "{}", task.name());
            assert!(tb.tokens.iter().all(|&t| (t as usize) < cfg.vocab));
            let (_, value_len, n_queried, vpk) = task.spec();
            assert_eq!(tb.queries.len(), 3 * n_queried * vpk * value_len);
        }
    }

    #[test]
    fn needle_key_appears_in_haystack() {
        let cfg = NiahConfig { seq: 256, vocab: 256 };
        let mut rng = Rng::new(2);
        let tb = generate(NiahTask::SNiah1, &cfg, 1, &mut rng);
        // key token (at probe) must appear earlier in the haystack too
        let q = tb.queries[0];
        let key = tb.token(0, q.pos); // key sits at the query position
        let count = (0..q.pos).filter(|&t| tb.token(0, t) == key).count();
        assert!(count >= 1, "needle key missing from haystack");
    }

    #[test]
    fn chance_level_is_low() {
        // A constant predictor should score ~0 on value prediction.
        let cfg = NiahConfig { seq: 256, vocab: 256 };
        let mut rng = Rng::new(3);
        let tb = generate(NiahTask::MkNiah, &cfg, 4, &mut rng);
        let preds = vec![5i32; tb.tokens.len()];
        assert!(tb.accuracy(&preds) < 0.05);
    }
}
