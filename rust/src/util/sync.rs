//! Sync-primitive shim: `std::sync` in real builds, `loom::sync` under
//! `RUSTFLAGS="--cfg loom"` model-checking builds.
//!
//! The thread pool (and anything else that wants its interleavings
//! model-checked) imports `Arc`/`Mutex`/`Condvar`/`mpsc`/`thread`/
//! `atomic` from here instead of `std::sync`. A plain build re-exports
//! std, so this module is zero-cost and tier-1 tests never see loom; a
//! `--cfg loom` build swaps in loom's instrumented doubles, under which
//! `loom::model` exhaustively explores thread interleavings and memory
//! orderings (see `tests/loom_threadpool.rs` and docs/ANALYSIS.md).
//!
//! Two deliberate non-exports:
//!
//! * `OnceLock` — loom has no double for it; the process-wide
//!   [`crate::util::threadpool::resident_pool`] static is `#[cfg(not(loom))]`
//!   and loom models construct (and drop) their own pools instead.
//! * statics — loom atomics are not const-constructible, so anything
//!   that must live in a `static` (e.g. the pool-id counter) uses
//!   `std::sync::atomic` explicitly and stays outside the model.

#[cfg(not(loom))]
pub use std::sync::{atomic, mpsc, Arc, Condvar, Mutex};
#[cfg(not(loom))]
pub use std::thread;

#[cfg(loom)]
pub use loom::sync::{atomic, mpsc, Arc, Condvar, Mutex};
#[cfg(loom)]
pub use loom::thread;
