//! A miniature property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs.
//! On failure it performs a bounded greedy shrink using the generator's
//! `shrink` hook and panics with the minimal failing case, the seed, and
//! the case index so failures are reproducible.

use super::rng::Rng;

/// A generator of random test inputs with an optional shrinker.
pub trait Gen {
    type Item: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Rng) -> Self::Item;
    /// Produce "smaller" candidate inputs. Default: no shrinking.
    fn shrink(&self, _item: &Self::Item) -> Vec<Self::Item> {
        Vec::new()
    }
}

/// Run a property over `cases` random inputs (seed fixed for CI stability,
/// overridable with env `PROP_SEED`).
pub fn check<G: Gen>(name: &str, cases: usize, gen: &G, prop: impl Fn(&G::Item) -> bool) {
    let seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            // Greedy bounded shrink.
            let mut minimal = input.clone();
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in gen.shrink(&minimal) {
                    budget -= 1;
                    if !prop(&cand) {
                        minimal = cand;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (seed={seed}, case={case})\n  original: {input:?}\n  minimal:  {minimal:?}"
            );
        }
    }
}

/// Generator: usize uniform in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Item = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range(self.0, self.1 + 1)
    }
    fn shrink(&self, item: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *item > self.0 {
            out.push(self.0);
            out.push(self.0 + (*item - self.0) / 2);
            out.push(item - 1);
        }
        out.dedup();
        out
    }
}

/// Generator: Vec<f32> with length in [min_len, max_len], values in [lo, hi].
pub struct F32Vec {
    pub min_len: usize,
    pub max_len: usize,
    pub lo: f32,
    pub hi: f32,
}

impl Gen for F32Vec {
    type Item = Vec<f32>;
    fn generate(&self, rng: &mut Rng) -> Vec<f32> {
        let n = rng.range(self.min_len, self.max_len + 1);
        (0..n).map(|_| rng.range_f32(self.lo, self.hi)).collect()
    }
    fn shrink(&self, item: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if item.len() > self.min_len {
            out.push(item[..item.len() / 2.max(self.min_len)].to_vec());
            let mut v = item.clone();
            v.pop();
            out.push(v);
        }
        // Zero out values.
        if item.iter().any(|&x| x != 0.0) {
            out.push(item.iter().map(|_| 0.0).collect());
        }
        out
    }
}

/// Pair generator from two independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Item = (A::Item, B::Item);
    fn generate(&self, rng: &mut Rng) -> Self::Item {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, item: &Self::Item) -> Vec<Self::Item> {
        let mut out = Vec::new();
        for a in self.0.shrink(&item.0) {
            out.push((a, item.1.clone()));
        }
        for b in self.1.shrink(&item.1) {
            out.push((item.0.clone(), b));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("len bounds", 200, &F32Vec { min_len: 1, max_len: 16, lo: -1.0, hi: 1.0 }, |v| {
            v.len() >= 1 && v.len() <= 16 && v.iter().all(|x| (-1.0..1.0).contains(x))
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics_with_info() {
        check("always false", 10, &UsizeIn(0, 100), |_| false);
    }

    #[test]
    fn shrinking_finds_small_case() {
        // Property fails for n >= 10; shrinker should report something < 20.
        let result = std::panic::catch_unwind(|| {
            check("n < 10", 100, &UsizeIn(0, 1000), |&n| n < 10);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal"), "{msg}");
    }

    #[test]
    fn pair_generator_works() {
        check(
            "pair",
            100,
            &Pair(UsizeIn(1, 8), UsizeIn(1, 8)),
            |&(a, b)| a >= 1 && b <= 8,
        );
    }
}
