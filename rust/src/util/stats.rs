//! Descriptive statistics and timing helpers used by the bench harness,
//! the coordinator's metrics, and the experiment tables.

use std::time::Instant;

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least squares fit of `y = a + b x`. Returns `(a, b, r2)`.
///
/// Used to fit empirical complexity exponents: regress `log(time)` on
/// `log(T)` and read the slope (Table 1 reproduction).
pub fn ols(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Fit the scaling exponent p in `time ≈ c * T^p` from (T, time) pairs.
pub fn scaling_exponent(ts: &[usize], times: &[f64]) -> f64 {
    let xs: Vec<f64> = ts.iter().map(|&t| (t as f64).ln()).collect();
    let ys: Vec<f64> = times.iter().map(|&y| y.ln()).collect();
    ols(&xs, &ys).1
}

/// Stopwatch for timing a closure; returns (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Repeat a closure with warmup, collect per-iteration seconds.
pub fn sample_times(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Simple exponential moving average, used for smoothed loss curves.
#[derive(Debug, Clone)]
pub struct Ema {
    pub alpha: f64,
    pub value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v * (1.0 - self.alpha) + x * self.alpha,
        };
        self.value = Some(v);
        v
    }
}

/// Running average with window `w` (Fig. 5 per-position loss smoothing).
pub fn running_average(xs: &[f64], w: usize) -> Vec<f64> {
    assert!(w >= 1);
    let n = xs.len();
    let mut out = Vec::with_capacity(n);
    // prefix sums for O(n)
    let mut pre = Vec::with_capacity(n + 1);
    pre.push(0.0);
    for &x in xs {
        pre.push(pre.last().unwrap() + x);
    }
    let half = w / 2;
    for i in 0..n {
        let lo = i.saturating_sub(half);
        let hi = (i + half + 1).min(n);
        out.push((pre[hi] - pre[lo]) / (hi - lo) as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.9) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn ols_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + 0.5 * x).collect();
        let (a, b, r2) = ols(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn scaling_exponent_quadratic() {
        let ts = [256usize, 512, 1024, 2048];
        let times: Vec<f64> = ts.iter().map(|&t| 1e-9 * (t as f64).powi(2)).collect();
        let p = scaling_exponent(&ts, &times);
        assert!((p - 2.0).abs() < 1e-6, "p={p}");
    }

    #[test]
    fn running_average_window1_is_identity() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(running_average(&xs, 1), xs.to_vec());
    }

    #[test]
    fn running_average_smooths() {
        let xs = [0.0, 10.0, 0.0, 10.0, 0.0, 10.0];
        let sm = running_average(&xs, 3);
        // interior points average over 3
        assert!((sm[2] - 20.0 / 3.0).abs() < 1e-9 || (sm[2] - 10.0 / 3.0).abs() < 1e-9);
        assert_eq!(sm.len(), xs.len());
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..30 {
            e.update(4.0);
        }
        assert!((e.value.unwrap() - 4.0).abs() < 1e-6);
    }
}
