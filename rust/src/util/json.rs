//! A minimal JSON parser/serializer (RFC 8259 subset sufficient for this
//! repo: configs, golden test fixtures, metrics dumps). `serde` is not
//! available offline, so this is hand-rolled and heavily tested.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Get a nested value by dotted path, e.g. `"model.d_model"`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    /// Fetch an f32 vector from an array of numbers.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    // ---- parsing ----
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let b = input.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialization ----
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty output with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{}", x));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(|x| x.into()).collect())
    }
}
impl From<&[f32]> for Json {
    fn from(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.i += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => break,
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
        Ok(Json::Obj(m))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => break,
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
        Ok(Json::Arr(v))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => break,
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("bad surrogate pair"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: find the full char from the source.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                }
            }
        }
        Ok(s)
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.path("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"m":{"x":[1,2.5,-3],"s":"a\"b\\c\nd"},"n":null,"t":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
        // raw multibyte chars round-trip
        let j = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo 世界"));
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn builder_api() {
        let j = Json::obj()
            .set("name", "loglinear")
            .set("dim", 64usize)
            .set("ok", true)
            .set("xs", vec![1.0f64, 2.0]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("dim").unwrap().as_usize(), Some(64));
        assert_eq!(back.get("xs").unwrap().as_f32_vec().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn f32_vec_fixture() {
        let j = Json::parse("[0.5, -1.25, 3]").unwrap();
        assert_eq!(j.as_f32_vec().unwrap(), vec![0.5, -1.25, 3.0]);
    }

    #[test]
    fn integer_formatting_is_stable() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
