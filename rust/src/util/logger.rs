//! A minimal leveled logger writing to stderr with wallclock-relative
//! timestamps. The `log` facade is unavailable offline; this is the subset
//! the coordinator needs (levels, a global sink, cheap macros).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: Lazy<Instant> = Lazy::new(Instant::now);

/// Set the global log level (e.g. from `--log debug`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn set_level_str(s: &str) {
    set_level(match s {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    });
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Core log fn used by the macros.
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debuglog {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! errorlog {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn set_level_str_parses() {
        set_level_str("trace");
        assert!(enabled(Level::Trace));
        set_level_str("info");
        assert!(!enabled(Level::Debug));
    }
}
