//! Deterministic pseudo-random number generation (xoshiro256++ core with a
//! splitmix64 seeder). No external crates; reproducible across platforms.

/// A small, fast, seedable PRNG (xoshiro256++).
///
/// Used everywhere randomness is needed: synthetic data generation,
/// property-test input generation, weight init for Rust-side reference
/// models, and workload traces. Deterministic given the seed.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough for our use; modulo bias is
        // negligible for n << 2^64 but we use widening multiply anyway.
        let x = self.next_u64();
        (((x as u128) * (n as u128)) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine
    /// for init/data-gen purposes).
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std as `f32`.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with `N(0, std)` values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.range_f32(lo, hi);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Sample from a discrete distribution given unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork a child generator (for parallel streams) deterministically.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// Sample from a Zipf(s) distribution over `[0, n)` by inverse-CDF on a
/// precomputed table. Used by the synthetic-corpus generator.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.f64();
        match self
            .cdf
            .binary_search_by(|v| v.partial_cmp(&x).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(13);
        let z = Zipf::new(100, 1.2);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[50].max(1) * 5);
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5);
    }
}
