//! A tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and typed
//! accessors with defaults. The `loglinear` binary and all examples use it.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, keyword options, and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an iterator of argument strings.
    pub fn parse<I, S>(args: I) -> Args
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let mut it = args.into_iter().map(|s| s.into()).peekable();

        // First non-flag token is the subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = Some(it.next().unwrap());
            }
        }

        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    let (k, v) = stripped.split_at(eq);
                    out.opts.insert(k.to_string(), v[1..].to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Was `--name` given as a bare flag (or as `--name=true`)?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'"))
            })
            .unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--lens 512,1024,2048`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer '{s}'"))
                })
                .collect(),
        }
    }

    /// Comma-separated string list.
    pub fn str_list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().to_string())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace())
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train data.txt --steps 100 --lr=3e-4 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!((a.f64_or("lr", 0.0) - 3e-4).abs() < 1e-12);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["data.txt"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("serve");
        assert_eq!(a.usize_or("port", 8080), 8080);
        assert_eq!(a.str_or("model", "mamba2"), "mamba2");
        assert!(!a.flag("debug"));
    }

    #[test]
    fn lists() {
        let a = parse("bench --lens 512,1024,2048 --models mamba2,loglinear_mamba2");
        assert_eq!(a.usize_list_or("lens", &[]), vec![512, 1024, 2048]);
        assert_eq!(
            a.str_list_or("models", &[]),
            vec!["mamba2", "loglinear_mamba2"]
        );
        assert_eq!(a.usize_list_or("other", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn no_subcommand_when_flags_first() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn trailing_flag_is_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
    }

    #[test]
    fn eq_form_with_negative_number() {
        let a = parse("x --offset=-5");
        assert_eq!(a.f64_or("offset", 0.0), -5.0);
    }
}
