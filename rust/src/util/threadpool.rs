//! A small fixed-size thread pool (tokio/rayon unavailable offline).
//!
//! Used by the serving coordinator for worker threads, by data
//! generation, and — via [`resident_pool`] + [`par_row_chunks_pooled`] —
//! as the resident scheduler under the tensor GEMM kernels, the batched
//! Fenwick decoder, and the sharded decode step's per-shard jobs.
//! Supports fire-and-forget jobs, a scoped parallel map, and a
//! rayon-style blocking [`ThreadPool::scope`] that lets non-`'static`
//! work run on resident workers (no per-kernel thread spawns — the
//! "pooled GEMM workers" item of the roadmap). Scheduling is
//! **per-worker run queues with work stealing** ([`Queues`]): `execute`
//! spreads jobs round-robin, idle workers steal, and shutdown drains
//! every queue before any worker exits.
//!
//! Sync primitives come from [`crate::util::sync`], so a
//! `RUSTFLAGS="--cfg loom"` build swaps in loom's instrumented doubles
//! and `tests/loom_threadpool.rs` can model-check `scope` completion,
//! panic-in-job, and shutdown ordering. The process-wide [`resident_pool`]
//! and its `par_*` dispatchers are `#[cfg(not(loom))]` (loom has no
//! `OnceLock` double); loom builds get a sequential
//! [`par_row_chunks_pooled`] stand-in so the rest of the crate still
//! compiles unchanged.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::{thread, Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Scheduler state shared by every worker: **per-worker run queues with
/// work stealing** behind one mutex (the sharded-serving follow-on to
/// the old single shared `mpsc` channel). `execute` places jobs
/// round-robin across the queues; each worker drains its own queue
/// oldest-first and, when empty, steals the oldest job from its
/// neighbors' queues (scanning round-robin from its own index). The
/// single lock keeps the model loom-checkable and no more contended
/// than the old `Mutex<Receiver>` — the queues buy *placement*
/// (round-robin spread, stealing keeps stragglers busy), not
/// lock-freedom. Stealing also closes the lost-wakeup window a
/// `notify_one` per push would otherwise have: any awake worker can run
/// any queued job, so a missed notify only ever costs affinity, never
/// liveness.
struct Queues {
    queues: Vec<VecDeque<Job>>,
    /// Set once by `Drop`; workers exit only when this is set AND every
    /// queue is empty, so all queued jobs run before shutdown.
    shutdown: bool,
}

impl Queues {
    /// Next job for worker `me`: own queue first (oldest-first), then
    /// steal the oldest job from the other queues, scanning `me+1..`
    /// round-robin.
    fn pop_for(&mut self, me: usize) -> Option<Job> {
        if let Some(job) = self.queues[me].pop_front() {
            return Some(job);
        }
        let n = self.queues.len();
        for k in 1..n {
            if let Some(job) = self.queues[(me + k) % n].pop_front() {
                return Some(job);
            }
        }
        None
    }
}

struct Sched {
    state: Mutex<Queues>,
    /// Signalled on every push (`notify_one`) and at shutdown
    /// (`notify_all`).
    work: Condvar,
}

/// Process-unique id per pool so worker threads can be attributed to
/// *their* pool (scope's reentrancy check must not confuse two pools).
/// Deliberately `std::sync::atomic` even under loom: loom atomics are
/// not const-constructible in statics, and a monotonically increasing id
/// source has no interleaving behavior worth modeling.
static POOL_IDS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Id of the pool the current thread works for (`usize::MAX` = not a
/// worker). Replaces the old thread-*name* prefix check: a thread-local
/// needs no string match, works for unnamed threads, and has a loom
/// double, so the reentrancy decision itself is part of the model.
#[cfg(not(loom))]
std::thread_local! {
    static CURRENT_POOL: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}
#[cfg(loom)]
loom::thread_local! {
    static CURRENT_POOL: std::cell::Cell<usize> = std::cell::Cell::new(usize::MAX);
}

/// Per-thread count of [`ThreadPool::scope`] calls that took the
/// **dispatch** path (handed jobs to pool workers) rather than running
/// inline. Plain `std` thread-local even under loom, like [`POOL_IDS`]:
/// a monotone counter observed only by the owning thread has no
/// interleaving behavior worth modeling.
std::thread_local! {
    static SCOPE_DISPATCHES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// How many [`ThreadPool::scope`] calls *from the calling thread* have
/// dispatched jobs to pool workers (the non-inline path). This is the
/// observable behind the single-threaded inline guarantee: a
/// `gemm_threads(1)` configuration must never enter the resident pool,
/// whichever kernel dispatch layer (scalar or SIMD) sits underneath —
/// the tensor tests assert a zero delta across whole GEMM/slab sweeps.
/// Thread-local, so concurrently running tests cannot perturb each
/// other's deltas.
pub fn scope_dispatch_count() -> u64 {
    SCOPE_DISPATCHES.with(|c| c.get())
}

#[cfg(not(loom))]
fn spawn_worker(
    name: String,
    body: impl FnOnce() + Send + 'static,
) -> thread::JoinHandle<()> {
    thread::Builder::new().name(name).spawn(body).expect("spawn worker")
}

/// loom's `thread` double has plain `spawn` only; model threads don't
/// need names (worker identity rides on `CURRENT_POOL`).
#[cfg(loom)]
fn spawn_worker(
    _name: String,
    body: impl FnOnce() + Send + 'static,
) -> thread::JoinHandle<()> {
    thread::spawn(body)
}

/// Fixed-size pool of worker threads with per-worker run queues and
/// work stealing (see [`Queues`]).
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sched: Arc<Sched>,
    /// Round-robin placement cursor for `execute`. `std::sync::atomic`
    /// even under loom, like [`POOL_IDS`]: a monotonically increasing
    /// counter used only to spread placement has no interleaving
    /// behavior worth modeling (any value is correct — stealing
    /// rebalances).
    next: std::sync::atomic::AtomicUsize,
    /// Process-unique pool id; workers stamp it into `CURRENT_POOL`.
    id: usize,
}

/// One worker's life: pop (own queue, else steal), run, repeat; park on
/// the condvar when every queue is empty; exit only once shutdown is
/// flagged AND no queued job remains.
fn worker_loop(sched: &Sched, me: usize) {
    loop {
        let job = {
            let mut q = sched.state.lock().unwrap();
            loop {
                if let Some(job) = q.pop_for(me) {
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = sched.work.wait(q).unwrap();
            }
        };
        job();
    }
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let id = POOL_IDS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let sched = Arc::new(Sched {
            state: Mutex::new(Queues {
                queues: (0..n).map(|_| VecDeque::new()).collect(),
                shutdown: false,
            }),
            work: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let sched = Arc::clone(&sched);
            workers.push(spawn_worker(format!("pool{id}-{i}"), move || {
                CURRENT_POOL.with(|c| c.set(id));
                worker_loop(&sched, i);
            }));
        }
        ThreadPool {
            workers,
            sched,
            next: std::sync::atomic::AtomicUsize::new(0),
            id,
        }
    }

    /// Submit a job for asynchronous execution. Placement is round-robin
    /// across the per-worker queues; an idle worker whose own queue is
    /// empty steals it anyway, so placement affects affinity, not
    /// completion.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let slot = self.next.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
            % self.workers.len();
        {
            let mut q = self.sched.state.lock().unwrap();
            assert!(!q.shutdown, "pool closed");
            q.queues[slot].push_back(Box::new(job));
        }
        self.sched.work.notify_one();
    }

    /// Is the calling thread one of this pool's own workers?
    fn on_own_worker(&self) -> bool {
        CURRENT_POOL.with(|c| c.get() == self.id)
    }

    /// Run a batch of non-`'static` jobs on the pool, blocking until all
    /// of them complete (scoped-threads semantics on resident workers).
    ///
    /// Worker panics are caught so the completion counter always drains,
    /// then re-raised here once every job has finished. Called from one
    /// of *this pool's own* worker threads the jobs run inline instead
    /// (a blocked worker waiting on its own pool would deadlock a
    /// single-worker pool); workers of other pools dispatch normally.
    ///
    /// Soundness hinges on one guarantee — **`scope` never returns, by
    /// any path, while a dispatched job can still be running** — which
    /// the completion barrier below enforces even if dispatch itself
    /// panics. The loom model in `tests/loom_threadpool.rs` checks the
    /// completion/panic/shutdown interleavings; the miri test in
    /// `tests/miri_invariants.rs` checks the borrow erasure.
    pub fn scope<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        if jobs.is_empty() {
            return;
        }
        if self.on_own_worker() || self.size() == 1 {
            for job in jobs {
                job();
            }
            return;
        }
        SCOPE_DISPATCHES.with(|c| c.set(c.get() + 1));
        let total = jobs.len();
        // (jobs still running or not yet accounted, completion signal)
        let sync = Arc::new((Mutex::new(total), Condvar::new()));
        let panicked = Arc::new(AtomicBool::new(false));

        /// Drop guard that re-establishes the completion barrier on
        /// *every* exit path out of `scope`'s dispatch loop: on the
        /// normal path it waits for all dispatched jobs; if dispatch
        /// panics partway (queue closed), it first subtracts the jobs
        /// that were never handed to a worker (they were dropped, not
        /// run) and then waits for the ones that were. Unwinding past
        /// live borrowed-lifetime jobs is thereby impossible.
        struct CompletionBarrier<'a> {
            sync: &'a (Mutex<usize>, Condvar),
            undispatched: usize,
        }
        impl Drop for CompletionBarrier<'_> {
            fn drop(&mut self) {
                let (left, cv) = self.sync;
                // A poisoned counter would mean a worker panicked while
                // holding it — impossible (only arithmetic runs under
                // the lock) — but if it ever happens the barrier cannot
                // be trusted, and returning would let 'env borrows
                // escape into running jobs: abort instead of UB.
                let mut n = match left.lock() {
                    Ok(g) => g,
                    Err(_) => std::process::abort(),
                };
                *n -= self.undispatched;
                while *n > 0 {
                    n = match cv.wait(n) {
                        Ok(g) => g,
                        Err(_) => std::process::abort(),
                    };
                }
            }
        }

        let mut barrier = CompletionBarrier { sync: &*sync, undispatched: total };
        for job in jobs {
            // SAFETY: erasing 'env to 'static is sound because `scope`
            // never returns or unwinds while an erased job can still
            // run:
            //  * every job handed to a worker decrements the completion
            //    counter exactly once — a panicking job is caught
            //    (`catch_unwind` below) and still decrements, and panic
            //    payloads are `'static` by construction, so no 'env
            //    borrow can escape through one;
            //  * `barrier` waits on that counter on both the normal and
            //    the unwind path (see `CompletionBarrier`); a job that
            //    was never dispatched because `execute` panicked is
            //    dropped without running (its captures are plain
            //    borrows) and subtracted via `undispatched`;
            //  * if the barrier is unrecoverable (poisoned counter) the
            //    guard aborts rather than return early.
            // Every borrow captured by `job` (lifetime 'env) therefore
            // strictly outlives its execution.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
            };
            let sync = Arc::clone(&sync);
            let panicked = Arc::clone(&panicked);
            self.execute(move || {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    panicked.store(true, Ordering::SeqCst);
                }
                let (left, cv) = &*sync;
                let mut left = left.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    cv.notify_all();
                }
            });
            barrier.undispatched -= 1;
        }
        // Blocks until every dispatched job has completed.
        drop(barrier);
        if panicked.load(Ordering::SeqCst) {
            panic!("job panicked in ThreadPool::scope");
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    /// Graceful shutdown: flag, wake everyone, join. Workers exit only
    /// when the shutdown flag is set AND every run queue has drained
    /// ([`worker_loop`]), so every job queued before `drop` still runs —
    /// the ordering contract `tests/loom_threadpool.rs` model-checks.
    fn drop(&mut self) {
        {
            let mut q = self.sched.state.lock().unwrap();
            q.shutdown = true;
        }
        self.sched.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The process-wide resident worker pool (one worker per core), shared by
/// the GEMM row-block scheduler and the batched decode read path. Workers
/// are spawned once on first use and live for the process — kernels pay a
/// queue handoff instead of a thread spawn, which is what makes
/// many-small-GEMM regimes (decode batching, short chunks) worth
/// threading at all.
#[cfg(not(loom))]
pub fn resident_pool() -> &'static ThreadPool {
    static POOL: std::sync::OnceLock<ThreadPool> = std::sync::OnceLock::new();
    POOL.get_or_init(|| {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n.max(1))
    })
}

/// Parallel map over items using transient scoped threads; preserves order.
/// For CPU-bound work on this single-core testbed it degrades gracefully
/// to near-sequential execution.
#[cfg(not(loom))]
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let items: Vec<Option<T>> = items.into_iter().map(Some).collect();
    let items = Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>());
    let results = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let next = { items.lock().unwrap().pop() };
                match next {
                    None => break,
                    Some((i, item)) => {
                        let r = f(item.unwrap());
                        results.lock().unwrap()[i] = Some(r);
                    }
                }
            });
        }
    });
    out.into_iter().map(|r| r.unwrap()).collect()
}

/// Row-block parallel-for over a mutable row-major buffer: `out` is split
/// into contiguous blocks of `rows_per_block` whole rows (each `row_len`
/// long) and `f(row0, row1, block)` runs for each block on a transient
/// scoped worker, `par_map`-style. `f` receives the *global* row range
/// [row0, row1) plus the block's own sub-slice (locally indexed from
/// row0), so workers share nothing mutable and need no synchronization.
///
/// This is the *scoped-threads reference implementation*: the production
/// scheduler under the tensor GEMM kernels is [`par_row_chunks_pooled`]
/// (same contract, resident workers); this version is kept as the
/// spawn-per-call baseline and the equivalence oracle in the tests.
#[cfg(not(loom))]
pub fn par_row_chunks<F>(out: &mut [f32], row_len: usize, rows_per_block: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && rows_per_block > 0);
    debug_assert_eq!(out.len() % row_len, 0);
    let block_elems = rows_per_block * row_len;
    if out.len() <= block_elems {
        // single block: run inline, no spawn
        let rows = out.len() / row_len;
        f(0, rows, out);
        return;
    }
    thread::scope(|s| {
        for (bi, chunk) in out.chunks_mut(block_elems).enumerate() {
            let f = &f;
            s.spawn(move || {
                let r0 = bi * rows_per_block;
                let r1 = r0 + chunk.len() / row_len;
                f(r0, r1, chunk);
            });
        }
    });
}

/// [`par_row_chunks`] on the resident worker pool: same contract and the
/// same deterministic row partition, but blocks are dispatched to
/// [`resident_pool`] workers instead of transient scoped threads. This is
/// the scheduler under the tensor GEMM kernels ([`crate::tensor::gemm_into`]
/// and friends) and the batched Fenwick decode read.
///
/// Debug builds carry the determinism sentinel: the realized dispatch
/// partition is hashed and checked against
/// [`crate::tensor::partition_signature`], the pinned row-tiling
/// contract every thread-count-invariance promise rests on. A refactor
/// that changes how blocks are carved (work stealing, dynamic splits)
/// trips the sentinel instead of silently changing summation order.
#[cfg(not(loom))]
pub fn par_row_chunks_pooled<F>(out: &mut [f32], row_len: usize, rows_per_block: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && rows_per_block > 0);
    debug_assert_eq!(out.len() % row_len, 0);
    let block_elems = rows_per_block * row_len;
    if out.len() <= block_elems {
        // single block: run inline, no dispatch
        let rows = out.len() / row_len;
        f(0, rows, out);
        return;
    }
    #[cfg(debug_assertions)]
    {
        let rows = out.len() / row_len;
        let mut sig = crate::tensor::PartitionSig::new();
        let mut r0 = 0usize;
        for chunk in out.chunks(block_elems) {
            let r1 = r0 + chunk.len() / row_len;
            sig.fold(r0, r1);
            r0 = r1;
        }
        debug_assert_eq!(
            sig.finish(),
            crate::tensor::partition_signature(rows, rows_per_block),
            "determinism sentinel: realized row-block partition deviates from the pinned \
             arithmetic tiling ({rows} rows, {rows_per_block} rows/block)"
        );
    }
    let f = &f;
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(block_elems)
        .enumerate()
        .map(|(bi, chunk)| {
            Box::new(move || {
                let r0 = bi * rows_per_block;
                let r1 = r0 + chunk.len() / row_len;
                f(r0, r1, chunk);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    resident_pool().scope(jobs);
}

/// Sequential stand-in so the rest of the crate compiles under loom
/// model-checking builds (the resident pool static has no loom double;
/// GEMM internals are not what loom is modeling).
#[cfg(loom)]
pub fn par_row_chunks_pooled<F>(out: &mut [f32], row_len: usize, rows_per_block: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && rows_per_block > 0);
    let block_elems = rows_per_block * row_len;
    for (bi, chunk) in out.chunks_mut(block_elems).enumerate() {
        let r0 = bi * rows_per_block;
        let r1 = r0 + chunk.len() / row_len;
        f(r0, r1, chunk);
    }
}

// Not compiled under loom: these tests use std-only pieces (recv_timeout,
// par_map, scoped threads); the loom interleaving models live in
// tests/loom_threadpool.rs.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::util::sync::mpsc;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn idle_workers_steal_jobs_queued_behind_a_blocked_worker() {
        // Round-robin placement parks half the jobs on the queue of a
        // worker that is busy for the whole test; the idle worker must
        // steal and run them — placement is affinity, never liveness.
        let pool = ThreadPool::new(2);
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let (done_tx, done_rx) = mpsc::channel::<usize>();
        pool.execute(move || {
            release_rx.recv().unwrap();
        });
        for i in 0..8 {
            let tx = done_tx.clone();
            pool.execute(move || tx.send(i).unwrap());
        }
        let mut got: Vec<usize> = (0..8)
            .map(|_| {
                done_rx
                    .recv_timeout(std::time::Duration::from_secs(10))
                    .expect("jobs behind the blocked worker were never stolen")
            })
            .collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<usize>>());
        release_tx.send(()).unwrap();
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..50).collect();
        let ys = par_map(xs, 4, |x| x * x);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, i * i);
        }
    }

    #[test]
    fn par_map_empty() {
        let ys: Vec<usize> = par_map(Vec::<usize>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn par_row_chunks_covers_every_row_once() {
        // 13 rows of width 5, blocks of 3 rows: the last block is ragged.
        let (rows, width) = (13usize, 5usize);
        let mut buf = vec![0.0f32; rows * width];
        par_row_chunks(&mut buf, width, 3, |r0, r1, chunk| {
            assert_eq!(chunk.len(), (r1 - r0) * width);
            for i in r0..r1 {
                for j in 0..width {
                    chunk[(i - r0) * width + j] += (i * width + j) as f32;
                }
            }
        });
        for (idx, &v) in buf.iter().enumerate() {
            assert_eq!(v, idx as f32, "row element {idx} written wrong or twice");
        }
    }

    #[test]
    fn par_row_chunks_single_block_runs_inline() {
        let mut buf = vec![0.0f32; 4];
        par_row_chunks(&mut buf, 2, 10, |r0, r1, chunk| {
            assert_eq!((r0, r1), (0, 2));
            chunk.fill(1.0);
        });
        assert!(buf.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn scope_runs_all_jobs_and_blocks_until_done() {
        let pool = ThreadPool::new(3);
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(jobs);
        // scope returned => every job has finished (borrow of counter ends here)
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn scope_panicking_job_reraises_after_all_jobs_complete() {
        // The regression test for the lifetime-erasure contract: one job
        // panics, yet scope (a) still waits for every other job, (b)
        // only then re-raises. If the barrier broke, the borrow of
        // `done` below would be dangling inside still-running jobs.
        let pool = ThreadPool::new(2);
        let done = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|i| {
                let done = &done;
                Box::new(move || {
                    if i == 3 {
                        panic!("deliberate test panic in scope job");
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| pool.scope(jobs)));
        assert!(result.is_err(), "scope must re-raise the job panic");
        assert_eq!(
            done.load(Ordering::SeqCst),
            7,
            "every non-panicking job must have completed before scope unwound"
        );
    }

    #[test]
    fn scope_from_inside_a_worker_runs_inline_without_deadlock() {
        // a size-1 pool whose single job opens a nested scope: must not
        // block forever waiting for itself
        let pool = Arc::new(ThreadPool::new(1));
        let (tx, rx) = mpsc::channel();
        let p2 = Arc::clone(&pool);
        pool.execute(move || {
            let hits = AtomicUsize::new(0);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            p2.scope(jobs);
            tx.send(hits.load(Ordering::SeqCst)).unwrap();
        });
        let n = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("nested scope deadlocked");
        assert_eq!(n, 4);
    }

    #[test]
    fn scope_from_another_pools_worker_dispatches_normally() {
        // cross-pool nesting must not be mistaken for self-reentrancy:
        // pool A's worker scoping onto pool B uses B's workers and returns
        let a = Arc::new(ThreadPool::new(1));
        let b = Arc::new(ThreadPool::new(2));
        let (tx, rx) = mpsc::channel();
        let b2 = Arc::clone(&b);
        a.execute(move || {
            let hits = AtomicUsize::new(0);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            b2.scope(jobs);
            tx.send(hits.load(Ordering::SeqCst)).unwrap();
        });
        let n = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("cross-pool scope deadlocked");
        assert_eq!(n, 8);
    }

    #[test]
    fn scope_dispatch_count_tracks_only_the_dispatch_path() {
        // empty job lists and inline paths (single-worker pool, own
        // worker) must not count; a real dispatch from this thread must
        let c0 = scope_dispatch_count();
        let pool1 = ThreadPool::new(1);
        pool1.scope(Vec::new());
        let jobs = |n: usize, hits: &AtomicUsize| -> Vec<Box<dyn FnOnce() + Send + '_>> {
            (0..n)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect()
        };
        let hits = AtomicUsize::new(0);
        pool1.scope(jobs(3, &hits)); // size-1 pool: inline
        assert_eq!(hits.load(Ordering::SeqCst), 3);
        assert_eq!(scope_dispatch_count(), c0, "inline paths must not count as dispatches");
        let pool2 = ThreadPool::new(2);
        pool2.scope(jobs(3, &hits));
        assert_eq!(hits.load(Ordering::SeqCst), 6);
        assert_eq!(scope_dispatch_count(), c0 + 1, "a worker dispatch counts exactly once");
    }

    #[test]
    fn pooled_row_chunks_matches_scoped_version() {
        let (rows, width) = (29usize, 7usize);
        let fill = |r0: usize, r1: usize, chunk: &mut [f32]| {
            for i in r0..r1 {
                for j in 0..width {
                    chunk[(i - r0) * width + j] += (i * width + j) as f32;
                }
            }
        };
        let mut a = vec![0.0f32; rows * width];
        let mut b = vec![0.0f32; rows * width];
        par_row_chunks(&mut a, width, 4, fill);
        par_row_chunks_pooled(&mut b, width, 4, fill);
        assert_eq!(a, b);
    }
}
