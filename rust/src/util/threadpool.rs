//! A small fixed-size thread pool (tokio/rayon unavailable offline).
//!
//! Used by the serving coordinator for worker threads and by data
//! generation. Supports fire-and-forget jobs and a scoped parallel map.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size pool of worker threads consuming from a shared queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: mpsc::Sender<Msg>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            workers.push(
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { workers, tx }
    }

    /// Submit a job for asynchronous execution.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.send(Msg::Run(Box::new(job))).expect("pool closed");
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel map over items using transient scoped threads; preserves order.
/// For CPU-bound work on this single-core testbed it degrades gracefully
/// to near-sequential execution.
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let items: Vec<Option<T>> = items.into_iter().map(Some).collect();
    let items = Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>());
    let results = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let next = { items.lock().unwrap().pop() };
                match next {
                    None => break,
                    Some((i, item)) => {
                        let r = f(item.unwrap());
                        results.lock().unwrap()[i] = Some(r);
                    }
                }
            });
        }
    });
    out.into_iter().map(|r| r.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..50).collect();
        let ys = par_map(xs, 4, |x| x * x);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, i * i);
        }
    }

    #[test]
    fn par_map_empty() {
        let ys: Vec<usize> = par_map(Vec::<usize>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }
}
