//! A small fixed-size thread pool (tokio/rayon unavailable offline).
//!
//! Used by the serving coordinator for worker threads and by data
//! generation. Supports fire-and-forget jobs and a scoped parallel map.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size pool of worker threads consuming from a shared queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: mpsc::Sender<Msg>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            workers.push(
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { workers, tx }
    }

    /// Submit a job for asynchronous execution.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.send(Msg::Run(Box::new(job))).expect("pool closed");
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel map over items using transient scoped threads; preserves order.
/// For CPU-bound work on this single-core testbed it degrades gracefully
/// to near-sequential execution.
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let items: Vec<Option<T>> = items.into_iter().map(Some).collect();
    let items = Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>());
    let results = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let next = { items.lock().unwrap().pop() };
                match next {
                    None => break,
                    Some((i, item)) => {
                        let r = f(item.unwrap());
                        results.lock().unwrap()[i] = Some(r);
                    }
                }
            });
        }
    });
    out.into_iter().map(|r| r.unwrap()).collect()
}

/// Row-block parallel-for over a mutable row-major buffer: `out` is split
/// into contiguous blocks of `rows_per_block` whole rows (each `row_len`
/// long) and `f(row0, row1, block)` runs for each block on a transient
/// scoped worker, `par_map`-style. `f` receives the *global* row range
/// [row0, row1) plus the block's own sub-slice (locally indexed from
/// row0), so workers share nothing mutable and need no synchronization.
/// This is the scheduler under the tensor GEMM kernels
/// ([`crate::tensor::gemm_into`] and friends).
pub fn par_row_chunks<F>(out: &mut [f32], row_len: usize, rows_per_block: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && rows_per_block > 0);
    debug_assert_eq!(out.len() % row_len, 0);
    let block_elems = rows_per_block * row_len;
    if out.len() <= block_elems {
        // single block: run inline, no spawn
        let rows = out.len() / row_len;
        f(0, rows, out);
        return;
    }
    thread::scope(|s| {
        for (bi, chunk) in out.chunks_mut(block_elems).enumerate() {
            let f = &f;
            s.spawn(move || {
                let r0 = bi * rows_per_block;
                let r1 = r0 + chunk.len() / row_len;
                f(r0, r1, chunk);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..50).collect();
        let ys = par_map(xs, 4, |x| x * x);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, i * i);
        }
    }

    #[test]
    fn par_map_empty() {
        let ys: Vec<usize> = par_map(Vec::<usize>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn par_row_chunks_covers_every_row_once() {
        // 13 rows of width 5, blocks of 3 rows: the last block is ragged.
        let (rows, width) = (13usize, 5usize);
        let mut buf = vec![0.0f32; rows * width];
        par_row_chunks(&mut buf, width, 3, |r0, r1, chunk| {
            assert_eq!(chunk.len(), (r1 - r0) * width);
            for i in r0..r1 {
                for j in 0..width {
                    chunk[(i - r0) * width + j] += (i * width + j) as f32;
                }
            }
        });
        for (idx, &v) in buf.iter().enumerate() {
            assert_eq!(v, idx as f32, "row element {idx} written wrong or twice");
        }
    }

    #[test]
    fn par_row_chunks_single_block_runs_inline() {
        let mut buf = vec![0.0f32; 4];
        par_row_chunks(&mut buf, 2, 10, |r0, r1, chunk| {
            assert_eq!((r0, r1), (0, 2));
            chunk.fill(1.0);
        });
        assert!(buf.iter().all(|&v| v == 1.0));
    }
}
