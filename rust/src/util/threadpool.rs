//! A small fixed-size thread pool (tokio/rayon unavailable offline).
//!
//! Used by the serving coordinator for worker threads, by data
//! generation, and — via [`resident_pool`] + [`par_row_chunks_pooled`] —
//! as the resident scheduler under the tensor GEMM kernels and the
//! batched Fenwick decoder. Supports fire-and-forget jobs, a scoped
//! parallel map, and a rayon-style blocking [`ThreadPool::scope`] that
//! lets non-`'static` work run on resident workers (no per-kernel thread
//! spawns — the "pooled GEMM workers" item of the roadmap).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Process-unique id per pool so worker threads can be attributed to
/// *their* pool (scope's reentrancy check must not confuse two pools).
static POOL_IDS: AtomicUsize = AtomicUsize::new(0);

/// Fixed-size pool of worker threads consuming from a shared queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    /// Mutex-wrapped so a `&ThreadPool` can be shared across threads
    /// (the resident pool is a process-wide static).
    tx: Mutex<mpsc::Sender<Msg>>,
    /// worker thread-name prefix, unique to this pool instance
    /// (trailing '-' makes prefix matching unambiguous: "pool1-" never
    /// prefixes a "pool10-" worker name)
    name_prefix: String,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let name_prefix = format!("pool{}-", POOL_IDS.fetch_add(1, Ordering::Relaxed));
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            workers.push(
                thread::Builder::new()
                    .name(format!("{name_prefix}{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { workers, tx: Mutex::new(tx), name_prefix }
    }

    /// Submit a job for asynchronous execution.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .lock()
            .unwrap()
            .send(Msg::Run(Box::new(job)))
            .expect("pool closed");
    }

    /// Run a batch of non-`'static` jobs on the pool, blocking until all
    /// of them complete (scoped-threads semantics on resident workers).
    ///
    /// Worker panics are caught so the completion counter always drains,
    /// then re-raised here. Called from one of *this pool's own* worker
    /// threads the jobs run inline instead (a blocked worker waiting on
    /// its own pool would deadlock a single-worker pool); workers of
    /// other pools dispatch normally.
    pub fn scope<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        if jobs.is_empty() {
            return;
        }
        let on_own_worker = thread::current()
            .name()
            .is_some_and(|n| n.starts_with(self.name_prefix.as_str()));
        if on_own_worker || self.size() == 1 {
            for job in jobs {
                job();
            }
            return;
        }
        let sync = Arc::new((Mutex::new(jobs.len()), Condvar::new()));
        let panicked = Arc::new(AtomicBool::new(false));
        for job in jobs {
            // SAFETY: this function blocks below until every job has
            // signalled completion, so everything borrowed by `job`
            // (lifetime 'env) strictly outlives its execution.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
            };
            let sync = Arc::clone(&sync);
            let panicked = Arc::clone(&panicked);
            self.execute(move || {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    panicked.store(true, Ordering::SeqCst);
                }
                let (left, cv) = &*sync;
                let mut left = left.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    cv.notify_all();
                }
            });
        }
        let (left, cv) = &*sync;
        let mut left = left.lock().unwrap();
        while *left > 0 {
            left = cv.wait(left).unwrap();
        }
        if panicked.load(Ordering::SeqCst) {
            panic!("job panicked in ThreadPool::scope");
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let tx = self.tx.lock().unwrap();
            for _ in &self.workers {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The process-wide resident worker pool (one worker per core), shared by
/// the GEMM row-block scheduler and the batched decode read path. Workers
/// are spawned once on first use and live for the process — kernels pay a
/// queue handoff instead of a thread spawn, which is what makes
/// many-small-GEMM regimes (decode batching, short chunks) worth
/// threading at all.
pub fn resident_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n.max(1))
    })
}

/// Parallel map over items using transient scoped threads; preserves order.
/// For CPU-bound work on this single-core testbed it degrades gracefully
/// to near-sequential execution.
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let items: Vec<Option<T>> = items.into_iter().map(Some).collect();
    let items = Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>());
    let results = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let next = { items.lock().unwrap().pop() };
                match next {
                    None => break,
                    Some((i, item)) => {
                        let r = f(item.unwrap());
                        results.lock().unwrap()[i] = Some(r);
                    }
                }
            });
        }
    });
    out.into_iter().map(|r| r.unwrap()).collect()
}

/// Row-block parallel-for over a mutable row-major buffer: `out` is split
/// into contiguous blocks of `rows_per_block` whole rows (each `row_len`
/// long) and `f(row0, row1, block)` runs for each block on a transient
/// scoped worker, `par_map`-style. `f` receives the *global* row range
/// [row0, row1) plus the block's own sub-slice (locally indexed from
/// row0), so workers share nothing mutable and need no synchronization.
///
/// This is the *scoped-threads reference implementation*: the production
/// scheduler under the tensor GEMM kernels is [`par_row_chunks_pooled`]
/// (same contract, resident workers); this version is kept as the
/// spawn-per-call baseline and the equivalence oracle in the tests.
pub fn par_row_chunks<F>(out: &mut [f32], row_len: usize, rows_per_block: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && rows_per_block > 0);
    debug_assert_eq!(out.len() % row_len, 0);
    let block_elems = rows_per_block * row_len;
    if out.len() <= block_elems {
        // single block: run inline, no spawn
        let rows = out.len() / row_len;
        f(0, rows, out);
        return;
    }
    thread::scope(|s| {
        for (bi, chunk) in out.chunks_mut(block_elems).enumerate() {
            let f = &f;
            s.spawn(move || {
                let r0 = bi * rows_per_block;
                let r1 = r0 + chunk.len() / row_len;
                f(r0, r1, chunk);
            });
        }
    });
}

/// [`par_row_chunks`] on the resident worker pool: same contract and the
/// same deterministic row partition, but blocks are dispatched to
/// [`resident_pool`] workers instead of transient scoped threads. This is
/// the scheduler under the tensor GEMM kernels ([`crate::tensor::gemm_into`]
/// and friends) and the batched Fenwick decode read.
pub fn par_row_chunks_pooled<F>(out: &mut [f32], row_len: usize, rows_per_block: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && rows_per_block > 0);
    debug_assert_eq!(out.len() % row_len, 0);
    let block_elems = rows_per_block * row_len;
    if out.len() <= block_elems {
        // single block: run inline, no dispatch
        let rows = out.len() / row_len;
        f(0, rows, out);
        return;
    }
    let f = &f;
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(block_elems)
        .enumerate()
        .map(|(bi, chunk)| {
            Box::new(move || {
                let r0 = bi * rows_per_block;
                let r1 = r0 + chunk.len() / row_len;
                f(r0, r1, chunk);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    resident_pool().scope(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..50).collect();
        let ys = par_map(xs, 4, |x| x * x);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, i * i);
        }
    }

    #[test]
    fn par_map_empty() {
        let ys: Vec<usize> = par_map(Vec::<usize>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn par_row_chunks_covers_every_row_once() {
        // 13 rows of width 5, blocks of 3 rows: the last block is ragged.
        let (rows, width) = (13usize, 5usize);
        let mut buf = vec![0.0f32; rows * width];
        par_row_chunks(&mut buf, width, 3, |r0, r1, chunk| {
            assert_eq!(chunk.len(), (r1 - r0) * width);
            for i in r0..r1 {
                for j in 0..width {
                    chunk[(i - r0) * width + j] += (i * width + j) as f32;
                }
            }
        });
        for (idx, &v) in buf.iter().enumerate() {
            assert_eq!(v, idx as f32, "row element {idx} written wrong or twice");
        }
    }

    #[test]
    fn par_row_chunks_single_block_runs_inline() {
        let mut buf = vec![0.0f32; 4];
        par_row_chunks(&mut buf, 2, 10, |r0, r1, chunk| {
            assert_eq!((r0, r1), (0, 2));
            chunk.fill(1.0);
        });
        assert!(buf.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn scope_runs_all_jobs_and_blocks_until_done() {
        let pool = ThreadPool::new(3);
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(jobs);
        // scope returned => every job has finished (borrow of counter ends here)
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn scope_from_inside_a_worker_runs_inline_without_deadlock() {
        // a size-1 pool whose single job opens a nested scope: must not
        // block forever waiting for itself
        let pool = Arc::new(ThreadPool::new(1));
        let (tx, rx) = mpsc::channel();
        let p2 = Arc::clone(&pool);
        pool.execute(move || {
            let hits = AtomicUsize::new(0);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            p2.scope(jobs);
            tx.send(hits.load(Ordering::SeqCst)).unwrap();
        });
        let n = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("nested scope deadlocked");
        assert_eq!(n, 4);
    }

    #[test]
    fn scope_from_another_pools_worker_dispatches_normally() {
        // cross-pool nesting must not be mistaken for self-reentrancy:
        // pool A's worker scoping onto pool B uses B's workers and returns
        let a = Arc::new(ThreadPool::new(1));
        let b = Arc::new(ThreadPool::new(2));
        let (tx, rx) = mpsc::channel();
        let b2 = Arc::clone(&b);
        a.execute(move || {
            let hits = AtomicUsize::new(0);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            b2.scope(jobs);
            tx.send(hits.load(Ordering::SeqCst)).unwrap();
        });
        let n = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("cross-pool scope deadlocked");
        assert_eq!(n, 8);
    }

    #[test]
    fn pooled_row_chunks_matches_scoped_version() {
        let (rows, width) = (29usize, 7usize);
        let fill = |r0: usize, r1: usize, chunk: &mut [f32]| {
            for i in r0..r1 {
                for j in 0..width {
                    chunk[(i - r0) * width + j] += (i * width + j) as f32;
                }
            }
        };
        let mut a = vec![0.0f32; rows * width];
        let mut b = vec![0.0f32; rows * width];
        par_row_chunks(&mut a, width, 4, fill);
        par_row_chunks_pooled(&mut b, width, 4, fill);
        assert_eq!(a, b);
    }
}
