//! From-scratch utility substrates.
//!
//! The build is fully offline: crates like `clap`, `serde`, `rand`,
//! `criterion`, and `proptest` are not available, so this module provides
//! the small, well-tested pieces of them the rest of the crate needs.

pub mod rng;
pub mod json;
pub mod cli;
pub mod logger;
pub mod stats;
pub mod sync;
pub mod threadpool;
pub mod prop;

pub use rng::Rng;
pub use stats::Summary;
