//! Metrics registry: counters, gauges, and log-bucketed histograms.
//!
//! [`LogHistogram`] answers the long-lived-server problem that
//! `ServerStats` used to have: percentile latency without an unbounded
//! sample vector. Observations land in fixed log-spaced buckets
//! ([`SUB_BUCKETS`] per octave → ≤ ~9% relative error on any quantile),
//! with exact running `n`/`mean`/`min`/`max`, in O(1) memory forever.
//!
//! [`Registry`] is a deliberately boring, deterministic container: a
//! registration-ordered `Vec` of named metrics with index handles
//! ([`MetricId`]) — no `HashMap` (determinism lint: `src/obs/` is a
//! serving path), no atomics (the server owns its stats mutably; the
//! span recorder's lane counters cover the cross-thread cases). It
//! exists so every serving metric can be enumerated, printed, and
//! exported as one JSON document ([`Registry::to_json`]) instead of
//! being a bag of ad-hoc struct fields.

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Log-bucket resolution: buckets per octave (power of two). 8 gives a
/// worst-case relative quantile error of 2^(1/8) − 1 ≈ 9%.
pub const SUB_BUCKETS: i32 = 8;
/// Smallest resolvable magnitude: 2^[`MIN_EXP`] (≈ 1ns when observing
/// seconds). Anything smaller (or ≤ 0) lands in the first bucket.
pub const MIN_EXP: i32 = -30;
/// Largest resolvable magnitude: 2^[`MAX_EXP`] (≈ 64s as seconds).
/// Anything larger lands in the last bucket.
pub const MAX_EXP: i32 = 6;
/// Total bucket count.
pub const NUM_BUCKETS: usize = ((MAX_EXP - MIN_EXP) * SUB_BUCKETS) as usize;

/// Streaming histogram over log-spaced buckets, with exact running
/// moments and extrema. Fixed memory: `NUM_BUCKETS` u64 counts.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    n: usize,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

/// Bucket index for a value (clamped into range; non-positive → 0).
fn bucket_of(v: f64) -> usize {
    if v.is_nan() || v <= 0.0 {
        return 0;
    }
    let idx = (v.log2() * SUB_BUCKETS as f64).floor() as i64 - (MIN_EXP * SUB_BUCKETS) as i64;
    idx.clamp(0, NUM_BUCKETS as i64 - 1) as usize
}

/// Geometric midpoint of bucket `i` — the quantile representative.
fn bucket_mid(i: usize) -> f64 {
    let exp = (MIN_EXP * SUB_BUCKETS) as f64 + i as f64 + 0.5;
    (exp / SUB_BUCKETS as f64).exp2()
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            counts: vec![0; NUM_BUCKETS],
            n: 0,
            sum: 0.0,
            sumsq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one observation. O(1), allocation-free.
    // xtask: deny_alloc
    #[inline]
    pub fn record(&mut self, v: f64) {
        self.counts[bucket_of(v)] += 1;
        self.n += 1;
        self.sum += v;
        self.sumsq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact running mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Exact running sample standard deviation (n−1 denominator, like
    /// `Summary::of`; 0 for fewer than two observations).
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let n = self.n as f64;
        let m = self.mean();
        ((self.sumsq - n * m * m).max(0.0) / (n - 1.0)).sqrt()
    }

    /// Exact running minimum (0 when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact running maximum (0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Approximate `p`-th percentile (`p` in [0, 100]): the geometric
    /// midpoint of the bucket holding the rank-⌈p·n/100⌉ observation,
    /// clamped to the exact observed [min, max]. Within one bucket width
    /// (≈ 9% relative) of the exact order statistic.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// A [`Summary`] view: exact n/mean/std/min/max, histogram-derived
    /// p50/p90/p99. `None` when empty (matching
    /// `ServerStats::latency_summary`'s old contract).
    pub fn summary(&self) -> Option<Summary> {
        if self.n == 0 {
            return None;
        }
        Some(Summary {
            n: self.n,
            mean: self.mean(),
            std: self.std(),
            min: self.min,
            max: self.max,
            p50: self.percentile(50.0),
            p90: self.percentile(90.0),
            p99: self.percentile(99.0),
        })
    }

    /// Summary-level JSON (no raw buckets).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("n", self.n)
            .set("mean", self.mean())
            .set("std", self.std())
            .set("min", self.min())
            .set("max", self.max())
            .set("p50", self.percentile(50.0))
            .set("p90", self.percentile(90.0))
            .set("p99", self.percentile(99.0))
    }
}

/// Handle into a [`Registry`] — stable for the registry's lifetime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricId(usize);

/// One registered metric.
#[derive(Debug, Clone)]
pub enum Metric {
    Counter(u64),
    Gauge(f64),
    Histogram(LogHistogram),
}

/// Named metrics in registration order. Lookup by name is a linear scan
/// (registration-time only); hot-path updates go through [`MetricId`]
/// handles (O(1) indexed access, no hashing, no allocation).
#[derive(Debug, Clone, Default)]
pub struct Registry {
    items: Vec<(&'static str, Metric)>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn register(&mut self, name: &'static str, m: Metric) -> MetricId {
        if let Some(i) = self.items.iter().position(|(n, _)| *n == name) {
            return MetricId(i);
        }
        self.items.push((name, m));
        MetricId(self.items.len() - 1)
    }

    /// Register (or find) a counter.
    pub fn counter(&mut self, name: &'static str) -> MetricId {
        self.register(name, Metric::Counter(0))
    }

    /// Register (or find) a gauge.
    pub fn gauge(&mut self, name: &'static str) -> MetricId {
        self.register(name, Metric::Gauge(0.0))
    }

    /// Register (or find) a log-bucketed histogram.
    pub fn histogram(&mut self, name: &'static str) -> MetricId {
        self.register(name, Metric::Histogram(LogHistogram::new()))
    }

    /// Increment a counter. No-op on a non-counter id.
    // xtask: deny_alloc
    #[inline]
    pub fn inc(&mut self, id: MetricId, by: u64) {
        if let Metric::Counter(c) = &mut self.items[id.0].1 {
            *c += by;
        }
    }

    /// Set a gauge. No-op on a non-gauge id.
    // xtask: deny_alloc
    #[inline]
    pub fn set(&mut self, id: MetricId, v: f64) {
        if let Metric::Gauge(g) = &mut self.items[id.0].1 {
            *g = v;
        }
    }

    /// Record a histogram observation. No-op on a non-histogram id.
    // xtask: deny_alloc
    #[inline]
    pub fn observe(&mut self, id: MetricId, v: f64) {
        if let Metric::Histogram(h) = &mut self.items[id.0].1 {
            h.record(v);
        }
    }

    /// Metric by name.
    pub fn get(&self, name: &str) -> Option<&Metric> {
        self.items.iter().find(|(n, _)| *n == name).map(|(_, m)| m)
    }

    /// Mutable metric by id — snapshot assembly (e.g. installing an
    /// externally-accumulated histogram into an export registry).
    pub fn get_mut(&mut self, id: MetricId) -> Option<&mut Metric> {
        self.items.get_mut(id.0).map(|(_, m)| m)
    }

    /// Counter value by name (`None` if absent or not a counter).
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        match self.get(name)? {
            Metric::Counter(c) => Some(*c),
            _ => None,
        }
    }

    /// Gauge value by name.
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        match self.get(name)? {
            Metric::Gauge(g) => Some(*g),
            _ => None,
        }
    }

    /// Histogram by name.
    pub fn histogram_ref(&self, name: &str) -> Option<&LogHistogram> {
        match self.get(name)? {
            Metric::Histogram(h) => Some(h),
            _ => None,
        }
    }

    /// All metrics, registration order.
    pub fn iter(&self) -> impl Iterator<Item = (&'static str, &Metric)> {
        self.items.iter().map(|(n, m)| (*n, m))
    }

    /// One JSON object: counters/gauges as numbers, histograms as
    /// summary objects (keys sorted by the `util::json` writer).
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for (name, m) in &self.items {
            obj = match m {
                Metric::Counter(c) => obj.set(*name, *c as f64),
                Metric::Gauge(g) => obj.set(*name, *g),
                Metric::Histogram(h) => obj.set(*name, h.to_json()),
            };
        }
        obj
    }

    /// Plain-text table (name, value / histogram percentiles).
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        for (name, m) in &self.items {
            match m {
                Metric::Counter(c) => out.push_str(&format!("{name:<34} {c}\n")),
                Metric::Gauge(g) => out.push_str(&format!("{name:<34} {g:.6}\n")),
                Metric::Histogram(h) => out.push_str(&format!(
                    "{name:<34} n={} mean={:.3e} p50={:.3e} p90={:.3e} p99={:.3e} max={:.3e}\n",
                    h.count(),
                    h.mean(),
                    h.percentile(50.0),
                    h.percentile(90.0),
                    h.percentile(99.0),
                    h.max(),
                )),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn histogram_percentiles_track_exact_summary() {
        // log-normal-ish latencies spanning several octaves
        let mut rng = Rng::new(0x0B5);
        let samples: Vec<f64> = (0..4000)
            .map(|_| (rng.normal_f32(0.0, 1.0) as f64 * 1.2 - 7.0).exp2())
            .collect();
        let mut h = LogHistogram::new();
        for &s in &samples {
            h.record(s);
        }
        let exact = Summary::of(&samples);
        let approx = h.summary().unwrap();
        // exact moments and extrema
        assert_eq!(approx.n, exact.n);
        assert!((approx.mean - exact.mean).abs() <= 1e-9 * exact.mean.abs().max(1.0));
        assert_eq!(approx.min, exact.min);
        assert_eq!(approx.max, exact.max);
        // quantiles within one log-bucket width (2^(1/8) ≈ 1.091) of exact
        let tol = 2f64.powf(1.0 / SUB_BUCKETS as f64) * 1.0001;
        for (got, want) in [
            (approx.p50, exact.p50),
            (approx.p90, exact.p90),
            (approx.p99, exact.p99),
        ] {
            assert!(
                got / want <= tol && want / got <= tol,
                "histogram quantile {got} vs exact {want} outside {tol}x"
            );
        }
    }

    #[test]
    fn histogram_bucket_boundaries() {
        // values on exact powers of two land in their own bucket; the
        // representative midpoint stays within the bucket's bounds
        let mut h = LogHistogram::new();
        for &v in &[0.5, 1.0, 2.0] {
            h.record(v);
        }
        assert_ne!(bucket_of(0.5), bucket_of(1.0));
        assert_ne!(bucket_of(1.0), bucket_of(2.0));
        let i = bucket_of(1.0);
        let mid = bucket_mid(i);
        assert!((1.0..2f64.powf(1.0 / SUB_BUCKETS as f64)).contains(&mid));
        // out-of-range and non-positive values clamp, never panic
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-3.0), 0);
        assert_eq!(bucket_of(1e-300), 0);
        assert_eq!(bucket_of(1e300), NUM_BUCKETS - 1);
        h.record(0.0);
        h.record(1e300);
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 1e300);
    }

    #[test]
    fn empty_histogram_is_defined() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert!(h.summary().is_none());
    }

    #[test]
    fn single_sample_percentiles_are_exact() {
        let mut h = LogHistogram::new();
        h.record(0.25);
        let s = h.summary().unwrap();
        // clamping to [min, max] makes a single observation exact
        assert_eq!(s.p50, 0.25);
        assert_eq!(s.p99, 0.25);
        assert_eq!(s.min, 0.25);
        assert_eq!(s.max, 0.25);
    }

    #[test]
    fn registry_roundtrip() {
        let mut reg = Registry::new();
        let c = reg.counter("requests_total");
        let g = reg.gauge("pool_occupancy");
        let h = reg.histogram("step_seconds");
        reg.inc(c, 3);
        reg.set(g, 0.75);
        reg.observe(h, 0.001);
        reg.observe(h, 0.002);
        // idempotent registration returns the same handle
        assert_eq!(reg.counter("requests_total"), c);
        assert_eq!(reg.counter_value("requests_total"), Some(3));
        assert_eq!(reg.gauge_value("pool_occupancy"), Some(0.75));
        assert_eq!(reg.histogram_ref("step_seconds").unwrap().count(), 2);
        assert!(reg.counter_value("missing").is_none());
        // JSON export parses back and carries every metric
        let j = crate::util::json::Json::parse(&reg.to_json().to_string()).unwrap();
        assert_eq!(j.get("requests_total").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(
            j.get("step_seconds").and_then(|v| v.get("n")).and_then(|v| v.as_f64()),
            Some(2.0)
        );
        let table = reg.render_table();
        assert!(table.contains("requests_total"));
        assert!(table.contains("step_seconds"));
    }
}
