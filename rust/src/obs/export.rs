//! Trace exporters: Chrome trace-event JSON, per-request timelines, and
//! a plain-text category summary.
//!
//! Everything here consumes the fixed-size [`SpanEvent`]s drained from
//! the recorder ([`crate::obs::drain`]) — export is an offline path and
//! allocates freely; only emission is alloc-constrained.
//!
//! The Chrome export writes the [trace-event format] (`ph: "X"` complete
//! events, `ph: "i"` instants) through the dependency-free
//! [`crate::util::json`] writer, so `chrome://tracing` / Perfetto load
//! it directly: one row per recorder lane (`tid`), microsecond
//! timestamps from the process tracing epoch, and per-span `args`
//! carrying the category payload and attributed kernel flops.
//!
//! [trace-event format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

use std::collections::BTreeMap;

use crate::obs::span::{SpanCat, SpanEvent, ALL_CATS, NUM_CATS};
use crate::util::json::Json;

/// Build a Chrome trace-event JSON document from drained events.
/// `dropped` (the recorder's overflow count) is recorded under
/// `otherData` so a truncated trace is self-describing.
pub fn chrome_trace(events: &[SpanEvent], dropped: u64) -> Json {
    let mut arr: Vec<Json> = Vec::with_capacity(events.len());
    for e in events {
        let ts_us = e.start_ns as f64 / 1e3;
        let mut ev = Json::obj()
            .set("name", e.category().name())
            .set("cat", "serving")
            .set("pid", 1.0)
            .set("tid", e.tid as f64)
            .set("ts", ts_us)
            .set(
                "args",
                Json::obj().set("payload", e.payload as f64).set("flops", e.flops as f64),
            );
        if e.end_ns > e.start_ns {
            ev = ev.set("ph", "X").set("dur", (e.end_ns - e.start_ns) as f64 / 1e3);
        } else {
            ev = ev.set("ph", "i").set("s", "t");
        }
        arr.push(ev);
    }
    Json::obj()
        .set("traceEvents", Json::Arr(arr))
        .set("displayTimeUnit", "ms")
        .set("otherData", Json::obj().set("dropped_events", dropped as f64))
}

/// One request's reconstructed lifecycle, assembled from the spans that
/// carry its request id as payload (`Submit`/`QueueWait`/`Admit`/
/// `PrefillChunk`/`ScoreChunk`/`StreamEmit`/`Cancel`).
#[derive(Debug, Clone, Default)]
pub struct RequestTimeline {
    pub id: u64,
    /// `Submit` span start tick.
    pub submit_ns: Option<u64>,
    /// Queue residency (submit → leaving the FIFO), from the
    /// `QueueWait` closed span.
    pub queue_wait_ns: Option<u64>,
    /// `Admit` span end tick.
    pub admit_ns: Option<u64>,
    /// Prefill chunk spans consumed (count, summed duration, flops).
    pub prefill_chunks: usize,
    pub prefill_ns: u64,
    pub prefill_flops: u64,
    /// Scoring chunk spans consumed.
    pub score_chunks: usize,
    pub score_ns: u64,
    /// `StreamEmit` instants in order — one per streamed event (sampled
    /// token or score row).
    pub stream_ns: Vec<u64>,
    pub cancelled: bool,
}

impl RequestTimeline {
    /// Time to first streamed token/row, from submit. `None` until both
    /// endpoints were captured.
    pub fn ttft_seconds(&self) -> Option<f64> {
        let first = *self.stream_ns.first()?;
        let submit = self.submit_ns?;
        Some(first.saturating_sub(submit) as f64 * 1e-9)
    }

    /// Gaps between consecutive streamed events, in seconds.
    pub fn inter_token_seconds(&self) -> Vec<f64> {
        self.stream_ns.windows(2).map(|w| w[1].saturating_sub(w[0]) as f64 * 1e-9).collect()
    }

    /// Queue wait in seconds, if captured.
    pub fn queue_wait_seconds(&self) -> Option<f64> {
        self.queue_wait_ns.map(|ns| ns as f64 * 1e-9)
    }
}

/// Group request-scoped spans by their payload request id. Events whose
/// category is not request-scoped (decode steps, per-layer kernels) are
/// ignored here — they describe the batch, not one request. Output is
/// sorted by request id.
pub fn timelines(events: &[SpanEvent]) -> Vec<RequestTimeline> {
    let mut by_id: BTreeMap<u64, RequestTimeline> = BTreeMap::new();
    for e in events {
        let cat = e.category();
        let scoped = matches!(
            cat,
            SpanCat::Submit
                | SpanCat::QueueWait
                | SpanCat::Admit
                | SpanCat::PrefillChunk
                | SpanCat::ScoreChunk
                | SpanCat::StreamEmit
                | SpanCat::Cancel
        );
        if !scoped {
            continue;
        }
        let tl = by_id.entry(e.payload).or_insert_with(|| RequestTimeline {
            id: e.payload,
            ..RequestTimeline::default()
        });
        match cat {
            SpanCat::Submit => tl.submit_ns = Some(e.start_ns),
            SpanCat::QueueWait => tl.queue_wait_ns = Some(e.end_ns.saturating_sub(e.start_ns)),
            SpanCat::Admit => tl.admit_ns = Some(e.end_ns),
            SpanCat::PrefillChunk => {
                tl.prefill_chunks += 1;
                tl.prefill_ns += e.end_ns.saturating_sub(e.start_ns);
                tl.prefill_flops += e.flops;
            }
            SpanCat::ScoreChunk => {
                tl.score_chunks += 1;
                tl.score_ns += e.end_ns.saturating_sub(e.start_ns);
            }
            SpanCat::StreamEmit => tl.stream_ns.push(e.start_ns),
            SpanCat::Cancel => tl.cancelled = true,
            _ => {}
        }
    }
    by_id.into_values().collect()
}

/// Per-category aggregate over a drained trace: event count, total
/// duration, attributed flops.
#[derive(Debug, Clone, Copy, Default)]
pub struct CatAgg {
    pub count: usize,
    pub total_ns: u64,
    pub flops: u64,
}

/// Aggregate events by category (indexed by `SpanCat as usize`).
///
/// Note a span's `flops` field includes work rolled up from enclosed
/// child spans, so summing the `flops` column *across categories*
/// double-counts nested work; per-category *self* attribution (each
/// flop counted exactly once) is what [`crate::obs::flop_totals`]
/// reports.
pub fn by_category(events: &[SpanEvent]) -> [CatAgg; NUM_CATS] {
    let mut agg = [CatAgg::default(); NUM_CATS];
    for e in events {
        let a = &mut agg[(e.cat as usize).min(NUM_CATS - 1)];
        a.count += 1;
        a.total_ns += e.end_ns.saturating_sub(e.start_ns);
        a.flops += e.flops;
    }
    agg
}

/// Render a plain-text summary table: one row per category with events,
/// total/mean duration, and attributed flop throughput.
pub fn summary_table(events: &[SpanEvent], dropped: u64) -> String {
    let agg = by_category(events);
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>8} {:>12} {:>12} {:>14} {:>10}\n",
        "category", "events", "total ms", "mean us", "flops", "GFLOP/s"
    ));
    for cat in ALL_CATS {
        let a = agg[cat as usize];
        if a.count == 0 {
            continue;
        }
        let total_ms = a.total_ns as f64 / 1e6;
        let mean_us = a.total_ns as f64 / 1e3 / a.count as f64;
        let gflops = if a.total_ns > 0 { a.flops as f64 / a.total_ns as f64 } else { 0.0 };
        out.push_str(&format!(
            "{:<16} {:>8} {:>12.3} {:>12.2} {:>14} {:>10.2}\n",
            cat.name(),
            a.count,
            total_ms,
            mean_us,
            a.flops,
            gflops
        ));
    }
    if dropped > 0 {
        out.push_str(&format!("(+{dropped} events dropped by ring overflow)\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cat: SpanCat, start: u64, end: u64, payload: u64, flops: u64) -> SpanEvent {
        SpanEvent { start_ns: start, end_ns: end, payload, flops, cat: cat as u8, tid: 0, depth: 0 }
    }

    #[test]
    fn chrome_trace_is_valid_json_and_parses_back() {
        let events = vec![
            ev(SpanCat::Submit, 100, 200, 1, 0),
            ev(SpanCat::DecodeStep, 300, 900, 2, 512),
            ev(SpanCat::StreamEmit, 900, 900, 1, 0), // instant
        ];
        let doc = chrome_trace(&events, 3);
        let parsed = Json::parse(&doc.to_string()).expect("chrome trace must be valid JSON");
        let arr = parsed.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
        assert_eq!(arr.len(), 3);
        // complete event: ph X with dur in microseconds
        let step = &arr[1];
        assert_eq!(step.get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(step.get("name").and_then(|v| v.as_str()), Some("decode_step"));
        assert_eq!(step.get("ts").and_then(|v| v.as_f64()), Some(0.3));
        assert_eq!(step.get("dur").and_then(|v| v.as_f64()), Some(0.6));
        assert_eq!(
            step.get("args").and_then(|a| a.get("flops")).and_then(|v| v.as_f64()),
            Some(512.0)
        );
        // zero-duration event: instant phase
        assert_eq!(arr[2].get("ph").and_then(|v| v.as_str()), Some("i"));
        assert_eq!(
            parsed
                .get("otherData")
                .and_then(|o| o.get("dropped_events"))
                .and_then(|v| v.as_f64()),
            Some(3.0)
        );
    }

    #[test]
    fn timelines_reconstruct_request_lifecycle() {
        let events = vec![
            ev(SpanCat::Submit, 1_000, 1_010, 7, 0),
            ev(SpanCat::QueueWait, 1_000, 51_000, 7, 0),
            ev(SpanCat::Admit, 51_000, 52_000, 7, 0),
            ev(SpanCat::PrefillChunk, 60_000, 90_000, 7, 1000),
            ev(SpanCat::PrefillChunk, 90_000, 120_000, 7, 1200),
            ev(SpanCat::StreamEmit, 130_000, 130_000, 7, 0),
            ev(SpanCat::StreamEmit, 150_000, 150_000, 7, 0),
            ev(SpanCat::StreamEmit, 180_000, 180_000, 7, 0),
            // a different, cancelled request
            ev(SpanCat::Submit, 2_000, 2_010, 9, 0),
            ev(SpanCat::Cancel, 70_000, 71_000, 9, 0),
            // batch-scoped events must not produce timelines
            ev(SpanCat::DecodeStep, 125_000, 131_000, 2, 999),
        ];
        let tls = timelines(&events);
        assert_eq!(tls.len(), 2);
        let t7 = &tls[0];
        assert_eq!(t7.id, 7);
        assert_eq!(t7.submit_ns, Some(1_000));
        assert_eq!(t7.queue_wait_ns, Some(50_000));
        assert_eq!(t7.admit_ns, Some(52_000));
        assert_eq!(t7.prefill_chunks, 2);
        assert_eq!(t7.prefill_ns, 60_000);
        assert_eq!(t7.prefill_flops, 2200);
        assert_eq!(t7.stream_ns.len(), 3);
        assert!((t7.ttft_seconds().unwrap() - 129e-6).abs() < 1e-12);
        let gaps = t7.inter_token_seconds();
        assert_eq!(gaps.len(), 2);
        assert!((gaps[0] - 20e-6).abs() < 1e-12);
        assert!((gaps[1] - 30e-6).abs() < 1e-12);
        assert!((t7.queue_wait_seconds().unwrap() - 50e-6).abs() < 1e-12);
        let t9 = &tls[1];
        assert_eq!(t9.id, 9);
        assert!(t9.cancelled);
        assert!(t9.ttft_seconds().is_none());
    }

    #[test]
    fn summary_table_aggregates_categories() {
        let events = vec![
            ev(SpanCat::DecodeStep, 0, 1_000_000, 0, 2_000_000),
            ev(SpanCat::DecodeStep, 1_000_000, 3_000_000, 0, 4_000_000),
            ev(SpanCat::Advance, 10, 20, 0, 100),
        ];
        let agg = by_category(&events);
        let d = agg[SpanCat::DecodeStep as usize];
        assert_eq!(d.count, 2);
        assert_eq!(d.total_ns, 3_000_000);
        assert_eq!(d.flops, 6_000_000);
        let table = summary_table(&events, 5);
        assert!(table.contains("decode_step"));
        assert!(table.contains("advance_bucket"));
        assert!(table.contains("dropped by ring overflow"));
        // untouched categories are omitted
        assert!(!table.contains("prefix_evict"));
    }
}
