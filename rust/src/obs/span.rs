//! Zero-alloc-on-hot-path span recorder.
//!
//! Emission sites (`span` / `instant` / `record_closed` / `account_flops`)
//! are called from the serving engine's hot loops — the decode step, the
//! per-layer batched advance/read, the GEMM dispatch entry points — so
//! after the one-time [`enable`] they never allocate: every thread writes
//! fixed-size [`SpanEvent`]s into a preallocated ring buffer lane, and a
//! full ring overwrites its oldest event (counting the drop) instead of
//! growing. With tracing disabled (the default) every entry point is a
//! single relaxed atomic load and a branch; with the `obs_off` cargo
//! feature the recorder is compiled out entirely and the emission calls
//! constant-fold to no-ops.
//!
//! Concurrency model: lanes are `Mutex`-guarded but effectively
//! thread-private (each thread is assigned a lane on first emission), so
//! the lock is uncontended on the hot path and only ever contended by
//! [`drain`]. The GEMM *worker* threads never emit spans — flop
//! accounting happens on the dispatching thread at the `tensor` entry
//! points, before row-block parallelization — so in practice one lane
//! per engine loop is active. Sharded decode adds one emitting thread
//! per concurrent shard job ([`SpanCat::ShardStep`] /
//! [`SpanCat::PipelineStage`]); each lands in its own lane, which is
//! exactly the model the lanes exist for. Statics use `std::sync` directly (not the
//! `util::sync` loom shim): loom atomics are not const-constructible,
//! and the recorder is deliberately outside the loom model, like
//! `tensor::GEMM_THREADS` (see `util/sync.rs` docs).
//!
//! Span *nesting* is tracked per lane with a fixed-depth category stack;
//! [`account_flops`] attributes kernel flops to the innermost open span
//! (and, transitively on close, to its ancestors), which is how a
//! `DecodeStep` span ends up carrying the flops of the per-layer
//! `Advance`/`Read`/`Project`/`Logits` GEMMs it encloses.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// `false` when the `obs_off` cargo feature compiled the recorder out:
/// every emission entry point short-circuits on this constant and the
/// optimizer removes the call entirely.
pub const COMPILED: bool = cfg!(not(feature = "obs_off"));

/// Span/event categories — the serving-path taxonomy (docs/OBSERVABILITY.md).
///
/// The discriminant is the wire value stored in [`SpanEvent::cat`] and
/// the index into the per-category flop/byte counters, so the order is
/// part of the (in-process) format; append, don't reorder.
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanCat {
    /// `DecodeServer::submit` / `submit_score` (payload: request id).
    Submit = 0,
    /// Queue residency, submit → leaving the FIFO (payload: request id).
    /// Recorded as a closed span at admission time.
    QueueWait = 1,
    /// Backend admission of one sequence (payload: request id).
    Admit = 2,
    /// Prefix-cache longest-prefix probe (payload: prompt tokens).
    PrefixProbe = 3,
    /// Prefix-cache hit adoption (payload: tokens served from cache).
    PrefixHit = 4,
    /// Prefix-cache LRU eviction under pool pressure (payload: blocks freed).
    PrefixEvict = 5,
    /// One chunkwise prefill ingest for one sequence (payload: request id).
    PrefillChunk = 6,
    /// One scoring chunk (prefill-side log-prob rows; payload: request id).
    ScoreChunk = 7,
    /// One batched decode step over the bucket (payload: occupied rows).
    DecodeStep = 8,
    /// Pool-wide batched Fenwick advance for one layer (payload: bucket rows).
    Advance = 9,
    /// Batched level read for one layer (payload: bucket rows).
    Read = 10,
    /// Layer-to-layer q/k/v projection GEMMs (payload: layer index).
    Project = 11,
    /// Last-layer logits GEMM (payload: bucket rows).
    Logits = 12,
    /// One `StreamEvent` pushed to the stream queue (payload: request id).
    /// Instant event: `start_ns == end_ns`.
    StreamEmit = 13,
    /// `DecodeServer::cancel` (payload: request id).
    Cancel = 14,
    /// Kernel work outside any open span (flop attribution fallback).
    Untracked = 15,
    /// One shard's advance+read job inside a sharded decode step
    /// (payload: shard index).
    ShardStep = 16,
    /// Shard occupancy sample at decode time
    /// (payload: `shard << 32 | blocks_in_use`).
    ShardOccupancy = 17,
    /// One layer's stage inside a shard's pipelined decode job
    /// (payload: layer index). The per-shard layer-boundary buffer
    /// carried through the `LayerProjection` is the pipeline register.
    PipelineStage = 18,
}

/// Number of categories (flop/byte counter array length).
pub const NUM_CATS: usize = 19;

impl SpanCat {
    /// Stable display name (Chrome-trace `name` field, summary tables).
    pub fn name(self) -> &'static str {
        match self {
            SpanCat::Submit => "submit",
            SpanCat::QueueWait => "queue_wait",
            SpanCat::Admit => "admit",
            SpanCat::PrefixProbe => "prefix_probe",
            SpanCat::PrefixHit => "prefix_hit",
            SpanCat::PrefixEvict => "prefix_evict",
            SpanCat::PrefillChunk => "prefill_chunk",
            SpanCat::ScoreChunk => "score_chunk",
            SpanCat::DecodeStep => "decode_step",
            SpanCat::Advance => "advance_bucket",
            SpanCat::Read => "read_batch",
            SpanCat::Project => "project",
            SpanCat::Logits => "logits_gemm",
            SpanCat::StreamEmit => "stream_emit",
            SpanCat::Cancel => "cancel",
            SpanCat::Untracked => "untracked",
            SpanCat::ShardStep => "shard_step",
            SpanCat::ShardOccupancy => "shard_occupancy",
            SpanCat::PipelineStage => "pipeline_stage",
        }
    }

    /// Inverse of the wire discriminant.
    pub fn from_u8(b: u8) -> Option<SpanCat> {
        ALL_CATS.get(b as usize).copied()
    }
}

/// Every category, indexed by discriminant.
pub const ALL_CATS: [SpanCat; NUM_CATS] = [
    SpanCat::Submit,
    SpanCat::QueueWait,
    SpanCat::Admit,
    SpanCat::PrefixProbe,
    SpanCat::PrefixHit,
    SpanCat::PrefixEvict,
    SpanCat::PrefillChunk,
    SpanCat::ScoreChunk,
    SpanCat::DecodeStep,
    SpanCat::Advance,
    SpanCat::Read,
    SpanCat::Project,
    SpanCat::Logits,
    SpanCat::StreamEmit,
    SpanCat::Cancel,
    SpanCat::Untracked,
    SpanCat::ShardStep,
    SpanCat::ShardOccupancy,
    SpanCat::PipelineStage,
];

/// One fixed-size recorded span. `start_ns`/`end_ns` are monotonic ticks
/// from the process-wide epoch ([`now_ns`]); `payload` is
/// category-specific (usually the request id); `flops` is the kernel
/// work attributed to this span *including* enclosed child spans;
/// `depth` is the nesting depth at emission (0 = top level).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SpanEvent {
    pub start_ns: u64,
    pub end_ns: u64,
    pub payload: u64,
    pub flops: u64,
    pub cat: u8,
    pub tid: u16,
    pub depth: u8,
}

impl SpanEvent {
    /// Decoded category (`Untracked` if the wire value is unknown).
    pub fn category(&self) -> SpanCat {
        SpanCat::from_u8(self.cat).unwrap_or(SpanCat::Untracked)
    }

    /// Span duration in seconds.
    pub fn seconds(&self) -> f64 {
        self.end_ns.saturating_sub(self.start_ns) as f64 * 1e-9
    }
}

/// Max simultaneously-tracked emitting threads; a process with more
/// wraps onto shared lanes (events stay valid, per-lane nesting depths
/// may interleave). The serving engine uses one lane per engine loop.
pub const MAX_LANES: usize = 64;

/// Default per-lane ring capacity (events); rings for all [`MAX_LANES`]
/// lanes are allocated up front at [`enable`] time (≈ 40B per event).
pub const DEFAULT_CAPACITY: usize = 1 << 14;

/// Max span nesting depth tracked for flop attribution; deeper spans
/// still record but attribute their flops to [`SpanCat::Untracked`].
pub const MAX_STACK: usize = 32;

struct Lane {
    /// Preallocated ring storage; `len()` is the capacity (0 until `enable`).
    events: Vec<SpanEvent>,
    /// Next write index.
    head: usize,
    /// Valid events in the ring (≤ capacity).
    filled: usize,
    /// Events overwritten before being drained.
    dropped: u64,
    /// Open-span stack: (category, flops accumulated while innermost).
    stack: [(u8, u64); MAX_STACK],
    depth: usize,
    /// Per-category kernel flop/byte totals for work dispatched from
    /// this lane's thread (lane-local, so concurrent threads never
    /// interleave counts — see [`thread_flop_totals`]).
    flops: [u64; NUM_CATS],
    bytes: [u64; NUM_CATS],
}

impl Lane {
    const fn empty() -> Lane {
        Lane {
            events: Vec::new(),
            head: 0,
            filled: 0,
            dropped: 0,
            stack: [(0u8, 0u64); MAX_STACK],
            depth: 0,
            flops: [0u64; NUM_CATS],
            bytes: [0u64; NUM_CATS],
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static LANES: OnceLock<Vec<Mutex<Lane>>> = OnceLock::new();
static NEXT_LANE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static LANE_ID: Cell<usize> = const { Cell::new(usize::MAX) };
}

/// Monotonic nanoseconds since the process-wide tracing epoch (first
/// call wins). Cheap enough for per-span use; all exported timestamps
/// share this origin.
// xtask: deny_alloc
#[inline]
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Is span recording currently on? One relaxed load — this is the whole
/// disabled-mode cost of an emission site (plus the compiled-out `false`
/// under the `obs_off` feature).
// xtask: deny_alloc
#[inline]
pub fn enabled() -> bool {
    COMPILED && ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on with the default per-lane ring capacity.
pub fn enable() {
    enable_with_capacity(DEFAULT_CAPACITY);
}

/// Turn span recording on, (re)sizing every lane's ring to `capacity`
/// events and clearing previously recorded events, drop counts, and
/// flop/byte counters. Call from a quiescent point (no spans open).
pub fn enable_with_capacity(capacity: usize) {
    let capacity = capacity.max(1);
    let lanes = LANES.get_or_init(|| (0..MAX_LANES).map(|_| Mutex::new(Lane::empty())).collect());
    for lane in lanes {
        let mut l = lane.lock().unwrap_or_else(|p| p.into_inner());
        if l.events.len() != capacity {
            l.events.clear();
            l.events.resize(capacity, SpanEvent::default());
        }
        l.head = 0;
        l.filled = 0;
        l.dropped = 0;
        l.depth = 0;
    }
    reset_flops();
    // tick the epoch so the first enable doesn't pay lazy-init mid-span
    now_ns();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn span recording off. Already-open [`SpanGuard`]s still record on
/// drop (their lane state stays consistent); new spans are no-ops.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Zero every lane's per-category flop/byte counters.
pub fn reset_flops() {
    let Some(lanes) = LANES.get() else { return };
    for lane in lanes {
        let mut l = lane.lock().unwrap_or_else(|p| p.into_inner());
        l.flops = [0u64; NUM_CATS];
        l.bytes = [0u64; NUM_CATS];
    }
}

/// Per-category (flops, bytes) totals accumulated since the last
/// [`reset_flops`] / [`enable_with_capacity`], summed over every
/// thread's lane. Index with `SpanCat as usize`.
pub fn flop_totals() -> ([u64; NUM_CATS], [u64; NUM_CATS]) {
    let mut f = [0u64; NUM_CATS];
    let mut b = [0u64; NUM_CATS];
    let Some(lanes) = LANES.get() else { return (f, b) };
    for lane in lanes {
        let l = lane.lock().unwrap_or_else(|p| p.into_inner());
        for i in 0..NUM_CATS {
            f[i] += l.flops[i];
            b[i] += l.bytes[i];
        }
    }
    (f, b)
}

/// Per-category (flops, bytes) totals for kernel work dispatched from
/// *this thread* only. GEMM flops are accounted on the dispatching
/// thread, so a single-threaded driver (a bench, an engine loop) sees
/// all of its kernel work here, unpolluted by other threads.
pub fn thread_flop_totals() -> ([u64; NUM_CATS], [u64; NUM_CATS]) {
    with_lane(|lane, _| (lane.flops, lane.bytes)).unwrap_or(([0; NUM_CATS], [0; NUM_CATS]))
}

/// Total flops across all categories and lanes since the last reset.
pub fn total_flops() -> u64 {
    flop_totals().0.iter().sum()
}

/// Run `f` on this thread's lane. Returns `None` only before the first
/// `enable` (no lanes exist yet). Lock is uncontended on the hot path
/// (lanes are thread-affine); no allocation.
// xtask: deny_alloc
#[inline]
fn with_lane<R>(f: impl FnOnce(&mut Lane, u16) -> R) -> Option<R> {
    let lanes = LANES.get()?;
    let id = LANE_ID.with(|c| {
        let mut id = c.get();
        if id == usize::MAX {
            id = NEXT_LANE.fetch_add(1, Ordering::Relaxed) % MAX_LANES;
            c.set(id);
        }
        id
    });
    let mut lane = lanes[id].lock().unwrap_or_else(|p| p.into_inner());
    Some(f(&mut lane, id as u16))
}

/// Ring write: overwrite-oldest on a full ring, counting the drop, so a
/// drained trace always holds the *most recent* window.
// xtask: deny_alloc
#[inline]
fn push_event(lane: &mut Lane, ev: SpanEvent) {
    let cap = lane.events.len();
    if cap == 0 {
        return;
    }
    if lane.filled == cap {
        lane.dropped += 1;
    } else {
        lane.filled += 1;
    }
    lane.events[lane.head] = ev;
    lane.head = (lane.head + 1) % cap;
}

/// RAII span handle from [`span`]; records the event when dropped.
#[must_use = "a span records on drop — bind it for the region's lifetime"]
pub struct SpanGuard {
    armed: bool,
    cat: SpanCat,
    payload: u64,
    start_ns: u64,
}

impl SpanGuard {
    /// A guard that records nothing (disabled-mode fast path).
    #[inline]
    fn disarmed(cat: SpanCat) -> SpanGuard {
        SpanGuard { armed: false, cat, payload: 0, start_ns: 0 }
    }
}

/// Open a span of category `cat`. The span closes (and its event is
/// recorded) when the returned guard drops. Alloc-free; when tracing is
/// disabled this is one atomic load.
// xtask: deny_alloc
#[inline]
pub fn span(cat: SpanCat, payload: u64) -> SpanGuard {
    if !enabled() {
        return SpanGuard::disarmed(cat);
    }
    let start_ns = now_ns();
    with_lane(|lane, _| {
        if lane.depth < MAX_STACK {
            lane.stack[lane.depth] = (cat as u8, 0);
        }
        lane.depth += 1;
    });
    SpanGuard { armed: true, cat, payload, start_ns }
}

impl Drop for SpanGuard {
    // xtask: deny_alloc
    #[inline]
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let end_ns = now_ns();
        let (cat, payload, start_ns) = (self.cat, self.payload, self.start_ns);
        with_lane(|lane, tid| {
            lane.depth = lane.depth.saturating_sub(1);
            let flops = if lane.depth < MAX_STACK { lane.stack[lane.depth].1 } else { 0 };
            // roll this span's kernel work up into the enclosing span
            if lane.depth > 0 && lane.depth - 1 < MAX_STACK {
                lane.stack[lane.depth - 1].1 += flops;
            }
            push_event(
                lane,
                SpanEvent {
                    start_ns,
                    end_ns,
                    payload,
                    flops,
                    cat: cat as u8,
                    tid,
                    depth: lane.depth as u8,
                },
            );
        });
    }
}

/// Record an instantaneous event (`start == end`), e.g. a stream-queue
/// push. Alloc-free.
// xtask: deny_alloc
#[inline]
pub fn instant(cat: SpanCat, payload: u64) {
    if !enabled() {
        return;
    }
    let t = now_ns();
    record_closed(cat, t, t, payload);
}

/// Record an already-closed span with explicit endpoints — for regions
/// whose start predates the emission site (e.g. queue wait, measured
/// submit → admit). Alloc-free.
// xtask: deny_alloc
#[inline]
pub fn record_closed(cat: SpanCat, start_ns: u64, end_ns: u64, payload: u64) {
    if !enabled() {
        return;
    }
    with_lane(|lane, tid| {
        let depth = lane.depth.min(u8::MAX as usize) as u8;
        push_event(
            lane,
            SpanEvent { start_ns, end_ns, payload, flops: 0, cat: cat as u8, tid, depth },
        );
    });
}

/// Attribute `flops` floating-point operations and `bytes` of kernel
/// traffic to the innermost open span on this thread (falling back to
/// [`SpanCat::Untracked`]). Called by the `tensor` GEMM dispatch entry
/// points on the dispatching thread; alloc-free.
// xtask: deny_alloc
#[inline]
pub fn account_flops(flops: u64, bytes: u64) {
    if !enabled() {
        return;
    }
    with_lane(|lane, _| {
        let cat = if lane.depth > 0 && lane.depth <= MAX_STACK {
            let top = lane.depth - 1;
            lane.stack[top].1 += flops;
            lane.stack[top].0
        } else {
            SpanCat::Untracked as u8
        };
        lane.flops[cat as usize] += flops;
        lane.bytes[cat as usize] += bytes;
    });
}

/// The lane id (== [`SpanEvent::tid`]) this thread records into,
/// assigning one if needed; `None` before the first [`enable`]. Lets a
/// single-threaded driver filter a drained trace down to its own events
/// when other threads may also be emitting.
pub fn current_lane() -> Option<u16> {
    with_lane(|_, tid| tid)
}

/// Everything [`drain`] hands back: the recorded events (chronological)
/// plus the overflow-drop count since the last drain/enable.
#[derive(Debug, Clone, Default)]
pub struct Drained {
    pub events: Vec<SpanEvent>,
    /// Total overflow drops across all lanes.
    pub dropped: u64,
    /// Per-lane overflow drops (lanes with a non-zero count only).
    pub dropped_by_lane: Vec<(u16, u64)>,
}

/// Collect and clear every lane's recorded events, sorted by start tick
/// (ties: outermost span first). Not a hot path — allocates the result.
pub fn drain() -> Drained {
    let mut out = Drained::default();
    let Some(lanes) = LANES.get() else { return out };
    for (id, lane) in lanes.iter().enumerate() {
        let mut l = lane.lock().unwrap_or_else(|p| p.into_inner());
        let cap = l.events.len();
        if cap > 0 {
            // chronological unroll: oldest event sits at head - filled
            let start = (l.head + cap - l.filled) % cap;
            for i in 0..l.filled {
                out.events.push(l.events[(start + i) % cap]);
            }
        }
        if l.dropped > 0 {
            out.dropped += l.dropped;
            out.dropped_by_lane.push((id as u16, l.dropped));
        }
        l.head = 0;
        l.filled = 0;
        l.dropped = 0;
    }
    out.events.sort_by_key(|e| (e.start_ns, std::cmp::Reverse(e.end_ns), e.depth));
    out
}

#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    // the recorder is process-global; tests that toggle it serialize here
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain, keeping only this thread's events — other test threads may
    /// emit while tracing is enabled here, but they land in other lanes.
    fn drain_mine() -> Drained {
        let tid = current_lane().expect("recorder enabled");
        let mut d = drain();
        d.events.retain(|e| e.tid == tid);
        d.dropped = d
            .dropped_by_lane
            .iter()
            .find(|(l, _)| *l == tid)
            .map(|(_, n)| *n)
            .unwrap_or(0);
        d
    }

    #[test]
    fn disabled_mode_is_a_no_op() {
        let _g = test_lock();
        // reset recorder state left over from earlier tests, then disable
        enable_with_capacity(4);
        disable();
        let guard = span(SpanCat::DecodeStep, 7);
        instant(SpanCat::StreamEmit, 7);
        account_flops(1000, 4000);
        drop(guard);
        // nothing recorded, nothing counted
        let d = drain_mine();
        assert!(d.events.is_empty(), "disabled mode recorded {} events", d.events.len());
        assert_eq!(d.dropped, 0);
        assert_eq!(thread_flop_totals().0, [0u64; NUM_CATS]);
    }

    #[test]
    fn records_nested_spans_with_flop_attribution() {
        let _g = test_lock();
        enable_with_capacity(64);
        {
            let _outer = span(SpanCat::DecodeStep, 42);
            account_flops(100, 400);
            {
                let _inner = span(SpanCat::Advance, 1);
                account_flops(250, 1000);
            }
            {
                let _inner = span(SpanCat::Read, 1);
                account_flops(50, 200);
            }
        }
        disable();
        let d = drain_mine();
        assert_eq!(d.events.len(), 3);
        assert_eq!(d.dropped, 0);
        // sorted by start: outer first (ties broken outermost-first)
        assert_eq!(d.events[0].category(), SpanCat::DecodeStep);
        assert_eq!(d.events[0].depth, 0);
        assert_eq!(d.events[0].payload, 42);
        let adv = d.events.iter().find(|e| e.category() == SpanCat::Advance).unwrap();
        let rd = d.events.iter().find(|e| e.category() == SpanCat::Read).unwrap();
        assert_eq!(adv.depth, 1);
        assert_eq!(adv.flops, 250);
        assert_eq!(rd.flops, 50);
        // outer span carries its own + children's flops
        assert_eq!(d.events[0].flops, 400);
        // children nest inside the outer interval
        assert!(adv.start_ns >= d.events[0].start_ns && adv.end_ns <= d.events[0].end_ns);
        assert!(rd.start_ns >= adv.end_ns);
        // per-category lane counters saw the same attribution
        let (f, b) = thread_flop_totals();
        assert_eq!(f[SpanCat::DecodeStep as usize], 100);
        assert_eq!(f[SpanCat::Advance as usize], 250);
        assert_eq!(f[SpanCat::Read as usize], 50);
        assert_eq!(b[SpanCat::Advance as usize], 1000);
        assert_eq!(total_flops(), 400);
    }

    #[test]
    fn ring_wraparound_keeps_newest_and_counts_drops() {
        let _g = test_lock();
        enable_with_capacity(8);
        for i in 0..20u64 {
            instant(SpanCat::StreamEmit, i);
        }
        disable();
        let d = drain_mine();
        assert_eq!(d.events.len(), 8, "full ring holds exactly its capacity");
        assert_eq!(d.dropped, 12, "overwrites are counted as drops");
        // the survivors are the *last* 8 events, in order
        let payloads: Vec<u64> = d.events.iter().map(|e| e.payload).collect();
        assert_eq!(payloads, (12..20).collect::<Vec<u64>>());
        // drain cleared the ring and the drop counter
        let d2 = drain_mine();
        assert!(d2.events.is_empty());
        assert_eq!(d2.dropped, 0);
    }

    #[test]
    fn untracked_flops_fall_through_to_their_own_category() {
        let _g = test_lock();
        enable_with_capacity(8);
        account_flops(77, 308);
        disable();
        let (f, _) = thread_flop_totals();
        assert_eq!(f[SpanCat::Untracked as usize], 77);
        drain();
    }

    #[test]
    fn record_closed_preserves_explicit_endpoints() {
        let _g = test_lock();
        enable_with_capacity(8);
        record_closed(SpanCat::QueueWait, 1_000, 5_000, 9);
        disable();
        let d = drain_mine();
        assert_eq!(d.events.len(), 1);
        assert_eq!(d.events[0].start_ns, 1_000);
        assert_eq!(d.events[0].end_ns, 5_000);
        assert_eq!(d.events[0].category(), SpanCat::QueueWait);
        assert!((d.events[0].seconds() - 4e-6).abs() < 1e-12);
    }

    #[test]
    fn category_roundtrip() {
        for (i, c) in ALL_CATS.iter().enumerate() {
            assert_eq!(*c as usize, i);
            assert_eq!(SpanCat::from_u8(i as u8), Some(*c));
            assert!(!c.name().is_empty());
        }
        assert_eq!(SpanCat::from_u8(NUM_CATS as u8), None);
    }
}
