//! Serving-stack observability: spans, metrics, flop accounting, export.
//!
//! The paper's headline claim is *compute log-linear in sequence
//! length*; this module is how the repo shows where a request's time and
//! flops actually go. Three layers:
//!
//! 1. **Span recorder** ([`span`]) — zero-alloc-on-hot-path, per-thread
//!    preallocated ring buffers of fixed-size [`SpanEvent`]s with
//!    monotonic start/end ticks, a category enum ([`SpanCat`]: the
//!    submit→admit→prefill→decode→stream taxonomy, down to the per-layer
//!    `advance_bucket`/`read_batch`/projection/logits kernels), and a
//!    u64 payload. Runtime-toggleable ([`enable`]/[`disable`]; disabled
//!    cost is one relaxed atomic load per site) and compile-out-able
//!    (`--features obs_off`).
//! 2. **Kernel flop/byte accounting** — the `tensor` GEMM dispatch entry
//!    points call [`account_flops`] with their dims-derived flop count;
//!    the recorder attributes it to the innermost open span and to
//!    per-category totals ([`flop_totals`]/[`thread_flop_totals`]), which
//!    is how the prefill bench plots flops-per-token vs prompt length
//!    and checks the O(T log T) growth curve empirically.
//! 3. **Metrics registry** ([`metrics`]) — counters, gauges, and
//!    log-bucketed [`LogHistogram`]s (p50/p90/p99 in fixed memory; the
//!    fix for `ServerStats`' formerly unbounded sample vectors), plus
//!    **exporters** ([`export`]): per-request timeline assembly
//!    ([`RequestTimeline`]: TTFT, queue wait, inter-token gaps), Chrome
//!    trace-event JSON loadable in Perfetto ([`chrome_trace`]), and a
//!    plain-text category summary ([`summary_table`]).
//!
//! Capture workflow (see docs/OBSERVABILITY.md):
//!
//! ```no_run
//! use loglinear::obs;
//! obs::enable();
//! // ... drive the server / backend ...
//! let drained = obs::drain();
//! let doc = obs::chrome_trace(&drained.events, drained.dropped);
//! std::fs::write("trace.json", doc.pretty()).unwrap();
//! println!("{}", obs::summary_table(&drained.events, drained.dropped));
//! obs::disable();
//! ```
//!
//! Instrumentation must never perturb serving numerics — spans only
//! observe timestamps and counters, and the serving-trace differential
//! suite (`coordinator::trace`) continues to pin every instrumented path
//! bit-exactly against the per-sequence oracle replay.

pub mod export;
pub mod metrics;
pub mod span;

pub use export::{by_category, chrome_trace, summary_table, timelines, CatAgg, RequestTimeline};
pub use metrics::{LogHistogram, Metric, MetricId, Registry};
pub use span::{
    account_flops, current_lane, disable, drain, enable, enable_with_capacity, enabled,
    flop_totals, instant, now_ns, record_closed, reset_flops, span, thread_flop_totals,
    total_flops, Drained, SpanCat, SpanEvent, SpanGuard, COMPILED, NUM_CATS,
};
