//! Pool-wide batched Fenwick advance (the ROADMAP batched-*advance* seam:
//! the state-update mirror of [`super::pooled::BatchedDecoder`]'s batched
//! read).
//!
//! The pooled decode path used to *read* every live level of every
//! sequence in a decode bucket as one block-sparse GEMM but *advance*
//! each sequence one at a time — `Σ_i popcount(t_i)` scattered per-block
//! loops per step, each paying its own call overhead and none of them
//! threading. [`BatchedAdvance::advance_bucket`] closes that asymmetry:
//! one call advances a whole bucket, grouping the work by phase and
//! Fenwick level and executing the heavy per-block ops (per-token
//! transitions, sentinel writes) as **one scattered-block dispatch over
//! the [`StatePool`] slab** ([`crate::tensor::slab_block_dispatch`]) on
//! the resident worker pool.
//!
//! **Bit-exactness by shared primitives.** Phases mirror the
//! storage-generic per-sequence skeleton
//! ([`crate::state::update::advance_levels`]) exactly:
//!
//! 1. *Admission* — the pre-mutation `can_advance` contract, batch-wide:
//!    a sequential simulation of per-sequence admission (each admitted
//!    sequence frees its privately-owned merged-out blocks, pays for any
//!    copy-on-write clones of shared — prefix-cached — blocks, and
//!    consumes one sentinel block; the shared
//!    [`crate::state::update::pool_advance_plan`] formula) decides,
//!    **before any mutation**, which sequences step. Refused sequences
//!    are skipped cleanly — levels, position, and pool occupancy
//!    untouched — exactly as if the per-sequence loop had skipped them in
//!    order.
//! 2. *Merge*, sequence-major — each admitted sequence folds its live
//!    levels `0..=lssb(t)` into its lowest live level (the accumulator)
//!    in ascending-level order via the same [`StatePool::axpy`] + release
//!    the per-sequence path uses, cloning a *shared* accumulator into a
//!    private block first (copy-on-write; releasing a shared source just
//!    drops a refcount). Sequence-major execution makes the admission
//!    plan's block accounting hold instant-by-instant: a sequence's CoW
//!    clone lands before its own frees, exactly as the sequential
//!    simulation assumed. Merges stay on the caller thread: amortized one
//!    block-axpy per sequence per step, and the accumulate reads sources
//!    scattered anywhere in the slab.
//! 3. *Transition + write*, one dispatch — after a copy-on-write pre-pass
//!    clones any still-shared carried level into a private block (the
//!    dispatch mutates blocks in place, and shared state is immutable),
//!    every carried (sequence, level) block's per-token transition
//!    ([`crate::state::update::transition_block`]: Mamba-2 decay or GDN
//!    gated Householder) and every admitted sequence's fresh sentinel
//!    write ([`crate::state::update::write_block`]) are independent
//!    per-block ops on disjoint blocks (post-CoW, every block has exactly
//!    one owner), so they run as **one**
//!    [`crate::tensor::slab_block_dispatch`] pass — the dominant
//!    `Σ_i popcount(t_i)` cost of the advance, now threaded with a single
//!    queue handoff. Each block is owned by exactly one worker running
//!    the same primitive as the per-sequence store, so results are
//!    bit-exact for any thread count (asserted by the tests below and the
//!    `decode_batched` bench's pre-timing check).
//!
//! All of a sequence's merge releases happen before any later sequence's
//! net consumption, and every carried-clone/sentinel alloc comes after
//! all merges, so an admission plan that succeeds sequentially always
//! succeeds batched (the pool's low-water mark under batching is no lower
//! than under the loop). Sharing only *decreases* during the pass, so the
//! plan's shared/private split is a conservative bound.

use crate::fenwick;
use crate::state::pool::{BlockId, Precision, StatePool};
use crate::state::pooled::PooledFenwickState;
use crate::state::update::{
    pool_advance_plan, transition_block, transition_block_bf16, write_block, write_block_bf16,
};
use crate::state::Transition;
use crate::tensor;

/// One sequence's per-token inputs for a batched advance: the `(k, v)`
/// sentinel pair, its write scale, and the transition applied to carried
/// states — exactly the argument row of
/// [`PooledFenwickState::advance`].
pub struct AdvanceJob<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub write_scale: f32,
    pub transition: Transition<'a>,
}

/// Work-item tag for the fused transition+write dispatch: which job a
/// block belongs to and which primitive to run on it.
#[derive(Clone, Copy)]
enum BlockOp {
    /// Apply job `j`'s transition to a carried state block.
    Transition(usize),
    /// Write job `j`'s `write_scale · k v^T` sentinel into a fresh block.
    Write(usize),
}

/// Below this many block-elements of transition+write work the fused
/// dispatch stays on the caller thread (same rationale as the batched
/// read's threshold: the resident pool makes a dispatch a queue handoff,
/// but decode-sized buckets of tiny states still don't amortize one).
const ADVANCE_FLOP_THRESHOLD: usize = 1 << 16;

/// Pool-wide batched advance engine (see module docs). Owns its plan
/// workspaces so steady-state bucket steps allocate nothing.
#[derive(Default)]
pub struct BatchedAdvance {
    admitted: Vec<usize>,
    /// fused dispatch plan: (slab block row, op), sorted by row
    ops: Vec<(usize, BlockOp)>,
    rows: Vec<usize>,
    tags: Vec<BlockOp>,
    /// sentinel block per admitted sequence (same order as `admitted`)
    sentinels: Vec<BlockId>,
}

/// Would [`BatchedAdvance::advance_bucket`] admit every sequence right
/// now? The same sequential admission simulation as its phase 1, without
/// mutating anything. The pooled backend polls this before stepping a
/// bucket so prefix-cache LRU eviction can relieve pool pressure
/// *before* the advance runs — a mid-bucket refusal would leave admitted
/// sequences stepped and refused ones behind, which eviction cannot
/// repair after the fact.
pub fn bucket_feasible(pool: &StatePool, seqs: &[&mut PooledFenwickState]) -> bool {
    let mut avail = pool.available();
    for seq in seqs {
        let plan = pool_advance_plan(pool, seq.levels(), seq.t);
        if !plan.feasible(avail) {
            return false;
        }
        avail = (avail as isize + plan.net()) as usize;
    }
    true
}

impl BatchedAdvance {
    pub fn new() -> BatchedAdvance {
        BatchedAdvance::default()
    }

    /// Advance every sequence in the bucket by one token — the pool-wide
    /// analogue of calling [`PooledFenwickState::advance`] on each
    /// `seqs[i]` with `jobs[i]`, in order. Returns the indices of
    /// sequences the pool could not admit (in bucket order); those are
    /// left completely untouched, everything else is stepped. Bit-exact
    /// with the per-sequence loop for both transition families and any
    /// thread count.
    pub fn advance_bucket(
        &mut self,
        pool: &mut StatePool,
        seqs: &mut [&mut PooledFenwickState],
        jobs: &[AdvanceJob<'_>],
    ) -> Vec<usize> {
        assert_eq!(seqs.len(), jobs.len(), "one job per sequence");
        let n = seqs.len();
        if n == 0 {
            return Vec::new();
        }
        let _span = crate::obs::span(crate::obs::SpanCat::Advance, n as u64);
        let (dk, dv) = (seqs[0].dk, seqs[0].dv);
        // hard assert: the fused dispatch below slices the slab at dk·dv
        // strides, so a mismatched pool would silently corrupt unrelated
        // blocks in release builds (once per bucket — cheap)
        assert_eq!(pool.block_elems(), dk * dv, "pool sized for these states");

        // ---- 1) admission: sequential simulation of the per-sequence
        // pre-mutation `can_advance` check (the same refcount-aware
        // `pool_advance_plan` formula `advance_levels` uses via
        // `PoolStore`, so the two paths agree by construction). Nothing
        // is mutated yet, so a refusal here leaves the sequence exactly
        // as it was. Plans are conservative: sharing can only decrease
        // between here and execution.
        let mut refused = Vec::new();
        self.admitted.clear();
        let mut avail = pool.available();
        for (i, seq) in seqs.iter().enumerate() {
            assert_eq!((seq.dk, seq.dv), (dk, dv), "mixed state shapes in bucket");
            assert_eq!(jobs[i].k.len(), dk, "k shape (seq {i})");
            assert_eq!(jobs[i].v.len(), dv, "v shape (seq {i})");
            let plan = pool_advance_plan(pool, seq.levels(), seq.t);
            if plan.feasible(avail) {
                avail = (avail as isize + plan.net()) as usize;
                self.admitted.push(i);
            } else {
                refused.push(i);
            }
        }
        if self.admitted.is_empty() {
            return refused;
        }

        // ---- 2) merge, sequence-major: each admitted sequence folds its
        // live levels 0..=lssb(t) into its lowest live level in ascending
        // order — the exact per-sequence accumulate order — cloning a
        // shared accumulator first (copy-on-write; the clone is charged
        // to this sequence's admission plan, before its own frees).
        for &i in &self.admitted {
            if seqs[i].t == 0 {
                continue;
            }
            let l = fenwick::lssb(seqs[i].t) as usize;
            let mut acc: Option<BlockId> = None;
            for s in 0..=l {
                let Some(src) = seqs[i].levels_mut().get_mut(s).and_then(Option::take) else {
                    continue;
                };
                match acc {
                    None => acc = Some(src),
                    Some(ref mut a) => {
                        if pool.is_shared(*a) {
                            let clone = pool
                                .clone_block(*a)
                                .expect("admission plan reserved the CoW clone");
                            pool.release(*a);
                            *a = clone;
                        }
                        pool.axpy(*a, src, 1.0);
                        pool.release(src);
                    }
                }
            }
            if let Some(acc) = acc {
                let levels = seqs[i].levels_mut();
                if levels.len() <= l + 1 {
                    levels.resize_with(l + 2, || None);
                }
                debug_assert!(levels[l + 1].is_none(), "Fenwick invariant");
                levels[l + 1] = Some(acc);
            }
        }

        // ---- 3) transition + write, one fused scattered-block dispatch.
        // First the copy-on-write pre-pass: the dispatch mutates blocks
        // in place, so any carried level still shared with the prefix
        // cache (or another sequence) is cloned into a private block now.
        // All merge releases already happened, so the plan's reserve
        // covers these clones plus the sentinels (see module docs);
        // alloc() zeroes each sentinel block, exactly like the
        // per-sequence store's write.
        for &i in &self.admitted {
            for slot in seqs[i].levels_mut().iter_mut() {
                if let Some(id) = slot {
                    if pool.is_shared(*id) {
                        let clone = pool
                            .clone_block(*id)
                            .expect("admission plan reserved the CoW clone");
                        pool.release(*id);
                        *slot = Some(clone);
                    }
                }
            }
        }
        self.sentinels.clear();
        for _ in &self.admitted {
            let id = pool.alloc().expect("admission plan reserved this block");
            self.sentinels.push(id);
        }
        self.ops.clear();
        for (slot, &i) in self.admitted.iter().enumerate() {
            for id in seqs[i].levels().iter().flatten() {
                debug_assert!(pool.is_allocated(*id));
                debug_assert!(!pool.is_shared(*id), "CoW pre-pass left a shared block");
                self.ops.push((id.0, BlockOp::Transition(i)));
            }
            self.ops.push((self.sentinels[slot].0, BlockOp::Write(i)));
        }
        self.ops.sort_unstable_by_key(|&(row, _)| row);
        self.rows.clear();
        self.tags.clear();
        for &(row, op) in &self.ops {
            self.rows.push(row);
            self.tags.push(op);
        }
        let threads = if self.rows.len() * dk * dv < ADVANCE_FLOP_THRESHOLD {
            1
        } else {
            tensor::current_gemm_threads().clamp(1, self.rows.len())
        };
        let tags = &self.tags;
        // Same dispatch either way — only the slab element type and the
        // per-block primitive change. The bf16 primitives are the exact
        // ones PoolStore uses, so batched and per-sequence bf16 advances
        // stay bit-exact with each other (docs/PRECISION.md).
        match pool.precision() {
            Precision::F32 => tensor::slab_block_dispatch(
                pool.slab_mut(),
                dk * dv,
                &self.rows,
                threads,
                |j, block| match tags[j] {
                    BlockOp::Transition(i) => transition_block(block, dv, &jobs[i].transition),
                    BlockOp::Write(i) => {
                        write_block(block, dv, jobs[i].k, jobs[i].v, jobs[i].write_scale)
                    }
                },
            ),
            Precision::Bf16 => tensor::slab_block_dispatch(
                pool.slab_bf16_mut(),
                dk * dv,
                &self.rows,
                threads,
                |j, block| match tags[j] {
                    BlockOp::Transition(i) => transition_block_bf16(block, dv, &jobs[i].transition),
                    BlockOp::Write(i) => {
                        write_block_bf16(block, dv, jobs[i].k, jobs[i].v, jobs[i].write_scale)
                    }
                },
            ),
        }

        // ---- 4) install sentinels and bump positions.
        for (slot, &i) in self.admitted.iter().enumerate() {
            let levels = seqs[i].levels_mut();
            if levels.is_empty() {
                levels.resize_with(1, || None);
            }
            debug_assert!(levels[0].is_none(), "sentinel slot must be merged first");
            levels[0] = Some(self.sentinels[slot]);
            seqs[i].bump_t();
        }
        refused
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::pooled::blocks_for_steps;
    use crate::util::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    fn unit(mut v: Vec<f32>) -> Vec<f32> {
        let n = crate::tensor::ops::l2_norm(&v).max(1e-6);
        for x in v.iter_mut() {
            *x /= n;
        }
        v
    }

    /// THE tentpole property: advancing a bucket through the batched pass
    /// is bit-exact with the per-sequence `advance` loop, for mixed
    /// Mamba-2/GDN transitions, mixed positions, and any thread count.
    #[test]
    fn batched_advance_is_bit_exact_with_per_sequence_loop() {
        let (dk, dv, n, steps) = (8usize, 6usize, 7usize, 100usize);
        for threads in [1usize, 4] {
            crate::tensor::gemm_threads(threads);
            let mut rng = Rng::new(0xADB1 + threads as u64);
            let mut pool_a = StatePool::new(dk * dv, n * blocks_for_steps(steps + 16));
            let mut pool_b = StatePool::new(dk * dv, n * blocks_for_steps(steps + 16));
            let mut per_seq: Vec<PooledFenwickState> =
                (0..n).map(|_| PooledFenwickState::new(dk, dv)).collect();
            let mut batched: Vec<PooledFenwickState> =
                (0..n).map(|_| PooledFenwickState::new(dk, dv)).collect();
            // stagger positions so every Fenwick level pattern appears
            for (i, seq) in per_seq.iter_mut().enumerate() {
                for _ in 0..(3 * i) {
                    let k = unit(randv(&mut rng, dk));
                    let v = randv(&mut rng, dv);
                    seq.advance(&mut pool_a, &k, &v, 1.0, Transition::Decay(0.95)).unwrap();
                    batched[i]
                        .advance(&mut pool_b, &k, &v, 1.0, Transition::Decay(0.95))
                        .unwrap();
                }
            }
            let mut adv = BatchedAdvance::new();
            let lambda: Vec<f32> = (0..10).map(|l| 0.8f32.powi(l)).collect();
            for step in 0..steps {
                let ks: Vec<Vec<f32>> = (0..n).map(|_| unit(randv(&mut rng, dk))).collect();
                let vs: Vec<Vec<f32>> = (0..n).map(|_| randv(&mut rng, dv)).collect();
                let alphas: Vec<f32> = (0..n).map(|_| rng.range_f32(0.8, 1.0)).collect();
                let betas: Vec<f32> = (0..n).map(|_| rng.range_f32(0.1, 1.0)).collect();
                let job = |i: usize| {
                    // alternate transition families across the bucket AND
                    // over time so mixed buckets are the common case
                    if (i + step) % 2 == 0 {
                        (1.0, Transition::Decay(alphas[i]))
                    } else {
                        (
                            betas[i],
                            Transition::GatedHouseholder {
                                alpha: alphas[i],
                                beta: betas[i],
                                k: &ks[i],
                            },
                        )
                    }
                };
                for i in 0..n {
                    let (ws, tr) = job(i);
                    per_seq[i].advance(&mut pool_a, &ks[i], &vs[i], ws, tr).unwrap();
                }
                let jobs: Vec<AdvanceJob<'_>> = (0..n)
                    .map(|i| {
                        let (ws, tr) = job(i);
                        AdvanceJob { k: &ks[i], v: &vs[i], write_scale: ws, transition: tr }
                    })
                    .collect();
                let mut refs: Vec<&mut PooledFenwickState> = batched.iter_mut().collect();
                let refused = adv.advance_bucket(&mut pool_b, &mut refs, &jobs);
                assert!(refused.is_empty(), "pool sized for the trace (step {step})");

                assert_eq!(pool_a.in_use(), pool_b.in_use(), "step {step}");
                let q = randv(&mut rng, dk);
                let (mut oa, mut ob) = (vec![0.0f32; dv], vec![0.0f32; dv]);
                for i in 0..n {
                    assert_eq!(per_seq[i].t, batched[i].t, "step {step} seq {i}");
                    assert_eq!(
                        per_seq[i].live_states(),
                        batched[i].live_states(),
                        "step {step} seq {i}"
                    );
                    per_seq[i].read_into(&pool_a, &q, &lambda, &mut oa);
                    batched[i].read_into(&pool_b, &q, &lambda, &mut ob);
                    assert_eq!(oa, ob, "bit-exact divergence at step {step} seq {i} (threads {threads})");
                }
            }
            for mut s in per_seq {
                s.release(&mut pool_a);
            }
            for mut s in batched {
                s.release(&mut pool_b);
            }
            assert_eq!((pool_a.in_use(), pool_b.in_use()), (0, 0));
        }
        crate::tensor::gemm_threads(0);
    }

    /// bf16 twin of the tentpole property: on a reduced-precision slab
    /// the batched pass and the per-sequence loop still agree *bit-exactly
    /// with each other* (they share the bf16 primitives and therefore the
    /// narrowing sequence), even though both diverge from the f32 oracle
    /// within the documented tolerance.
    #[test]
    fn batched_advance_matches_per_sequence_loop_on_bf16_slab() {
        use crate::state::pool::Precision;
        let (dk, dv, n, steps) = (8usize, 6usize, 5usize, 48usize);
        for threads in [1usize, 4] {
            crate::tensor::gemm_threads(threads);
            let mut rng = Rng::new(0xBFAD + threads as u64);
            let cap = n * blocks_for_steps(steps + 16);
            let mut pool_a = StatePool::with_precision(dk * dv, cap, Precision::Bf16);
            let mut pool_b = StatePool::with_precision(dk * dv, cap, Precision::Bf16);
            let mut per_seq: Vec<PooledFenwickState> =
                (0..n).map(|_| PooledFenwickState::new(dk, dv)).collect();
            let mut batched: Vec<PooledFenwickState> =
                (0..n).map(|_| PooledFenwickState::new(dk, dv)).collect();
            let mut adv = BatchedAdvance::new();
            let lambda: Vec<f32> = (0..10).map(|l| 0.8f32.powi(l)).collect();
            for step in 0..steps {
                let ks: Vec<Vec<f32>> = (0..n).map(|_| unit(randv(&mut rng, dk))).collect();
                let vs: Vec<Vec<f32>> = (0..n).map(|_| randv(&mut rng, dv)).collect();
                let alphas: Vec<f32> = (0..n).map(|_| rng.range_f32(0.8, 1.0)).collect();
                let betas: Vec<f32> = (0..n).map(|_| rng.range_f32(0.1, 1.0)).collect();
                let job = |i: usize| {
                    if (i + step) % 2 == 0 {
                        (1.0, Transition::Decay(alphas[i]))
                    } else {
                        (
                            betas[i],
                            Transition::GatedHouseholder {
                                alpha: alphas[i],
                                beta: betas[i],
                                k: &ks[i],
                            },
                        )
                    }
                };
                for i in 0..n {
                    let (ws, tr) = job(i);
                    per_seq[i].advance(&mut pool_a, &ks[i], &vs[i], ws, tr).unwrap();
                }
                let jobs: Vec<AdvanceJob<'_>> = (0..n)
                    .map(|i| {
                        let (ws, tr) = job(i);
                        AdvanceJob { k: &ks[i], v: &vs[i], write_scale: ws, transition: tr }
                    })
                    .collect();
                let mut refs: Vec<&mut PooledFenwickState> = batched.iter_mut().collect();
                let refused = adv.advance_bucket(&mut pool_b, &mut refs, &jobs);
                assert!(refused.is_empty(), "pool sized for the trace (step {step})");
                let q = randv(&mut rng, dk);
                let (mut oa, mut ob) = (vec![0.0f32; dv], vec![0.0f32; dv]);
                for i in 0..n {
                    per_seq[i].read_into(&pool_a, &q, &lambda, &mut oa);
                    batched[i].read_into(&pool_b, &q, &lambda, &mut ob);
                    for (a, b) in oa.iter().zip(ob.iter()) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "bf16 batched/per-seq divergence at step {step} seq {i} (threads {threads})"
                        );
                    }
                }
            }
            for mut s in per_seq {
                s.release(&mut pool_a);
            }
            for mut s in batched {
                s.release(&mut pool_b);
            }
            assert_eq!((pool_a.in_use(), pool_b.in_use()), (0, 0), "bf16 leak (threads {threads})");
        }
        crate::tensor::gemm_threads(0);
    }

    /// Batch-wide admission (satellite): when the pool can only satisfy
    /// some sequences' sentinel writes mid-bucket, exactly the refused
    /// sequences are untouched — levels, position, pool occupancy — and
    /// they recover after `StatePool::grow`.
    #[test]
    fn refused_sequences_are_untouched_and_recover_after_grow() {
        let (dk, dv, n) = (4usize, 4usize, 4usize);
        let mut rng = Rng::new(0xADB2);
        // twin pools: `ref_pool` is big enough for everything (the oracle
        // trajectory), `pool` refuses mid-bucket
        let mut pool = StatePool::new(dk * dv, 4 * n);
        let mut ref_pool = StatePool::new(dk * dv, 4 * n);
        let mut seqs: Vec<PooledFenwickState> =
            (0..n).map(|_| PooledFenwickState::new(dk, dv)).collect();
        let mut oracle: Vec<PooledFenwickState> =
            (0..n).map(|_| PooledFenwickState::new(dk, dv)).collect();
        // park everyone at t = 5 (2 live blocks: levels 0 and 3); the
        // next advance merges only the sentinel (frees nothing) and
        // consumes one fresh block per sequence
        for i in 0..n {
            for _ in 0..5 {
                let k = randv(&mut rng, dk);
                let v = randv(&mut rng, dv);
                seqs[i].advance(&mut pool, &k, &v, 1.0, Transition::Decay(0.9)).unwrap();
                oracle[i].advance(&mut ref_pool, &k, &v, 1.0, Transition::Decay(0.9)).unwrap();
            }
            assert_eq!(seqs[i].live_states(), 2);
        }
        // park extra allocations (other tenants of the pool) until only
        // the first two sequences' sentinel writes fit
        while pool.available() > 2 {
            let _ = pool.alloc().unwrap();
        }
        let in_use_before = pool.in_use();
        let ks: Vec<Vec<f32>> = (0..n).map(|_| randv(&mut rng, dk)).collect();
        let vs: Vec<Vec<f32>> = (0..n).map(|_| randv(&mut rng, dv)).collect();
        let jobs_v: Vec<AdvanceJob<'_>> = (0..n)
            .map(|i| AdvanceJob {
                k: &ks[i],
                v: &vs[i],
                write_scale: 1.0,
                transition: Transition::Decay(0.9),
            })
            .collect();
        let mut adv = BatchedAdvance::new();
        let refused = {
            let mut refs: Vec<&mut PooledFenwickState> = seqs.iter_mut().collect();
            adv.advance_bucket(&mut pool, &mut refs, &jobs_v)
        };
        assert_eq!(refused, vec![2, 3], "exactly the overflow sequences are refused");
        // admitted sequences advanced...
        for i in 0..2 {
            oracle[i].advance(&mut ref_pool, &ks[i], &vs[i], 1.0, Transition::Decay(0.9)).unwrap();
            assert_eq!(seqs[i].t, 6, "seq {i} advanced");
        }
        // ...refused sequences are untouched: levels, position, occupancy
        for i in 2..n {
            assert_eq!(seqs[i].t, 5, "refused seq {i} position mutated");
            assert_eq!(seqs[i].live_states(), 2, "refused seq {i} levels mutated");
        }
        assert_eq!(
            pool.in_use(),
            in_use_before + 2,
            "occupancy must reflect only the two admitted sentinel writes"
        );
        // recovery: grow the pool, re-run the bucket for the refused tail
        pool.grow(8);
        let refused2 = {
            let mut refs: Vec<&mut PooledFenwickState> = seqs.iter_mut().skip(2).collect();
            adv.advance_bucket(&mut pool, &mut refs, &jobs_v[2..])
        };
        assert!(refused2.is_empty(), "grown pool admits the tail");
        for i in 2..n {
            oracle[i].advance(&mut ref_pool, &ks[i], &vs[i], 1.0, Transition::Decay(0.9)).unwrap();
        }
        // everyone's state now matches the never-refused oracle bitwise
        let q = randv(&mut rng, dk);
        let lam = [1.0f32, 0.5, 0.25, 0.125];
        let (mut got, mut want) = (vec![0.0f32; dv], vec![0.0f32; dv]);
        for i in 0..n {
            seqs[i].read_into(&pool, &q, &lam, &mut got);
            oracle[i].read_into(&ref_pool, &q, &lam, &mut want);
            assert_eq!(got, want, "seq {i} diverged from the never-refused oracle");
        }
    }

    /// Copy-on-write under the batched pass: blocks retained by an
    /// external owner (the prefix cache) keep their exact bytes across
    /// advances, the advancing sequence's trajectory stays bit-exact with
    /// a never-shared oracle, and all refcounts drain to zero.
    #[test]
    fn shared_blocks_are_cloned_not_mutated_by_the_batched_advance() {
        let (dk, dv) = (4usize, 4usize);
        let mut pool = StatePool::new(dk * dv, 32);
        let mut ref_pool = StatePool::new(dk * dv, 32);
        let mut rng = Rng::new(0xADB4);
        let mut seq = PooledFenwickState::new(dk, dv);
        let mut oracle = PooledFenwickState::new(dk, dv);
        for _ in 0..6 {
            let k = unit(randv(&mut rng, dk));
            let v = randv(&mut rng, dv);
            seq.advance(&mut pool, &k, &v, 1.0, Transition::Decay(0.9)).unwrap();
            oracle.advance(&mut ref_pool, &k, &v, 1.0, Transition::Decay(0.9)).unwrap();
        }
        // a "cache" retains every live block and remembers the bytes
        let cached: Vec<(BlockId, Vec<f32>)> = seq
            .level_blocks()
            .into_iter()
            .map(|(_, id)| {
                pool.retain(id);
                (id, pool.get(id).to_vec())
            })
            .collect();
        let mut adv = BatchedAdvance::new();
        for step in 0..5 {
            let k = unit(randv(&mut rng, dk));
            let v = randv(&mut rng, dv);
            let tr = if step % 2 == 0 {
                Transition::Decay(0.95)
            } else {
                Transition::GatedHouseholder { alpha: 0.97, beta: 0.4, k: &k }
            };
            let jobs = vec![AdvanceJob { k: &k, v: &v, write_scale: 1.0, transition: tr }];
            let refused = {
                let mut refs: Vec<&mut PooledFenwickState> = vec![&mut seq];
                adv.advance_bucket(&mut pool, &mut refs, &jobs)
            };
            assert!(refused.is_empty(), "pool sized for the trace (step {step})");
            oracle.advance(&mut ref_pool, &k, &v, 1.0, tr).unwrap();
        }
        for (id, bytes) in &cached {
            assert_eq!(pool.get(*id), &bytes[..], "shared (cached) block was mutated");
        }
        let q = randv(&mut rng, dk);
        let lam = [1.0f32, 0.5, 0.25];
        let (mut got, mut want) = (vec![0.0f32; dv], vec![0.0f32; dv]);
        seq.read_into(&pool, &q, &lam, &mut got);
        oracle.read_into(&ref_pool, &q, &lam, &mut want);
        assert_eq!(got, want, "CoW trajectory diverged from the never-shared oracle");
        for (id, _) in cached {
            pool.release(id);
        }
        seq.release(&mut pool);
        assert_eq!(pool.in_use(), 0, "cache refs + sequence release must drain the pool");
    }

    /// Degenerate buckets: empty input, and an all-refused bucket on an
    /// exhausted pool (no mutation at all).
    #[test]
    fn empty_and_fully_refused_buckets_are_no_ops() {
        let (dk, dv) = (4usize, 4usize);
        let mut pool = StatePool::new(dk * dv, 1);
        let mut adv = BatchedAdvance::new();
        assert!(adv.advance_bucket(&mut pool, &mut [], &[]).is_empty());
        let mut a = PooledFenwickState::new(dk, dv);
        let mut b = PooledFenwickState::new(dk, dv);
        let k = vec![1.0f32; dk];
        let v = vec![1.0f32; dv];
        // one block: seq `a` takes it at t=0. In the bucket {a, b} that
        // follows, `a`'s merge at t=1 just relocates its sentinel (frees
        // nothing) and `b` writes fresh — both need a block from an
        // exhausted pool, so both are refused.
        a.advance(&mut pool, &k, &v, 1.0, Transition::Decay(0.9)).unwrap();
        assert_eq!(pool.available(), 0);
        let jobs: Vec<AdvanceJob<'_>> = (0..2)
            .map(|_| AdvanceJob {
                k: &k,
                v: &v,
                write_scale: 1.0,
                transition: Transition::Decay(0.9),
            })
            .collect();
        let before = (a.t, a.live_states(), b.t, b.live_states(), pool.in_use());
        let refused = {
            let mut refs: Vec<&mut PooledFenwickState> = vec![&mut a, &mut b];
            adv.advance_bucket(&mut pool, &mut refs, &jobs)
        };
        assert_eq!(refused, vec![0, 1], "exhausted pool refuses the whole bucket");
        assert_eq!(
            (a.t, a.live_states(), b.t, b.live_states(), pool.in_use()),
            before,
            "a fully refused bucket must not mutate anything"
        );
        a.release(&mut pool);
        assert_eq!(pool.in_use(), 0);
    }
}
