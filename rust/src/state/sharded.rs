//! Sharded façade over [`StatePool`] — the serving substrate split into
//! per-worker shards so decode buckets can advance and read concurrently.
//!
//! A single [`StatePool`] serializes every advance/read behind one
//! `&mut`: with the batched engines already saturating one core per
//! bucket, the pool itself is the scaling wall. [`ShardedStatePool`]
//! splits the block budget into `n` independent pools ("shards"), each
//! with its own free list, refcounts, and (when enabled) its own
//! [`PrefixCache`]. Sequences are **pinned to one shard at admission**
//! ([`ShardedStatePool::pin`]) and every block they ever hold lives in
//! that shard's pool, which is what makes per-shard jobs on the resident
//! thread pool sound: disjoint shards are disjoint `&mut`s
//! ([`ShardedStatePool::parts_mut`]), so shard jobs never synchronize on
//! state.
//!
//! ## Why sharding preserves bit-exactness
//!
//! Serving logits are bit-exact with the per-sequence oracle replay
//! because (a) every per-sequence computation — advance, batched read,
//! row-batched projection GEMMs — is independent of batchmates (the
//! established per-row invariant the trace harness pins), (b) a
//! sequence's states live wholly in one shard's pool, so its merge /
//! transition / sentinel op order never changes with the shard count,
//! and (c) the step loop never reorders one sequence's steps across
//! shards. [`BlockId`]s are **shard-local** (each shard numbers its
//! blocks from zero); a cached boundary snapshot is therefore only
//! adoptable by sequences pinned to the shard that owns it —
//! [`ShardedStatePool::lookup_prefix`] returns the owning shard for the
//! caller to pin against. Deterministic pinning (max headroom, lowest
//! index on ties) is for *reproducibility of occupancy traces*, not for
//! bits: any pinning yields the same per-sequence logits.
//!
//! Reservation accounting (admission backpressure) is per shard:
//! [`ShardedStatePool::reserve`] / [`ShardedStatePool::unreserve`] track
//! committed blocks against each shard's capacity, exactly the
//! `reserved_total` bookkeeping the unsharded backend kept globally.

use crate::state::pool::{Precision, StatePool};
use crate::state::prefix_cache::{BoundaryStates, PrefixCache};

/// A fixed set of independent [`StatePool`] shards with per-shard
/// reservation accounting and optional per-shard [`PrefixCache`]s (see
/// module docs). With one shard this is a thin pass-through — the
/// unsharded serving path, bit-for-bit.
pub struct ShardedStatePool {
    shards: Vec<StatePool>,
    /// one cache per shard when prefix caching is enabled (entries hold
    /// shard-local block ids, so caches can never be shared or merged)
    caches: Option<Vec<PrefixCache>>,
    /// admission-reserved blocks per shard
    reserved: Vec<usize>,
    block_elems: usize,
    shard_capacity: usize,
}

impl ShardedStatePool {
    /// `n_shards` pools of `shard_capacity` blocks of `block_elems`
    /// (d_k · d_v) floats each.
    pub fn new(block_elems: usize, shard_capacity: usize, n_shards: usize) -> ShardedStatePool {
        Self::with_precision(block_elems, shard_capacity, n_shards, Precision::F32)
    }

    /// Like [`ShardedStatePool::new`] but with an explicit storage
    /// precision, applied uniformly across every shard (mixed-precision
    /// shards would break the "any pinning yields the same logits"
    /// invariant in the module docs).
    pub fn with_precision(
        block_elems: usize,
        shard_capacity: usize,
        n_shards: usize,
        precision: Precision,
    ) -> ShardedStatePool {
        assert!(n_shards >= 1, "at least one shard");
        assert!(shard_capacity >= 1, "each shard needs capacity");
        ShardedStatePool {
            shards: (0..n_shards)
                .map(|_| StatePool::with_precision(block_elems, shard_capacity, precision))
                .collect(),
            caches: None,
            reserved: vec![0; n_shards],
            block_elems,
            shard_capacity,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Storage precision, uniform across shards.
    pub fn precision(&self) -> Precision {
        self.shards[0].precision()
    }

    /// Per-shard block capacity (uniform across shards). A request whose
    /// reservation exceeds this can never be admitted, no matter how
    /// empty the pools are — the sharded analogue of `TooLarge`.
    pub fn shard_capacity(&self) -> usize {
        self.shard_capacity
    }

    /// Elements per block (d_k · d_v), uniform across shards.
    pub fn block_elems(&self) -> usize {
        self.block_elems
    }

    /// One shard's pool.
    pub fn shard(&self, s: usize) -> &StatePool {
        &self.shards[s]
    }

    pub fn shard_mut(&mut self, s: usize) -> &mut StatePool {
        &mut self.shards[s]
    }

    /// Total capacity across shards — keeps `pool().capacity()`-style
    /// inspection working unchanged on the façade.
    pub fn capacity(&self) -> usize {
        self.shards.iter().map(|p| p.capacity()).sum()
    }

    /// Blocks in use across every shard.
    pub fn in_use(&self) -> usize {
        self.shards.iter().map(|p| p.in_use()).sum()
    }

    /// Sum of per-shard peaks (an upper bound on the true simultaneous
    /// peak, exact when shards peak together — occupancy accounting, not
    /// a timing claim).
    pub fn peak(&self) -> usize {
        self.shards.iter().map(|p| p.peak()).sum()
    }

    /// Blocks still allocatable across every shard.
    pub fn available(&self) -> usize {
        self.shards.iter().map(|p| p.available()).sum()
    }

    /// Can shard `s` take another `need`-block reservation?
    pub fn can_reserve(&self, s: usize, need: usize) -> bool {
        self.reserved[s] + need <= self.shard_capacity
    }

    /// Commit `need` blocks of shard `s`'s capacity to a sequence.
    pub fn reserve(&mut self, s: usize, need: usize) {
        debug_assert!(self.can_reserve(s, need), "over-reservation on shard {s}");
        self.reserved[s] += need;
    }

    /// Return a retired sequence's reservation to shard `s`.
    pub fn unreserve(&mut self, s: usize, need: usize) {
        debug_assert!(self.reserved[s] >= need, "unreserve underflow on shard {s}");
        self.reserved[s] -= need;
    }

    /// Blocks currently reserved against shard `s`.
    pub fn reserved(&self, s: usize) -> usize {
        self.reserved[s]
    }

    /// Pick the shard for a new `need`-block sequence: among shards with
    /// room (`reserved + need ≤ capacity`), the one with the most
    /// reservation headroom, lowest index on ties — deterministic, so
    /// identical traffic reproduces identical shard occupancy traces.
    /// `None` means every shard is committed (admission backpressure).
    pub fn pin(&self, need: usize) -> Option<usize> {
        self.reserved
            .iter()
            .enumerate()
            .filter(|&(_, &r)| r + need <= self.shard_capacity)
            .max_by_key(|&(s, &r)| (self.shard_capacity - r, std::cmp::Reverse(s)))
            .map(|(s, _)| s)
    }

    /// Turn on prefix caching: one [`PrefixCache`] per shard at `chunk`
    /// granularity. Idempotent.
    pub fn enable_prefix_cache(&mut self, chunk: usize) {
        if self.caches.is_none() {
            self.caches = Some(self.shards.iter().map(|_| PrefixCache::new(chunk)).collect());
        }
    }

    pub fn cache_enabled(&self) -> bool {
        self.caches.is_some()
    }

    /// One shard's cache, if caching is enabled.
    pub fn cache(&self, s: usize) -> Option<&PrefixCache> {
        self.caches.as_ref().map(|c| &c[s])
    }

    /// Total blocks held by every shard's cache.
    pub fn cache_blocks_held(&self) -> usize {
        self.caches.as_ref().map_or(0, |cs| cs.iter().map(|c| c.blocks_held()).sum())
    }

    /// Probe every shard's cache for the longest cached chunk-aligned
    /// prefix of `tokens`. Returns `(shard, matched_tokens, states)` for
    /// the deepest hit — longest match wins, lowest shard on ties (the
    /// winner is LRU-touched; losing shards' probes touch nothing, since
    /// only a *returned* lookup marks an entry used). The block handles
    /// are shard-local: the caller may only adopt them into a sequence
    /// pinned to that shard.
    pub fn lookup_prefix(&mut self, tokens: &[i32]) -> Option<(usize, usize, BoundaryStates)> {
        let caches = self.caches.as_mut()?;
        // two passes so losing shards are never LRU-touched: peek depths
        // first, then look up (and touch) only the winner
        let mut best: Option<(usize, usize)> = None; // (matched, shard)
        for (s, cache) in caches.iter().enumerate() {
            if let Some(m) = cache.peek_match(tokens) {
                if best.map_or(true, |(bm, _)| m > bm) {
                    best = Some((m, s));
                }
            }
        }
        let (_, s) = best?;
        let (m, states) = caches[s].lookup(tokens).expect("peeked above");
        Some((s, m, states))
    }

    /// Disjoint `(pool, cache)` mutable pair for shard `s` — what export
    /// bridges and eviction loops need simultaneously.
    pub fn pair_mut(&mut self, s: usize) -> (&mut StatePool, Option<&mut PrefixCache>) {
        (&mut self.shards[s], self.caches.as_mut().map(|c| &mut c[s]))
    }

    /// Every shard's disjoint `(pool, cache)` mutable pair at once — the
    /// borrow split that lets one thread-pool job per shard run
    /// concurrently without any synchronization on state.
    pub fn parts_mut(&mut self) -> Vec<(&mut StatePool, Option<&mut PrefixCache>)> {
        match self.caches.as_mut() {
            Some(caches) => self
                .shards
                .iter_mut()
                .zip(caches.iter_mut())
                .map(|(p, c)| (p, Some(c)))
                .collect(),
            None => self.shards.iter_mut().map(|p| (p, None)).collect(),
        }
    }

    /// Drop every shard's cache entries, releasing their refcounts
    /// (gate-swap invalidation, end-of-trace leak accounting). Caches
    /// stay enabled.
    pub fn clear_caches(&mut self) {
        if let Some(caches) = self.caches.as_mut() {
            for (cache, pool) in caches.iter_mut().zip(self.shards.iter_mut()) {
                cache.clear(pool);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_sum_across_shards() {
        let mut sp = ShardedStatePool::new(4, 3, 2);
        assert_eq!(sp.capacity(), 6);
        assert_eq!(sp.block_elems(), 4);
        let a = sp.shard_mut(0).alloc().unwrap();
        let b = sp.shard_mut(1).alloc().unwrap();
        let _c = sp.shard_mut(1).alloc().unwrap();
        assert_eq!(sp.in_use(), 3);
        assert_eq!(sp.available(), 3);
        sp.shard_mut(0).release(a);
        sp.shard_mut(1).release(b);
        assert_eq!(sp.in_use(), 1);
        assert_eq!(sp.peak(), 3, "per-shard peaks: 1 + 2");
    }

    #[test]
    fn precision_is_uniform_across_shards() {
        let sp = ShardedStatePool::new(4, 3, 2);
        assert_eq!(sp.precision(), Precision::F32);
        let mut sp = ShardedStatePool::with_precision(4, 3, 3, Precision::Bf16);
        assert_eq!(sp.precision(), Precision::Bf16);
        for s in 0..sp.n_shards() {
            assert_eq!(sp.shard(s).precision(), Precision::Bf16);
            assert_eq!(sp.shard(s).bytes_per_block(), 4 * 2);
        }
        // shard pools really store bf16: a widened read round-trips
        let id = sp.shard_mut(1).alloc().unwrap();
        sp.shard_mut(1).write_block_from(id, &[1.0, -2.5, 0.0, 0.5]);
        let mut out = [0.0f32; 4];
        sp.shard_mut(1).read_block_into(id, &mut out);
        assert_eq!(out, [1.0, -2.5, 0.0, 0.5]);
        sp.shard_mut(1).release(id);
    }

    #[test]
    fn pin_prefers_headroom_then_lowest_index() {
        let mut sp = ShardedStatePool::new(4, 10, 3);
        // empty: tie on headroom -> lowest index
        assert_eq!(sp.pin(4), Some(0));
        sp.reserve(0, 6);
        // shard 0 has 4 headroom, 1 and 2 have 10: tie between 1, 2 -> 1
        assert_eq!(sp.pin(4), Some(1));
        sp.reserve(1, 3);
        // headroom: 4, 7, 10 -> shard 2
        assert_eq!(sp.pin(4), Some(2));
        sp.reserve(2, 9);
        // headroom: 4, 7, 1 -> shard 1; a 5-block need skips shard 2
        assert_eq!(sp.pin(5), Some(1));
        // an 8-block need fits nowhere
        assert_eq!(sp.pin(8), None);
        sp.unreserve(0, 6);
        assert_eq!(sp.pin(8), Some(0));
        // per-shard capacity bounds a single reservation even on empty
        // shards
        assert_eq!(sp.pin(11), None);
    }

    #[test]
    fn reservation_accounting_is_per_shard() {
        let mut sp = ShardedStatePool::new(4, 5, 2);
        assert!(sp.can_reserve(0, 5));
        sp.reserve(0, 5);
        assert!(!sp.can_reserve(0, 1));
        assert!(sp.can_reserve(1, 5), "shard 1 unaffected by shard 0's commitments");
        assert_eq!(sp.reserved(0), 5);
        assert_eq!(sp.reserved(1), 0);
        sp.unreserve(0, 2);
        assert!(sp.can_reserve(0, 2));
        assert!(!sp.can_reserve(0, 3));
    }

    #[test]
    fn lookup_prefix_longest_match_wins_across_shards() {
        let mut sp = ShardedStatePool::new(4, 8, 2);
        sp.enable_prefix_cache(2);
        let p: Vec<i32> = (0..8).collect();
        // shard 0 caches the 2-token boundary, shard 1 the 4-token one
        let (s0_states, s1_states);
        {
            let (pool, cache) = sp.pair_mut(0);
            let id = pool.alloc().unwrap();
            s0_states = vec![vec![(1usize, id)]];
            cache.unwrap().insert(&p[..2], &s0_states, pool);
        }
        {
            let (pool, cache) = sp.pair_mut(1);
            let id = pool.alloc().unwrap();
            s1_states = vec![vec![(2usize, id)]];
            cache.unwrap().insert(&p[..4], &s1_states, pool);
        }
        let (shard, matched, states) = sp.lookup_prefix(&p).unwrap();
        assert_eq!((shard, matched), (1, 4), "longest match wins");
        assert_eq!(states, s1_states);
        // only the 2-token prefix in common -> shard 0's entry
        let (shard, matched, _) = sp.lookup_prefix(&[0, 1, 99, 99]).unwrap();
        assert_eq!((shard, matched), (0, 2));
        assert!(sp.lookup_prefix(&[7, 7]).is_none());
        assert_eq!(sp.cache_blocks_held(), 2);
        // drain: clear caches, then the exporters' own refs
        sp.clear_caches();
        {
            let (pool, _) = sp.pair_mut(0);
            pool.release(s0_states[0][0].1);
        }
        {
            let (pool, _) = sp.pair_mut(1);
            pool.release(s1_states[0][0].1);
        }
        assert_eq!(sp.in_use(), 0);
        assert_eq!(sp.cache_blocks_held(), 0);
    }

    #[test]
    fn losing_shards_are_not_lru_touched_by_a_probe() {
        // shard 0 holds two entries; a deeper hit on shard 1 must not
        // touch shard 0's shallower entry, so shard 0's own LRU order is
        // unchanged by cross-shard probes.
        let mut sp = ShardedStatePool::new(4, 8, 2);
        sp.enable_prefix_cache(2);
        let p: Vec<i32> = (0..8).collect();
        let (a, b);
        {
            let (pool, cache) = sp.pair_mut(0);
            let cache = cache.unwrap();
            a = pool.alloc().unwrap();
            cache.insert(&p[..2], &vec![vec![(1usize, a)]], pool);
            b = pool.alloc().unwrap();
            cache.insert(&[9, 9], &vec![vec![(1usize, b)]], pool);
        }
        {
            let (pool, cache) = sp.pair_mut(1);
            let id = pool.alloc().unwrap();
            cache.unwrap().insert(&p[..4], &vec![vec![(2usize, id)]], pool);
        }
        // deep probe: shard 1 wins; shard 0's [0,1] entry must NOT be
        // touched, so it is still LRU (older than [9,9])
        let (shard, _, _) = sp.lookup_prefix(&p).unwrap();
        assert_eq!(shard, 1);
        {
            let (pool, cache) = sp.pair_mut(0);
            assert!(cache.unwrap().evict_lru(pool));
        }
        // the evicted entry is the untouched [0,1] one
        assert!(sp.lookup_prefix(&[0, 1]).is_none(), "untouched entry was LRU");
        assert!(sp.lookup_prefix(&[9, 9]).is_some());
        sp.clear_caches();
        {
            let (pool, _) = sp.pair_mut(0);
            pool.release(a);
            pool.release(b);
        }
    }
}
