//! Pool-backed Fenwick decode states + the batched cross-sequence read
//! (paper §3.2 / App. B.4, lifted to serving).
//!
//! [`PooledFenwickState`] is [`super::FenwickState`] with its
//! `popcount(t)+1` live level states held as [`StatePool`] blocks instead
//! of owned `Mat`s: a server's resident decode memory becomes *pool
//! blocks in use* — the sum of live states across sequences — and pool
//! exhaustion is an explicit backpressure signal for admission control
//! instead of an OOM.
//!
//! [`BatchedDecoder`] is the decode-time analogue of
//! [`crate::attention::loglinear::ChunkFenwick::read_levels_into`]: where
//! the chunkwise trainer concatenates O(log T) level states *within* one
//! sequence into a single `Q_c @ S_cat` GEMM, the decoder concatenates
//! all live states *across the sequences of a decode batch*. Per step it
//! builds one λ-weighted query row per live (sequence, level) block and
//! folds the whole batch's output in a single block-sparse GEMM pass
//! `O = A' S_all` — `A'` is `(B, Σ live·d_k)` with the weighted queries
//! scattered on each row, `S_all` the `(Σ live·d_k, d_v)` stack of live
//! blocks read *in place* from the pool's contiguous slab (no gather
//! copy). Work is dispatched over the resident worker pool
//! ([`crate::util::threadpool::resident_pool`]) with one contiguous
//! output row-block per worker.
//!
//! Both the per-sequence and the batched read reduce to the shared
//! [`crate::attention::loglinear::level_read_acc`] op sequence per
//! (sequence, level), in the same order, so the batched path is
//! **bit-exact** with the [`super::FenwickState`] oracle — asserted by
//! the tests below and re-checked by the `decode_batched` bench.

use crate::attention::loglinear::level_read_acc;
use crate::state::pool::{BlockId, Precision, StatePool};
use crate::state::{level_weight, Transition};
use crate::tensor;
use crate::util::threadpool::par_row_chunks_pooled;

/// The pool had no free block for a state write — a backpressure signal
/// (defer admission / shed load), not a corruption: the failed step left
/// the sequence untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted;

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "state pool exhausted")
    }
}

impl std::error::Error for PoolExhausted {}

/// Upper bound on simultaneously-live blocks for a sequence that runs at
/// most `steps` decode steps: `max_{t < steps} popcount(t) + 1`, i.e. the
/// bit-length of `steps`. Admission control reserves exactly this many
/// blocks per sequence, which makes pool exhaustion impossible for
/// admitted sequences.
pub fn blocks_for_steps(steps: usize) -> usize {
    assert!(steps >= 1, "a sequence runs at least one step");
    (usize::BITS - steps.leading_zeros()) as usize
}

/// O(log T) Fenwick decode state for one sequence (one head), with level
/// states resident in a shared [`StatePool`].
#[derive(Debug, Clone)]
pub struct PooledFenwickState {
    pub dk: usize,
    pub dv: usize,
    /// levels[l] = pool block of the bucket state at level l (0 = sentinel)
    levels: Vec<Option<BlockId>>,
    /// number of tokens processed so far
    pub t: usize,
}

impl PooledFenwickState {
    pub fn new(dk: usize, dv: usize) -> PooledFenwickState {
        PooledFenwickState { dk, dv, levels: Vec::new(), t: 0 }
    }

    /// Number of live (non-empty) level states (= `popcount(t) + 1`).
    pub fn live_states(&self) -> usize {
        self.levels.iter().flatten().count()
    }

    /// The raw level slots (batched-advance plumbing).
    pub(crate) fn levels(&self) -> &[Option<BlockId>] {
        &self.levels
    }

    /// Mutable level slots (batched-advance plumbing). Invariants —
    /// level l live only when bit l−1 of `t` is set, plus the sentinel —
    /// are the caller's to preserve.
    pub(crate) fn levels_mut(&mut self) -> &mut Vec<Option<BlockId>> {
        &mut self.levels
    }

    /// Record one more processed token (batched-advance plumbing: the
    /// pool-wide pass mutates levels directly, then bumps `t` exactly like
    /// [`PooledFenwickState::advance`] does).
    pub(crate) fn bump_t(&mut self) {
        self.t += 1;
    }

    /// Level capacity currently tracked (≈ log2 t).
    pub fn level_capacity(&self) -> usize {
        self.levels.len()
    }

    /// Process one token's state update — merge, transition, write — the
    /// mutation half of [`super::FenwickState::step`]. Both run the *same*
    /// storage-generic skeleton ([`crate::state::update::advance_levels`]),
    /// so the op order is bit-identical by construction; only the storage
    /// backing differs ([`crate::state::update::PoolStore`] here). The
    /// read half lives in [`PooledFenwickState::read_into`] /
    /// [`BatchedDecoder::read_batch`] so a whole batch can read at once.
    ///
    /// Fails (before mutating anything) if the pool cannot supply the one
    /// fresh block the sentinel write needs after the merge's releases.
    // xtask: deny_alloc
    pub fn advance(
        &mut self,
        pool: &mut StatePool,
        k: &[f32],
        v: &[f32],
        write_scale: f32,
        transition: Transition<'_>,
    ) -> Result<(), PoolExhausted> {
        let mut store = crate::state::update::PoolStore { pool, dv: self.dv };
        crate::state::update::advance_levels(
            &mut store,
            &mut self.levels,
            self.t,
            k,
            v,
            write_scale,
            transition,
        )?;
        self.t += 1;
        Ok(())
    }

    /// Install an externally-built level layout — the prefill export
    /// bridge's entry point. `states[i] = (token_level, data)` with `data`
    /// a row-major `(dk, dv)` state; the sequence is positioned at `t`
    /// tokens processed, at the **post-merge boundary** of step `t`: level
    /// 0 (the sentinel) is empty and each provided `token_level ≥ 1` must
    /// be live in the Fenwick partition implied by `t` (bit `level-1` of
    /// `t` set). The next [`PooledFenwickState::advance`] then performs a
    /// no-op merge and proceeds exactly like the token recurrence at step
    /// `t` (see `prefill::bridge` for why chunk-aligned positions land on
    /// this boundary).
    ///
    /// Fails without mutating the pool if it cannot hold all the states.
    pub fn import_levels(
        pool: &mut StatePool,
        dk: usize,
        dv: usize,
        t: usize,
        states: &[(usize, &[f32])],
    ) -> Result<PooledFenwickState, PoolExhausted> {
        if pool.available() < states.len() {
            return Err(PoolExhausted);
        }
        let mut seq = PooledFenwickState::new(dk, dv);
        for &(level, data) in states {
            assert!(level >= 1, "level 0 is the sentinel; it is written by advance");
            assert!(
                level <= usize::BITS as usize && (t >> (level - 1)) & 1 == 1,
                "level {level} is not live at position {t} (Fenwick misalignment)"
            );
            assert_eq!(data.len(), dk * dv, "state shape");
            if seq.levels.len() <= level {
                seq.levels.resize(level + 1, None);
            }
            assert!(seq.levels[level].is_none(), "duplicate level {level} in import");
            let id = pool.alloc().expect("availability checked above");
            // precision-transparent: copies at f32, narrows (RNE) on a
            // bf16 pool — the one rounding the import path introduces
            pool.write_block_from(id, data);
            seq.levels[level] = Some(id);
        }
        seq.t = t;
        Ok(seq)
    }

    /// Live `(level, block)` handles in ascending level order —
    /// prefix-cache plumbing (insertion retains these very blocks).
    pub(crate) fn level_blocks(&self) -> Vec<(usize, BlockId)> {
        self.levels
            .iter()
            .enumerate()
            .filter_map(|(l, s)| s.map(|id| (l, id)))
            .collect()
    }

    /// Build a sequence at position `t` directly from **shared** block
    /// handles — the prefix-cache hit path. Where
    /// [`PooledFenwickState::import_levels`] copies external bytes into
    /// fresh blocks, this retains the given blocks in place (zero copies,
    /// zero new allocations — it cannot exhaust the pool). The adopted
    /// blocks are shared with their other owners (the cache, possibly
    /// other sequences), so the first advance's copy-on-write step clones
    /// before mutating; see [`crate::state::pool`]'s module docs.
    ///
    /// Same boundary contract as `import_levels`: `t` is a post-merge
    /// chunk boundary, level 0 empty, each `level ≥ 1` live in the
    /// Fenwick partition implied by `t`.
    pub(crate) fn adopt_levels(
        pool: &mut StatePool,
        dk: usize,
        dv: usize,
        t: usize,
        states: &[(usize, BlockId)],
    ) -> PooledFenwickState {
        let mut seq = PooledFenwickState::new(dk, dv);
        for &(level, id) in states {
            assert!(level >= 1, "level 0 is the sentinel; it is written by advance");
            assert!(
                level <= usize::BITS as usize && (t >> (level - 1)) & 1 == 1,
                "level {level} is not live at position {t} (Fenwick misalignment)"
            );
            if seq.levels.len() <= level {
                seq.levels.resize(level + 1, None);
            }
            assert!(seq.levels[level].is_none(), "duplicate level {level} in adopt");
            // xtask: allow(refcount): ownership transfers to the sequence's
            // level slots; PooledFenwickState::release drops it at retirement
            pool.retain(id);
            seq.levels[level] = Some(id);
        }
        seq.t = t;
        seq
    }

    /// Per-sequence λ-weighted read `o = Σ_l λ^(l) S^(l)T q` (overwrites
    /// `out`) — the matvec-loop baseline that [`BatchedDecoder`] batches.
    // xtask: deny_alloc
    pub fn read_into(&self, pool: &StatePool, q: &[f32], lambda: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dv);
        out.fill(0.0);
        for (l, slot) in self.levels.iter().enumerate() {
            if let Some(id) = slot {
                let lam = level_weight(lambda, l);
                if lam == 0.0 {
                    continue;
                }
                match pool.precision() {
                    Precision::F32 => level_read_acc(pool.get(*id), self.dv, q, lam, out),
                    // widen-on-the-fly read, f32 accumulation — the same
                    // row loop/op order as the f32 path (docs/PRECISION.md)
                    Precision::Bf16 => {
                        tensor::matvec_t_acc_slice_bf16(pool.get_bf16(*id), self.dv, q, lam, out)
                    }
                }
            }
        }
    }

    /// Convenience advance + read (mirrors [`super::FenwickState::step`]).
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        pool: &mut StatePool,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        write_scale: f32,
        transition: Transition<'_>,
        lambda: &[f32],
    ) -> Result<Vec<f32>, PoolExhausted> {
        self.advance(pool, k, v, write_scale, transition)?;
        let mut o = vec![0.0f32; self.dv];
        self.read_into(pool, q, lambda, &mut o);
        Ok(o)
    }

    /// Retire the sequence: release every live block back to the pool.
    pub fn release(&mut self, pool: &mut StatePool) {
        for slot in self.levels.iter_mut() {
            if let Some(id) = slot.take() {
                pool.release(id);
            }
        }
        self.t = 0;
    }
}

/// Below this many flops a batched read stays single-threaded; much lower
/// than the GEMM spawn threshold because the resident pool makes the
/// per-dispatch cost a queue handoff, which is what lets decode-sized
/// reads thread at all.
const BATCH_READ_FLOP_THRESHOLD: usize = 1 << 16;

/// Batched decode-time read engine: one λ-weighted block-sparse GEMM per
/// step for a whole batch of sequences at mixed positions (see module
/// docs). Owns its plan workspaces so steady-state steps allocate
/// nothing.
#[derive(Debug, Clone, Default)]
pub struct BatchedDecoder {
    /// λ-weighted query rows, one per live (sequence, level) block:
    /// row j = λ_{seq(j)}^{(level(j))} · q_{seq(j)}, shape (Σ live, d_k)
    wq: Vec<f32>,
    /// pool block per weighted-query row, CSR order
    blocks: Vec<BlockId>,
    /// CSR offsets: sequence i owns blocks[row_ptr[i]..row_ptr[i+1]]
    row_ptr: Vec<usize>,
}

impl BatchedDecoder {
    pub fn new() -> BatchedDecoder {
        BatchedDecoder::default()
    }

    /// Live blocks planned in the last [`BatchedDecoder::read_batch`]
    /// (the Σ live of the single fused read).
    pub fn last_planned_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// The batched read: `out[i] = Σ_l λ_i^(l) S_i^(l)T q_i` for every
    /// sequence in the batch, as one fused pass over the pool slab.
    ///
    /// `qs` is `(n, d_k)` row-major, `lambdas` one λ table per sequence,
    /// `out` `(n, d_v)` row-major (overwritten). Per sequence the op
    /// order equals [`PooledFenwickState::read_into`], so results are
    /// bit-exact with the per-sequence path for any thread count (each
    /// output row is owned by exactly one worker).
    // xtask: deny_alloc
    pub fn read_batch(
        &mut self,
        pool: &StatePool,
        seqs: &[&PooledFenwickState],
        qs: &[f32],
        lambdas: &[&[f32]],
        out: &mut [f32],
    ) {
        let n = seqs.len();
        if n == 0 {
            return;
        }
        let _span = crate::obs::span(crate::obs::SpanCat::Read, n as u64);
        let (dk, dv) = (seqs[0].dk, seqs[0].dv);
        assert_eq!(qs.len(), n * dk, "qs shape");
        assert_eq!(lambdas.len(), n, "lambdas shape");
        assert_eq!(out.len(), n * dv, "out shape");
        // 1) plan: a λ-weighted query row per live (sequence, level)
        //    block, grouped by sequence in ascending level order (the
        //    per-sequence read order).
        self.wq.clear();
        self.blocks.clear();
        self.row_ptr.clear();
        self.row_ptr.push(0);
        for (i, seq) in seqs.iter().enumerate() {
            assert_eq!((seq.dk, seq.dv), (dk, dv), "mixed state shapes in batch");
            let q = &qs[i * dk..(i + 1) * dk];
            for (l, slot) in seq.levels.iter().enumerate() {
                if let Some(id) = slot {
                    let lam = level_weight(lambdas[i], l);
                    if lam == 0.0 {
                        continue;
                    }
                    self.blocks.push(*id);
                    for &qk in q {
                        self.wq.push(lam * qk);
                    }
                }
            }
            self.row_ptr.push(self.blocks.len());
        }
        out.fill(0.0);
        if self.blocks.is_empty() {
            return;
        }
        // 2) execute: the block-sparse GEMM over the resident pool —
        //    contiguous output row-blocks per worker, blocks streamed
        //    straight from the pool slab (zero-copy).
        let flops = 2 * self.blocks.len() * dk * dv;
        // custom block-sparse path: attribute flops here, since it never
        // crosses the hooked dense/batched GEMM entry points
        crate::obs::account_flops(
            flops as u64,
            4 * (self.blocks.len() * dk * (dv + 1) + n * dv) as u64,
        );
        let threads = if flops < BATCH_READ_FLOP_THRESHOLD {
            1
        } else {
            tensor::current_gemm_threads().clamp(1, n)
        };
        let (wq, blocks, row_ptr) = (&self.wq, &self.blocks, &self.row_ptr);
        let bf16 = pool.precision() == Precision::Bf16;
        par_row_chunks_pooled(out, dv, n.div_ceil(threads), |r0, r1, chunk| {
            for i in r0..r1 {
                let orow = &mut chunk[(i - r0) * dv..(i - r0 + 1) * dv];
                for j in row_ptr[i]..row_ptr[i + 1] {
                    // the λ weight is pre-folded into the wq row, so
                    // scale = 1.0 reproduces the per-sequence op sequence
                    // exactly (1.0 * (λ·q_k) is bitwise λ·q_k)
                    let a = &wq[j * dk..(j + 1) * dk];
                    if bf16 {
                        // widen + f32-accumulate — same loop structure, so
                        // batched stays bit-exact with the per-sequence
                        // bf16 read (both read the same stored bits)
                        tensor::matvec_t_acc_slice_bf16(pool.get_bf16(blocks[j]), dv, a, 1.0, orow);
                    } else {
                        tensor::matvec_t_acc_slice(pool.get(blocks[j]), dv, a, 1.0, orow);
                    }
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnInputs;
    use crate::state::FenwickState;
    use crate::util::prop::{check, UsizeIn};
    use crate::util::Rng;

    #[test]
    fn blocks_for_steps_bounds_live_states_tightly() {
        for steps in 1usize..300 {
            let max_live = (0..steps).map(|t| t.count_ones() as usize + 1).max().unwrap();
            assert_eq!(blocks_for_steps(steps), max_live, "steps={steps}");
        }
    }

    #[test]
    fn pooled_state_is_bit_exact_with_fenwick_state() {
        let mut rng = Rng::new(21);
        let (dk, dv, t_len) = (8, 8, 200);
        let x = AttnInputs::random(t_len, dk, dv, &mut rng);
        let mut pool = StatePool::new(dk * dv, 16);
        let mut ps = PooledFenwickState::new(dk, dv);
        let mut fs = FenwickState::new(dk, dv);
        // truncated λ table also exercises clamp parity past the width
        let width = 5;
        for t in 0..t_len {
            let lam = &x.lambda.row(t)[..width];
            let (ws, tr_f, tr_p) = if t % 2 == 0 {
                (1.0, Transition::Decay(x.alpha[t]), Transition::Decay(x.alpha[t]))
            } else {
                (
                    x.beta[t],
                    Transition::GatedHouseholder { alpha: x.alpha[t], beta: x.beta[t], k: x.k.row(t) },
                    Transition::GatedHouseholder { alpha: x.alpha[t], beta: x.beta[t], k: x.k.row(t) },
                )
            };
            let o1 = fs.step(x.q.row(t), x.k.row(t), x.v.row(t), ws, tr_f, lam);
            let o2 = ps
                .step(&mut pool, x.q.row(t), x.k.row(t), x.v.row(t), ws, tr_p, lam)
                .unwrap();
            assert_eq!(o1, o2, "bit-exact divergence at t={t}");
            assert_eq!(ps.live_states(), fs.live_states(), "t={t}");
            assert_eq!(pool.in_use(), ps.live_states(), "t={t}");
        }
    }

    #[test]
    fn batched_read_matches_per_sequence_reads_bit_exact() {
        let (dk, dv) = (16, 12);
        let mut rng = Rng::new(22);
        let mut pool = StatePool::new(dk * dv, 64);
        let steps = [1usize, 3, 7, 12, 33, 64];
        let n = steps.len();
        let mut seqs = Vec::new();
        for (i, &st) in steps.iter().enumerate() {
            let mut seq = PooledFenwickState::new(dk, dv);
            let mut srng = Rng::new(100 + i as u64);
            for _ in 0..st {
                let k: Vec<f32> = (0..dk).map(|_| srng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> = (0..dv).map(|_| srng.normal_f32(0.0, 1.0)).collect();
                seq.advance(&mut pool, &k, &v, 1.0, Transition::Decay(0.97)).unwrap();
            }
            seqs.push(seq);
        }
        let qs: Vec<f32> = (0..n * dk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let table: Vec<f32> = (0..10).map(|_| rng.range_f32(0.05, 1.0)).collect();
        // mixed widths exercise per-sequence λ clamping inside the batch
        let lambdas: Vec<&[f32]> = (0..n).map(|i| &table[..3 + i]).collect();

        let mut want = vec![0.0f32; n * dv];
        for i in 0..n {
            seqs[i].read_into(&pool, &qs[i * dk..(i + 1) * dk], lambdas[i], &mut want[i * dv..(i + 1) * dv]);
        }
        let refs: Vec<&PooledFenwickState> = seqs.iter().collect();
        let mut dec = BatchedDecoder::new();
        let mut got = vec![1.0f32; n * dv]; // dirty buffer: read_batch overwrites
        dec.read_batch(&pool, &refs, &qs, &lambdas, &mut got);
        assert_eq!(got, want, "batched read diverged from per-sequence reads");
        assert_eq!(
            dec.last_planned_blocks(),
            seqs.iter().map(|s| s.live_states()).sum::<usize>()
        );
    }

    #[test]
    fn bf16_pooled_path_is_self_consistent_and_tolerance_bounded() {
        // Two properties of the reduced-precision slab: (1) the batched
        // read over a bf16 pool is bit-exact with the per-sequence bf16
        // read (both widen the same stored bits through the same op
        // order); (2) the bf16 trajectory tracks the f32 trajectory
        // within the docs/PRECISION.md relative-error bound.
        let (dk, dv) = (8, 8);
        let mut rng = Rng::new(0xBF16);
        let mut pool_f32 = StatePool::new(dk * dv, 32);
        let mut pool_bf16 = StatePool::with_precision(dk * dv, 32, Precision::Bf16);
        let steps = [1usize, 5, 12, 33];
        let n = steps.len();
        let (mut seqs_f32, mut seqs_bf16) = (Vec::new(), Vec::new());
        for (i, &st) in steps.iter().enumerate() {
            let mut a = PooledFenwickState::new(dk, dv);
            let mut b = PooledFenwickState::new(dk, dv);
            let mut srng = Rng::new(400 + i as u64);
            for t in 0..st {
                let k: Vec<f32> = (0..dk).map(|_| srng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> = (0..dv).map(|_| srng.normal_f32(0.0, 1.0)).collect();
                let (ws, tr) = if t % 3 == 0 {
                    (srng.range_f32(0.2, 1.0), Transition::Decay(0.95))
                } else {
                    (1.0, Transition::Decay(0.9))
                };
                a.advance(&mut pool_f32, &k, &v, ws, tr).unwrap();
                b.advance(&mut pool_bf16, &k, &v, ws, tr).unwrap();
            }
            seqs_f32.push(a);
            seqs_bf16.push(b);
        }
        let qs: Vec<f32> = (0..n * dk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let table: Vec<f32> = (0..8).map(|_| rng.range_f32(0.1, 1.0)).collect();
        let lambdas: Vec<&[f32]> = (0..n).map(|_| &table[..6]).collect();

        let mut per_seq = vec![0.0f32; n * dv];
        for i in 0..n {
            seqs_bf16[i].read_into(
                &pool_bf16,
                &qs[i * dk..(i + 1) * dk],
                lambdas[i],
                &mut per_seq[i * dv..(i + 1) * dv],
            );
        }
        let refs: Vec<&PooledFenwickState> = seqs_bf16.iter().collect();
        let mut dec = BatchedDecoder::new();
        let mut batched = vec![1.0f32; n * dv];
        dec.read_batch(&pool_bf16, &refs, &qs, &lambdas, &mut batched);
        for (g, w) in batched.iter().zip(per_seq.iter()) {
            assert_eq!(g.to_bits(), w.to_bits(), "bf16 batched read diverged from per-sequence");
        }

        let mut oracle = vec![0.0f32; n * dv];
        for i in 0..n {
            seqs_f32[i].read_into(
                &pool_f32,
                &qs[i * dk..(i + 1) * dk],
                lambdas[i],
                &mut oracle[i * dv..(i + 1) * dv],
            );
        }
        for (i, (g, w)) in per_seq.iter().zip(oracle.iter()).enumerate() {
            let rel = (g - w).abs() / (1.0 + w.abs());
            assert!(rel <= 0.05, "bf16 read outside tolerance at {i}: got {g}, oracle {w}");
        }

        for s in seqs_f32.iter_mut() {
            s.release(&mut pool_f32);
        }
        for s in seqs_bf16.iter_mut() {
            s.release(&mut pool_bf16);
        }
        assert_eq!((pool_f32.in_use(), pool_bf16.in_use()), (0, 0));
    }

    #[test]
    fn pool_never_leaks_under_random_retirement() {
        check("pooled no-leak", 25, &UsizeIn(1, 1000), |&seed| {
            let (dk, dv) = (4, 4);
            let mut rng = Rng::new(seed as u64);
            let mut pool = StatePool::new(dk * dv, 64);
            let mut live: Vec<PooledFenwickState> = Vec::new();
            let lam = [1.0f32, 0.5, 0.25];
            for _ in 0..200 {
                let r = rng.f64();
                if r < 0.25 && live.len() < 8 {
                    live.push(PooledFenwickState::new(dk, dv));
                } else if r < 0.45 && !live.is_empty() {
                    let i = rng.below(live.len());
                    let mut seq = live.swap_remove(i);
                    seq.release(&mut pool);
                } else if !live.is_empty() {
                    let i = rng.below(live.len());
                    let k: Vec<f32> = (0..dk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let v: Vec<f32> = (0..dv).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    // exhaustion is allowed mid-trace; it must not corrupt
                    let _ = live[i].step(&mut pool, &k, &k, &v, 1.0, Transition::Decay(0.9), &lam);
                }
                let total: usize = live.iter().map(|s| s.live_states()).sum();
                if pool.in_use() != total {
                    return false;
                }
            }
            for mut seq in live.drain(..) {
                seq.release(&mut pool);
            }
            pool.in_use() == 0
        });
    }

    #[test]
    fn advance_signals_exhaustion_cleanly_and_recovers_after_grow() {
        let (dk, dv) = (4, 4);
        let mut pool = StatePool::new(dk * dv, 2);
        let mut seq = PooledFenwickState::new(dk, dv);
        let k = vec![1.0f32; dk];
        let v = vec![1.0f32; dv];
        for _ in 0..3 {
            seq.advance(&mut pool, &k, &v, 1.0, Transition::Decay(0.9)).unwrap();
        }
        // t=3 needs a third simultaneous block: clean backpressure error
        let before = (seq.live_states(), seq.t, pool.in_use());
        assert_eq!(
            seq.advance(&mut pool, &k, &v, 1.0, Transition::Decay(0.9)),
            Err(PoolExhausted)
        );
        assert_eq!((seq.live_states(), seq.t, pool.in_use()), before, "failed step must not mutate");
        pool.grow(2);
        seq.advance(&mut pool, &k, &v, 1.0, Transition::Decay(0.9)).unwrap();
        assert_eq!(seq.live_states(), 3); // popcount(3)+1
        seq.release(&mut pool);
        assert_eq!(pool.in_use(), 0);
    }
}
