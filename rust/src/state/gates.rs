//! Position-dependent gate/level-weight tables shared by prefill and
//! decode, with an optional **per-head axis** (ROADMAP items: per-token
//! α/λ instead of fixed scalars, then per-head schedules instead of one
//! table shared across heads).
//!
//! A serving model's gates are a function of absolute position — and,
//! for multi-head models, of the head: the decay gate `α_t^h` applied to
//! head `h`'s carried states at step `t`, the GDN delta strength
//! `β_t^h`, and the level-weight row `λ_t^{h,(·)}` the read at position
//! `t` folds over head `h`'s live levels. [`GateTable`] is the one
//! source both ingestion paths consult — the chunkwise prefill engine
//! reads `alpha_h/beta_h` for a chunk's per-head cumulative decays, the
//! decode step reads `alpha_h(h, pos)` / `lambda_h(h, pos)` per
//! (sequence, head) entry — which is what makes chunkwise-prefilled and
//! token-stepped sequences agree: there is no second copy of the
//! schedule to drift.
//!
//! Head indices clamp to the last provided head, so a 1-head (shared)
//! table serves any number of heads and reproduces the pre-per-head
//! behavior **exactly** (the `*_h(h, t)` accessors degenerate to the
//! shared `alpha(t)`/`lambda(t)`). Positions past the end of a finite
//! table hold the last entry (the same clamping convention as
//! [`super::level_weight`] past the λ width), so a sequence can always
//! outrun the table without dropping gates.

use crate::tensor::Mat;

/// Per-position, optionally per-head gate schedule: `alpha_h(h, t)` decay
/// gates, `beta_h(h, t)` GDN delta strengths, and `lambda_h(h, t)`
/// level-weight rows; head and position both clamp to the last provided
/// entry.
#[derive(Debug, Clone)]
pub struct GateTable {
    /// α tables, one per head (each non-empty; position clamps)
    alpha: Vec<Vec<f32>>,
    /// β tables, one per head (GDN delta strength; defaults to all-1.0)
    beta: Vec<Vec<f32>>,
    /// λ tables, one per head, each `(positions, levels)` row-major
    lambda: Vec<Mat>,
}

impl GateTable {
    /// Position-independent shared gates: one α for every step and head,
    /// one λ row for every position (the original pooled-backend
    /// behavior). β defaults to 1.0 (plain DeltaNet strength); see
    /// [`GateTable::with_beta`].
    pub fn fixed(alpha: f32, lambda: Vec<f32>) -> GateTable {
        assert!(!lambda.is_empty(), "empty lambda row");
        let cols = lambda.len();
        GateTable {
            alpha: vec![vec![alpha]],
            beta: vec![vec![1.0]],
            lambda: vec![Mat::from_vec(1, cols, lambda)],
        }
    }

    /// Fully position-dependent shared gates: `alpha[t]` and
    /// `lambda.row(t)` apply at position `t` for every head; both clamp
    /// to their last entry beyond the table.
    pub fn per_token(alpha: Vec<f32>, lambda: Mat) -> GateTable {
        assert!(!alpha.is_empty(), "empty alpha table");
        assert!(lambda.rows >= 1 && lambda.cols >= 1, "empty lambda table");
        GateTable { alpha: vec![alpha], beta: vec![vec![1.0]], lambda: vec![lambda] }
    }

    /// Install a per-token β schedule (GDN delta strength), replicated to
    /// every head of this table. Clamps past the end like α.
    pub fn with_beta(mut self, beta: Vec<f32>) -> GateTable {
        assert!(!beta.is_empty(), "empty beta table");
        self.beta = vec![beta; self.heads()];
        self
    }

    /// Stack single-head tables into one per-head table: head `h` reads
    /// `tables[h]`'s schedules. Passing `heads` clones of one table is
    /// bit-identical to using that table shared (regression-tested).
    pub fn per_head(tables: Vec<GateTable>) -> GateTable {
        assert!(!tables.is_empty(), "at least one head table");
        let mut alpha = Vec::with_capacity(tables.len());
        let mut beta = Vec::with_capacity(tables.len());
        let mut lambda = Vec::with_capacity(tables.len());
        for t in tables {
            assert_eq!(t.heads(), 1, "per_head composes single-head tables");
            alpha.extend(t.alpha);
            beta.extend(t.beta);
            lambda.extend(t.lambda);
        }
        GateTable { alpha, beta, lambda }
    }

    /// Number of distinct head schedules (1 = shared across heads).
    pub fn heads(&self) -> usize {
        self.alpha.len()
    }

    #[inline]
    fn h(&self, head: usize) -> usize {
        head.min(self.alpha.len() - 1)
    }

    /// Decay gate applied to carried states at step `t` (shared/head-0
    /// view — identical to [`GateTable::alpha_h`] with `head = 0`).
    #[inline]
    pub fn alpha(&self, t: usize) -> f32 {
        self.alpha_h(0, t)
    }

    /// Decay gate for head `head` at step `t` (head clamps to the last
    /// provided schedule, so shared tables serve every head).
    #[inline]
    pub fn alpha_h(&self, head: usize, t: usize) -> f32 {
        let a = &self.alpha[self.h(head)];
        a[t.min(a.len() - 1)]
    }

    /// GDN delta strength for head `head` at step `t`.
    #[inline]
    pub fn beta_h(&self, head: usize, t: usize) -> f32 {
        let b = &self.beta[self.h(head)];
        b[t.min(b.len() - 1)]
    }

    /// Level-weight row for the read at position `t` (shared/head-0 view).
    #[inline]
    pub fn lambda(&self, t: usize) -> &[f32] {
        self.lambda_h(0, t)
    }

    /// Level-weight row for head `head`'s read at position `t`.
    #[inline]
    pub fn lambda_h(&self, head: usize, t: usize) -> &[f32] {
        let l = &self.lambda[self.h(head)];
        l.row(t.min(l.rows - 1))
    }

    /// Number of levels per λ row (head 0's width; all heads agree in
    /// practice, but readers clamp per [`super::level_weight`] anyway).
    pub fn lambda_levels(&self) -> usize {
        self.lambda[0].cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_table_is_position_independent() {
        let g = GateTable::fixed(0.9, vec![1.0, 0.5, 0.25]);
        for t in [0usize, 1, 7, 1000] {
            assert_eq!(g.alpha(t), 0.9);
            assert_eq!(g.lambda(t), &[1.0, 0.5, 0.25]);
        }
        assert_eq!(g.lambda_levels(), 3);
        assert_eq!(g.heads(), 1);
    }

    #[test]
    fn per_token_table_clamps_to_last_entry() {
        let lam = Mat::from_fn(3, 2, |t, l| (10 * t + l) as f32);
        let g = GateTable::per_token(vec![0.5, 0.6, 0.7], lam);
        assert_eq!(g.alpha(0), 0.5);
        assert_eq!(g.alpha(2), 0.7);
        assert_eq!(g.alpha(99), 0.7, "alpha clamps past the table");
        assert_eq!(g.lambda(1), &[10.0, 11.0]);
        assert_eq!(g.lambda(99), &[20.0, 21.0], "lambda clamps past the table");
    }

    #[test]
    fn shared_table_serves_every_head_identically() {
        let g = GateTable::per_token(vec![0.5, 0.6], Mat::from_fn(2, 2, |t, l| (t + l) as f32))
            .with_beta(vec![0.3, 0.4]);
        for head in [0usize, 1, 7] {
            for t in [0usize, 1, 9] {
                assert_eq!(g.alpha_h(head, t), g.alpha(t));
                assert_eq!(g.lambda_h(head, t), g.lambda(t));
                assert_eq!(g.beta_h(head, t), g.beta_h(0, t));
            }
        }
    }

    #[test]
    fn per_head_tables_give_each_head_its_own_schedule() {
        let g = GateTable::per_head(vec![
            GateTable::fixed(0.9, vec![1.0, 0.5]).with_beta(vec![0.2]),
            GateTable::fixed(0.8, vec![1.0, 0.25]).with_beta(vec![0.7]),
        ]);
        assert_eq!(g.heads(), 2);
        assert_eq!(g.alpha_h(0, 5), 0.9);
        assert_eq!(g.alpha_h(1, 5), 0.8);
        assert_eq!(g.beta_h(0, 0), 0.2);
        assert_eq!(g.beta_h(1, 0), 0.7);
        assert_eq!(g.lambda_h(0, 3), &[1.0, 0.5]);
        assert_eq!(g.lambda_h(1, 3), &[1.0, 0.25]);
        // heads past the table clamp to the last schedule
        assert_eq!(g.alpha_h(9, 5), 0.8);
    }

    #[test]
    #[should_panic(expected = "single-head tables")]
    fn per_head_rejects_nested_per_head_tables() {
        let two = GateTable::per_head(vec![
            GateTable::fixed(0.9, vec![1.0]),
            GateTable::fixed(0.8, vec![1.0]),
        ]);
        let _ = GateTable::per_head(vec![two, GateTable::fixed(0.7, vec![1.0])]);
    }
}
