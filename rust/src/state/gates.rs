//! Position-dependent gate/level-weight tables shared by prefill and
//! decode (ROADMAP item: per-token α/λ instead of the fixed scalars the
//! pooled backend hard-coded).
//!
//! A serving model's gates are a function of absolute position: the decay
//! gate `α_t` applied to carried states at step `t`, and the level-weight
//! row `λ_t^{(·)}` the read at position `t` folds over live levels.
//! [`GateTable`] is the one source both ingestion paths consult —
//! the chunkwise prefill engine reads `alpha(pos..pos+C)` for a chunk's
//! cumulative decays, the decode step reads `alpha(pos)` / `lambda(pos)`
//! for its transition and batched read — which is what makes
//! chunkwise-prefilled and token-stepped sequences agree: there is no
//! second copy of the schedule to drift.
//!
//! Past the end of a finite table the last entry is held (the same
//! clamping convention as [`super::level_weight`] past the λ width), so a
//! sequence can always outrun the table without dropping gates.

use crate::tensor::Mat;

/// Per-position gate schedule: `alpha(t)` decay gates and `lambda(t)`
/// level-weight rows, clamped to the last provided position.
#[derive(Debug, Clone)]
pub struct GateTable {
    /// α_t per position (non-empty; index clamps to the last entry)
    alpha: Vec<f32>,
    /// λ rows, `(positions, levels)` row-major (≥1 row; row clamps)
    lambda: Mat,
}

impl GateTable {
    /// Position-independent gates: one α for every step, one λ row for
    /// every position (the pre-PR pooled-backend behavior).
    pub fn fixed(alpha: f32, lambda: Vec<f32>) -> GateTable {
        assert!(!lambda.is_empty(), "empty lambda row");
        let cols = lambda.len();
        GateTable { alpha: vec![alpha], lambda: Mat::from_vec(1, cols, lambda) }
    }

    /// Fully position-dependent gates: `alpha[t]` and `lambda.row(t)`
    /// apply at position `t`; both clamp to their last entry beyond the
    /// table.
    pub fn per_token(alpha: Vec<f32>, lambda: Mat) -> GateTable {
        assert!(!alpha.is_empty(), "empty alpha table");
        assert!(lambda.rows >= 1 && lambda.cols >= 1, "empty lambda table");
        GateTable { alpha, lambda }
    }

    /// Decay gate applied to carried states at step `t`.
    #[inline]
    pub fn alpha(&self, t: usize) -> f32 {
        self.alpha[t.min(self.alpha.len() - 1)]
    }

    /// Level-weight row for the read at position `t`.
    #[inline]
    pub fn lambda(&self, t: usize) -> &[f32] {
        self.lambda.row(t.min(self.lambda.rows - 1))
    }

    /// Number of levels per λ row.
    pub fn lambda_levels(&self) -> usize {
        self.lambda.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_table_is_position_independent() {
        let g = GateTable::fixed(0.9, vec![1.0, 0.5, 0.25]);
        for t in [0usize, 1, 7, 1000] {
            assert_eq!(g.alpha(t), 0.9);
            assert_eq!(g.lambda(t), &[1.0, 0.5, 0.25]);
        }
        assert_eq!(g.lambda_levels(), 3);
    }

    #[test]
    fn per_token_table_clamps_to_last_entry() {
        let lam = Mat::from_fn(3, 2, |t, l| (10 * t + l) as f32);
        let g = GateTable::per_token(vec![0.5, 0.6, 0.7], lam);
        assert_eq!(g.alpha(0), 0.5);
        assert_eq!(g.alpha(2), 0.7);
        assert_eq!(g.alpha(99), 0.7, "alpha clamps past the table");
        assert_eq!(g.lambda(1), &[10.0, 11.0]);
        assert_eq!(g.lambda(99), &[20.0, 21.0], "lambda clamps past the table");
    }
}
