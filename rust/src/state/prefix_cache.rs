//! Radix-tree prefix-state cache over the [`StatePool`] — vLLM-style
//! prefix caching transposed onto log-linear attention's Fenwick level
//! states.
//!
//! A softmax server's prefix cache shares O(T) KV pages; here the entire
//! context of a prefix lives in the O(log T) chunk-boundary level states
//! the chunkwise prefill engine exports (`prefill::bridge`), which makes
//! those boundaries *cheap snapshot points*: one retained `(d_k × d_v)`
//! block per live level per (layer, head). [`PrefixCache`] keys those
//! snapshots by token-id prefix at **chunk granularity** — a radix tree
//! whose edges are whole `chunk`-token runs — so a request whose prompt
//! shares `m` leading chunks with any previously served prompt can adopt
//! the cached boundary state (via
//! [`PooledFenwickState::adopt_levels`](crate::state::pooled::PooledFenwickState::adopt_levels))
//! and resume chunkwise prefill at the match point instead of re-ingesting
//! those `m·C` tokens: the paper's O(T log T) prefill cost for a shared
//! system prompt is paid once, then amortized across every later request.
//!
//! **Why token-id keys suffice.** A serving backend's embeddings,
//! projections, and gate schedules are fixed per model instance and a
//! boundary state is a deterministic function of (weights, gates, token
//! ids), so an identical token prefix implies a *bit-identical* boundary
//! hierarchy — cache validity needs no epoch or weight-hash, only that
//! the owning backend invalidates on gate swaps (it does).
//!
//! **Ownership.** The cache owns one refcount on every block of every
//! entry ([`StatePool::retain`] at insertion — entries share the blocks
//! the exporting sequence already holds, so insertion allocates nothing
//! and cannot fail). Sequences admitted from a hit share the same blocks;
//! the copy-on-write step in the advance paths (see
//! [`crate::state::pool`]'s module docs) guarantees cached bytes are
//! never mutated. [`PrefixCache::evict_lru`] releases one entry's
//! refcounts under pool pressure — blocks still adopted by live readers
//! survive until those sequences retire (refcounted release), so eviction
//! is always safe, merely un-sharing future admissions.

use crate::state::pool::{BlockId, StatePool};

/// Exported boundary states of one cached prefix: indexed
/// `layer * heads + head`, each a list of live `(token_level, block)`
/// pairs at the boundary position.
pub type BoundaryStates = Vec<Vec<(usize, BlockId)>>;

struct Entry {
    states: BoundaryStates,
    last_used: u64,
}

#[derive(Default)]
struct Node {
    /// child edges, each labeled by the next `chunk` token ids
    children: Vec<(Vec<i32>, usize)>,
    entry: Option<Entry>,
}

/// Chunk-granular radix tree of boundary snapshots (see module docs).
pub struct PrefixCache {
    chunk: usize,
    /// node 0 is the root (empty prefix; never holds an entry)
    nodes: Vec<Node>,
    entries: usize,
    blocks_held: usize,
    /// LRU clock: bumped on every lookup/insert touch
    tick: u64,
}

impl PrefixCache {
    /// `chunk` = the backend's prefill chunk size (boundary granularity).
    pub fn new(chunk: usize) -> PrefixCache {
        assert!(chunk >= 1, "chunk granularity");
        PrefixCache { chunk, nodes: vec![Node::default()], entries: 0, blocks_held: 0, tick: 0 }
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Number of cached boundary snapshots.
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    /// Total pool blocks this cache holds a refcount on.
    pub fn blocks_held(&self) -> usize {
        self.blocks_held
    }

    /// Every [`BlockId`] the cache currently holds a refcount on, entry by
    /// entry (duplicates possible: two snapshots may share a block). Feeds
    /// the debug-build leak canary in `coordinator::backend` — the union of
    /// these and the live sequences' blocks must account for every
    /// allocated pool block.
    pub(crate) fn held_block_ids(&self) -> Vec<BlockId> {
        let mut ids = Vec::with_capacity(self.blocks_held);
        for node in &self.nodes {
            if let Some(entry) = &node.entry {
                for per_head in &entry.states {
                    ids.extend(per_head.iter().map(|&(_, id)| id));
                }
            }
        }
        ids
    }

    /// Length of the longest cached prefix of `tokens`, without touching
    /// LRU state or emitting trace events — a side-effect-free probe. The
    /// sharded façade peeks every shard with this and then `lookup`s only
    /// the winning shard, so losing shards' entries never get spuriously
    /// marked recently-used by a probe they lost.
    pub(crate) fn peek_match(&self, tokens: &[i32]) -> Option<usize> {
        let mut node = 0usize;
        let mut best = None;
        let mut depth = 0usize;
        while (depth + 1) * self.chunk <= tokens.len() {
            let run = &tokens[depth * self.chunk..(depth + 1) * self.chunk];
            let Some(&(_, next)) =
                self.nodes[node].children.iter().find(|(edge, _)| edge == run)
            else {
                break;
            };
            node = next;
            depth += 1;
            if self.nodes[node].entry.is_some() {
                best = Some(depth * self.chunk);
            }
        }
        best
    }

    /// Longest cached prefix of `tokens`, matching whole chunks only.
    /// Returns `(matched_tokens, states)` for the deepest boundary with a
    /// snapshot (and marks it most-recently used); `None` when no
    /// boundary prefix is cached. The returned handles are still owned by
    /// the cache — callers adopt them with a `retain` per block
    /// (`PooledFenwickState::adopt_levels`), never take them.
    pub fn lookup(&mut self, tokens: &[i32]) -> Option<(usize, BoundaryStates)> {
        let _probe = crate::obs::span(crate::obs::SpanCat::PrefixProbe, tokens.len() as u64);
        let mut node = 0usize;
        let mut best: Option<(usize, usize)> = None; // (node, matched tokens)
        let mut depth = 0usize;
        while (depth + 1) * self.chunk <= tokens.len() {
            let run = &tokens[depth * self.chunk..(depth + 1) * self.chunk];
            let Some(&(_, next)) =
                self.nodes[node].children.iter().find(|(edge, _)| edge == run)
            else {
                break;
            };
            node = next;
            depth += 1;
            if self.nodes[node].entry.is_some() {
                best = Some((node, depth * self.chunk));
            }
        }
        let (node, matched) = best?;
        crate::obs::instant(crate::obs::SpanCat::PrefixHit, matched as u64);
        self.tick += 1;
        let entry = self.nodes[node].entry.as_mut().expect("picked above");
        entry.last_used = self.tick;
        Some((matched, entry.states.clone()))
    }

    /// Cache the boundary snapshot of `tokens` (length must be a positive
    /// multiple of the chunk size). Retains every block — the entry
    /// *shares* the exporting sequence's blocks, so insertion allocates
    /// nothing and cannot fail. A boundary that is already cached is left
    /// as-is (determinism makes the existing snapshot bit-identical) and
    /// merely touched. Returns whether a new entry was created.
    pub fn insert(
        &mut self,
        tokens: &[i32],
        states: &BoundaryStates,
        pool: &mut StatePool,
    ) -> bool {
        assert!(
            !tokens.is_empty() && tokens.len() % self.chunk == 0,
            "prefix length {} is not a positive multiple of the chunk size {}",
            tokens.len(),
            self.chunk
        );
        let mut node = 0usize;
        for run in tokens.chunks(self.chunk) {
            node = match self.nodes[node].children.iter().find(|(edge, _)| edge == run) {
                Some(&(_, next)) => next,
                None => {
                    let next = self.nodes.len();
                    self.nodes.push(Node::default());
                    self.nodes[node].children.push((run.to_vec(), next));
                    next
                }
            };
        }
        self.tick += 1;
        if let Some(entry) = self.nodes[node].entry.as_mut() {
            entry.last_used = self.tick;
            return false;
        }
        let mut held = 0usize;
        for per_head in states {
            for &(_, id) in per_head {
                // xtask: allow(refcount): the cache entry owns this ref;
                // evict_lru / clear release it via release_entry
                pool.retain(id);
                held += 1;
            }
        }
        self.nodes[node].entry = Some(Entry { states: states.clone(), last_used: self.tick });
        self.entries += 1;
        self.blocks_held += held;
        true
    }

    /// Release the least-recently-used snapshot's refcounts back to the
    /// pool (the pool-pressure valve). Blocks still adopted by live
    /// sequences stay allocated until those sequences retire. Returns
    /// false when the cache is already empty.
    pub fn evict_lru(&mut self, pool: &mut StatePool) -> bool {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| n.entry.as_ref().map(|e| (e.last_used, i)))
            .min()
            .map(|(_, i)| i);
        let Some(i) = victim else {
            return false;
        };
        let entry = self.nodes[i].entry.take().expect("picked above");
        let held_before = self.blocks_held;
        self.release_entry(&entry, pool);
        crate::obs::instant(
            crate::obs::SpanCat::PrefixEvict,
            (held_before - self.blocks_held) as u64,
        );
        true
    }

    /// Drop every snapshot, releasing all refcounts (gate-swap
    /// invalidation, end-of-trace leak accounting).
    pub fn clear(&mut self, pool: &mut StatePool) {
        for i in 0..self.nodes.len() {
            if let Some(entry) = self.nodes[i].entry.take() {
                self.release_entry(&entry, pool);
            }
        }
        self.nodes = vec![Node::default()];
    }

    fn release_entry(&mut self, entry: &Entry, pool: &mut StatePool) {
        for per_head in &entry.states {
            for &(_, id) in per_head {
                pool.release(id);
                self.blocks_held -= 1;
            }
        }
        self.entries -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a fake boundary snapshot: `n` freshly allocated blocks
    /// tagged with `tag`, presented as one (layer, head) state list.
    fn snapshot(pool: &mut StatePool, n: usize, tag: f32) -> BoundaryStates {
        let mut per_head = Vec::new();
        for j in 0..n {
            let id = pool.alloc().unwrap();
            pool.get_mut(id)[0] = tag + j as f32;
            per_head.push((j + 1, id));
        }
        vec![per_head]
    }

    fn drop_snapshot(pool: &mut StatePool, s: &BoundaryStates) {
        for per_head in s {
            for &(_, id) in per_head {
                pool.release(id);
            }
        }
    }

    #[test]
    fn longest_chunk_prefix_wins_and_partial_chunks_never_match() {
        let mut pool = StatePool::new(4, 16);
        let mut cache = PrefixCache::new(4);
        let s8 = snapshot(&mut pool, 2, 10.0);
        let s4 = snapshot(&mut pool, 1, 20.0);
        let p: Vec<i32> = (0..12).collect();
        cache.insert(&p[..8], &s8, &mut pool);
        cache.insert(&p[..4], &s4, &mut pool);
        assert_eq!(cache.len(), 2);

        // full 8-token prefix match beats the 4-token one
        let (m, states) = cache.lookup(&p).unwrap();
        assert_eq!(m, 8);
        assert_eq!(states, s8);
        // diverging second chunk falls back to the 4-token boundary
        let mut q = p.clone();
        q[5] = 99;
        let (m, states) = cache.lookup(&q).unwrap();
        assert_eq!(m, 4);
        assert_eq!(states, s4);
        // a prompt shorter than one chunk can never match
        assert!(cache.lookup(&p[..3]).is_none());
        // diverging first chunk: no match at all
        let mut r = p.clone();
        r[0] = 99;
        assert!(cache.lookup(&r).is_none());

        cache.clear(&mut pool);
        drop_snapshot(&mut pool, &s8);
        drop_snapshot(&mut pool, &s4);
        assert_eq!(pool.in_use(), 0, "cache refcounts must drain");
    }

    #[test]
    fn insert_retains_and_duplicate_insert_is_a_touch() {
        let mut pool = StatePool::new(4, 8);
        let mut cache = PrefixCache::new(2);
        let s = snapshot(&mut pool, 2, 1.0);
        let p = [1, 2, 3, 4];
        assert!(cache.insert(&p, &s, &mut pool));
        assert_eq!(pool.ref_count(s[0][0].1), 2, "cache holds its own ref");
        assert!(!cache.insert(&p, &s, &mut pool), "re-insert is a touch, not a new entry");
        assert_eq!(pool.ref_count(s[0][0].1), 2, "no double retain");
        assert_eq!(cache.blocks_held(), 2);
        // the exporting sequence retires; cached blocks stay live
        drop_snapshot(&mut pool, &s);
        assert_eq!(pool.in_use(), 2);
        cache.clear(&mut pool);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn lru_eviction_releases_refcounts_but_spares_live_readers() {
        let mut pool = StatePool::new(4, 16);
        let mut cache = PrefixCache::new(2);
        let sa = snapshot(&mut pool, 1, 1.0);
        let sb = snapshot(&mut pool, 1, 2.0);
        cache.insert(&[1, 1], &sa, &mut pool);
        cache.insert(&[2, 2], &sb, &mut pool);
        drop_snapshot(&mut pool, &sa);
        drop_snapshot(&mut pool, &sb);
        // a reader adopts `a`'s block (retain), then `a` becomes LRU prey
        let (_, a_states) = cache.lookup(&[1, 1]).unwrap();
        let a_block = a_states[0][0].1;
        pool.retain(a_block); // the live reader's ref
        let _ = cache.lookup(&[2, 2]).unwrap(); // b is now more recent
        assert!(cache.evict_lru(&mut pool), "evicts a (LRU)");
        assert_eq!(cache.len(), 1);
        // the reader keeps the block alive despite eviction
        assert_eq!(pool.get(a_block)[0], 1.0, "live reader unaffected by eviction");
        assert!(cache.lookup(&[1, 1]).is_none(), "evicted prefix no longer matches");
        assert!(cache.evict_lru(&mut pool), "evicts b");
        assert!(!cache.evict_lru(&mut pool), "empty cache has nothing to evict");
        pool.release(a_block);
        assert_eq!(pool.in_use(), 0);
    }
}
