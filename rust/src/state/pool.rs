//! A slab/free-list pool of fixed-size state buffers for batched serving.
//!
//! Sequences in the decode server each hold `popcount(t)+1` live level
//! states; the pool recycles (d_k × d_v) blocks across sequences so the
//! server's memory footprint follows the *sum of live states*, analogous
//! to how paged KV-cache allocators (vLLM) track used pages rather than
//! max context. Invariants (no leak, no double-free, no use-after-free)
//! are property-tested below.
//!
//! This is the storage layer of the pooled decode path:
//! [`crate::state::pooled::PooledFenwickState`] keeps its live level
//! states as [`BlockId`]s here, and
//! [`crate::state::pooled::BatchedDecoder`] reads all live blocks across
//! a whole decode batch straight out of the contiguous `storage` slab —
//! one λ-weighted block-sparse GEMM over `(Σ live, d_k·d_v)` resident
//! floats instead of `Σ_i popcount(t_i)` scattered matvecs. Exhaustion is
//! a *backpressure signal*: [`StatePool::alloc`] returns `None` and the
//! serving coordinator defers admission (see
//! `coordinator::backend::PooledBackend`) rather than growing
//! unboundedly; capacity planning can use [`StatePool::grow`] and the
//! [`StatePool::peak`] accounting.
//!
//! ## Refcounts and copy-on-write
//!
//! Blocks carry a reference count so the prefix-state cache
//! ([`crate::state::prefix_cache`]) can hand the *same* chunk-boundary
//! level states to many sequences without copying:
//!
//! - [`StatePool::alloc`] returns a block with refcount 1 (sole owner) —
//!   existing callers see no change.
//! - [`StatePool::retain`] adds an owner; [`StatePool::release`] drops
//!   one, and the block only returns to the free list when the last
//!   owner releases (so "release" is always safe to call, shared or
//!   not).
//! - A block with refcount > 1 ([`StatePool::is_shared`]) is
//!   **immutable**: [`StatePool::get_mut`] and the [`StatePool::axpy`]
//!   destination assert sole ownership, so any write to shared state is
//!   a loud bug, not silent corruption. Writers clone first
//!   ([`StatePool::clone_block`] — a bitwise copy into a fresh block)
//!   and release their shared handle: copy-on-write. The batched advance
//!   (`state::batched_advance`) and the per-sequence
//!   [`crate::state::pooled::PooledFenwickState::advance`] both perform
//!   this clone-before-mutate step, which is what lets a sequence
//!   admitted from cached blocks decode without ever touching shared
//!   state.

/// Handle to one pooled block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockId(pub usize);

/// Fixed-block-size pool with a free list.
#[derive(Debug)]
pub struct StatePool {
    block_elems: usize,
    storage: Vec<f32>,
    free: Vec<usize>,
    allocated: Vec<bool>,
    /// Owners per block (0 when free; `alloc` starts at 1). A count > 1
    /// marks the block shared and therefore immutable (see module docs).
    refcount: Vec<u32>,
    peak_blocks: usize,
}

impl StatePool {
    /// `block_elems` = d_k * d_v; `capacity` = max simultaneous blocks.
    pub fn new(block_elems: usize, capacity: usize) -> StatePool {
        StatePool {
            block_elems,
            storage: vec![0.0; block_elems * capacity],
            free: (0..capacity).rev().collect(),
            allocated: vec![false; capacity],
            refcount: vec![0; capacity],
            peak_blocks: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.allocated.len()
    }

    pub fn in_use(&self) -> usize {
        self.capacity() - self.free.len()
    }

    pub fn peak(&self) -> usize {
        self.peak_blocks
    }

    /// Blocks still allocatable before the pool is exhausted.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Elements per block (d_k · d_v).
    pub fn block_elems(&self) -> usize {
        self.block_elems
    }

    /// Append `extra` zeroed blocks to the pool (capacity planning; the
    /// serving path prefers admission backpressure over growth so resident
    /// memory stays bounded, but offline drivers can expand freely).
    /// Existing [`BlockId`]s remain valid.
    pub fn grow(&mut self, extra: usize) {
        let old = self.capacity();
        self.storage.resize((old + extra) * self.block_elems, 0.0);
        self.allocated.resize(old + extra, false);
        self.refcount.resize(old + extra, 0);
        for idx in (old..old + extra).rev() {
            self.free.push(idx);
        }
    }

    /// Allocate a zeroed block; None if the pool is exhausted
    /// (backpressure signal for the batcher). The caller is the sole
    /// owner (refcount 1).
    // xtask: deny_alloc
    pub fn alloc(&mut self) -> Option<BlockId> {
        let idx = self.free.pop()?;
        debug_assert!(!self.allocated[idx]);
        self.allocated[idx] = true;
        self.refcount[idx] = 1;
        let s = idx * self.block_elems;
        self.storage[s..s + self.block_elems].fill(0.0);
        self.peak_blocks = self.peak_blocks.max(self.in_use());
        Some(BlockId(idx))
    }

    /// Add an owner to a live block (prefix-cache insertion, shared
    /// admission). Every `retain` must be paired with a later
    /// [`StatePool::release`].
    // xtask: deny_alloc
    pub fn retain(&mut self, id: BlockId) {
        assert!(self.allocated[id.0], "retain of freed block {}", id.0);
        self.refcount[id.0] += 1;
    }

    /// Drop one ownership of a block; the block returns to the free list
    /// only when the last owner releases. Panics on double-free (more
    /// releases than `alloc` + `retain`s).
    // xtask: deny_alloc
    pub fn release(&mut self, id: BlockId) {
        assert!(self.allocated[id.0], "double free of block {}", id.0);
        self.refcount[id.0] -= 1;
        if self.refcount[id.0] == 0 {
            self.allocated[id.0] = false;
            self.free.push(id.0);
        }
    }

    /// Current owner count of a live block.
    pub fn ref_count(&self, id: BlockId) -> u32 {
        assert!(self.allocated[id.0], "use after free");
        self.refcount[id.0]
    }

    /// More than one owner ⇒ the block is immutable and writers must
    /// copy first (see module docs).
    pub fn is_shared(&self, id: BlockId) -> bool {
        self.ref_count(id) > 1
    }

    /// Bitwise copy of `src` into a freshly allocated block — THE
    /// copy-on-write step. `None` on exhaustion (clean backpressure, no
    /// mutation). `src` keeps its owners; the caller owns the clone.
    pub fn clone_block(&mut self, src: BlockId) -> Option<BlockId> {
        assert!(self.allocated[src.0], "clone of freed block {}", src.0);
        let dst = self.alloc()?;
        debug_assert_ne!(dst.0, src.0);
        let (d, s) = (dst.0 * self.block_elems, src.0 * self.block_elems);
        self.storage.copy_within(s..s + self.block_elems, d);
        Some(dst)
    }

    // xtask: deny_alloc
    pub fn get(&self, id: BlockId) -> &[f32] {
        assert!(self.allocated[id.0], "use after free");
        debug_assert!(
            self.refcount[id.0] > 0,
            "read of live block {} with zero refcount (accounting drift)",
            id.0
        );
        let s = id.0 * self.block_elems;
        &self.storage[s..s + self.block_elems]
    }

    // xtask: deny_alloc
    pub fn get_mut(&mut self, id: BlockId) -> &mut [f32] {
        assert!(self.allocated[id.0], "use after free");
        assert!(
            self.refcount[id.0] == 1,
            "write to shared block {} (copy-on-write violation)",
            id.0
        );
        let s = id.0 * self.block_elems;
        &mut self.storage[s..s + self.block_elems]
    }

    /// The raw slab, for batched passes that partition work across many
    /// *allocated* blocks in one dispatch
    /// ([`crate::tensor::slab_block_dispatch`], driven by
    /// `state::batched_advance`). Callers must touch only ranges of
    /// blocks they hold live [`BlockId`]s for.
    pub(crate) fn slab_mut(&mut self) -> &mut [f32] {
        &mut self.storage
    }

    /// Is this block currently allocated? (validation hook for the
    /// batched passes that bypass [`StatePool::get_mut`]).
    pub(crate) fn is_allocated(&self, id: BlockId) -> bool {
        self.allocated[id.0]
    }

    /// `dst += scale * src` across two blocks (bucket merge). `dst` must
    /// be solely owned (copy-on-write contract); `src` may be shared.
    // xtask: deny_alloc
    pub fn axpy(&mut self, dst: BlockId, src: BlockId, scale: f32) {
        assert!(self.allocated[dst.0] && self.allocated[src.0]);
        assert!(
            self.refcount[dst.0] == 1,
            "axpy into shared block {} (copy-on-write violation)",
            dst.0
        );
        assert_ne!(dst.0, src.0);
        let (d, s) = (dst.0 * self.block_elems, src.0 * self.block_elems);
        // disjoint ranges: split_at_mut
        if d < s {
            let (a, b) = self.storage.split_at_mut(s);
            let dsl = &mut a[d..d + self.block_elems];
            let ssl = &b[..self.block_elems];
            for (x, &y) in dsl.iter_mut().zip(ssl) {
                *x += scale * y;
            }
        } else {
            let (a, b) = self.storage.split_at_mut(d);
            let ssl = &a[s..s + self.block_elems];
            let dsl = &mut b[..self.block_elems];
            for (x, &y) in dsl.iter_mut().zip(ssl) {
                *x += scale * y;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, UsizeIn};
    use crate::util::Rng;

    #[test]
    fn alloc_release_cycle() {
        let mut pool = StatePool::new(16, 4);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!(pool.in_use(), 2);
        pool.get_mut(a)[0] = 1.0;
        pool.get_mut(b)[0] = 2.0;
        pool.axpy(a, b, 3.0);
        assert_eq!(pool.get(a)[0], 7.0);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut pool = StatePool::new(4, 2);
        let _a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        assert!(pool.alloc().is_none());
    }

    #[test]
    fn freshly_allocated_blocks_are_zeroed() {
        let mut pool = StatePool::new(8, 2);
        let a = pool.alloc().unwrap();
        pool.get_mut(a).fill(9.0);
        pool.release(a);
        let b = pool.alloc().unwrap();
        assert!(pool.get(b).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn grow_extends_capacity_and_keeps_blocks_valid() {
        let mut pool = StatePool::new(4, 1);
        let a = pool.alloc().unwrap();
        pool.get_mut(a)[0] = 5.0;
        assert!(pool.alloc().is_none());
        pool.grow(2);
        assert_eq!(pool.capacity(), 3);
        assert_eq!(pool.available(), 2);
        let b = pool.alloc().unwrap();
        assert!(pool.get(b).iter().all(|&x| x == 0.0));
        assert_eq!(pool.get(a)[0], 5.0, "grow must not move existing blocks' data");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut pool = StatePool::new(4, 2);
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }

    #[test]
    fn retain_defers_free_until_last_release() {
        let mut pool = StatePool::new(4, 2);
        let a = pool.alloc().unwrap();
        pool.get_mut(a)[0] = 3.0;
        pool.retain(a);
        assert_eq!(pool.ref_count(a), 2);
        assert!(pool.is_shared(a));
        pool.release(a); // one owner left; block stays live
        assert_eq!(pool.in_use(), 1);
        assert_eq!(pool.get(a)[0], 3.0);
        assert!(!pool.is_shared(a));
        pool.release(a);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn clone_block_is_a_bitwise_copy_with_private_ownership() {
        let mut pool = StatePool::new(4, 3);
        let a = pool.alloc().unwrap();
        pool.get_mut(a).copy_from_slice(&[1.5, -0.0, 2.5e-40, f32::MIN_POSITIVE]);
        pool.retain(a); // a is now shared (cache + sequence)
        let b = pool.clone_block(a).unwrap();
        assert_eq!(
            pool.get(a)
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<_>>(),
            pool.get(b).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "clone must be bit-identical"
        );
        assert_eq!(pool.ref_count(b), 1, "clone is privately owned");
        pool.get_mut(b)[0] = 9.0; // writable: sole owner
        assert_eq!(pool.get(a)[0], 1.5, "source untouched by writes to the clone");
        pool.release(a);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    #[should_panic(expected = "copy-on-write violation")]
    fn writing_a_shared_block_panics() {
        let mut pool = StatePool::new(4, 2);
        let a = pool.alloc().unwrap();
        pool.retain(a);
        pool.get_mut(a)[0] = 1.0;
    }

    #[test]
    #[should_panic(expected = "copy-on-write violation")]
    fn axpy_into_a_shared_block_panics() {
        let mut pool = StatePool::new(4, 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        pool.retain(a);
        pool.axpy(a, b, 1.0);
    }

    #[test]
    fn random_retain_release_cow_traces_never_leak_property() {
        // The refcounted mirror of `random_workload_never_leaks_property`:
        // random alloc / retain / release / clone-on-write traces, with a
        // shadow refcount model. Invariants: in_use equals the number of
        // blocks with a live shadow count, no block is reused while any
        // owner remains (contents survive until the last release), and
        // everything drains to zero.
        check("pool refcount no-leak", 50, &UsizeIn(1, 500), |&seed| {
            let mut rng = Rng::new(seed as u64 ^ 0xC0DE);
            let mut pool = StatePool::new(4, 24);
            // (id, shadow_refcount, tag) — tag written at alloc, must
            // survive while any owner remains
            let mut live: Vec<(BlockId, u32, f32)> = Vec::new();
            let mut next_tag = 1.0f32;
            for _ in 0..300 {
                match rng.below(4) {
                    0 => {
                        if let Some(id) = pool.alloc() {
                            let tag = next_tag;
                            next_tag += 1.0;
                            pool.get_mut(id)[0] = tag;
                            live.push((id, 1, tag));
                        }
                    }
                    1 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        pool.retain(live[i].0);
                        live[i].1 += 1;
                    }
                    2 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        pool.release(live[i].0);
                        live[i].1 -= 1;
                        if live[i].1 == 0 {
                            live.swap_remove(i);
                        }
                    }
                    _ if !live.is_empty() => {
                        // copy-on-write: writers of shared blocks clone
                        // first; sole owners may write in place
                        let i = rng.below(live.len());
                        let (id, rc, tag) = live[i];
                        if rc > 1 {
                            if let Some(c) = pool.clone_block(id) {
                                if pool.get(c)[0] != tag {
                                    return false;
                                }
                                let tag2 = next_tag;
                                next_tag += 1.0;
                                pool.get_mut(c)[0] = tag2;
                                pool.release(id);
                                live[i].1 -= 1;
                                live.push((c, 1, tag2));
                            }
                        } else {
                            let tag2 = next_tag;
                            next_tag += 1.0;
                            pool.get_mut(id)[0] = tag2;
                            live[i].2 = tag2;
                        }
                    }
                    _ => {}
                }
                if pool.in_use() != live.len() {
                    return false;
                }
                // no premature reuse: every owned block still holds its tag
                if live.iter().any(|&(id, _, tag)| pool.get(id)[0] != tag) {
                    return false;
                }
            }
            for (id, rc, _) in live.drain(..) {
                for _ in 0..rc {
                    pool.release(id);
                }
            }
            pool.in_use() == 0 && pool.peak() <= 24
        });
    }

    #[test]
    fn random_workload_never_leaks_property() {
        // Random alloc/release traces: allocated count always equals
        // in_use, and everything released is reusable.
        check("pool no-leak", 50, &UsizeIn(1, 500), |&seed| {
            let mut rng = Rng::new(seed as u64);
            let mut pool = StatePool::new(4, 32);
            let mut live: Vec<BlockId> = Vec::new();
            for _ in 0..200 {
                if !live.is_empty() && rng.chance(0.45) {
                    let i = rng.below(live.len());
                    let id = live.swap_remove(i);
                    pool.release(id);
                } else if let Some(id) = pool.alloc() {
                    live.push(id);
                }
                if pool.in_use() != live.len() {
                    return false;
                }
            }
            for id in live.drain(..) {
                pool.release(id);
            }
            pool.in_use() == 0 && pool.peak() <= 32
        });
    }
}
