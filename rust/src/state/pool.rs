//! A slab/free-list pool of fixed-size state buffers for batched serving.
//!
//! Sequences in the decode server each hold `popcount(t)+1` live level
//! states; the pool recycles (d_k × d_v) blocks across sequences so the
//! server's memory footprint follows the *sum of live states*, analogous
//! to how paged KV-cache allocators (vLLM) track used pages rather than
//! max context. Invariants (no leak, no double-free, no use-after-free)
//! are property-tested below.
//!
//! This is the storage layer of the pooled decode path:
//! [`crate::state::pooled::PooledFenwickState`] keeps its live level
//! states as [`BlockId`]s here, and
//! [`crate::state::pooled::BatchedDecoder`] reads all live blocks across
//! a whole decode batch straight out of the contiguous `storage` slab —
//! one λ-weighted block-sparse GEMM over `(Σ live, d_k·d_v)` resident
//! floats instead of `Σ_i popcount(t_i)` scattered matvecs. Exhaustion is
//! a *backpressure signal*: [`StatePool::alloc`] returns `None` and the
//! serving coordinator defers admission (see
//! `coordinator::backend::PooledBackend`) rather than growing
//! unboundedly; capacity planning can use [`StatePool::grow`] and the
//! [`StatePool::peak`] accounting.
//!
//! ## Precision modes
//!
//! The slab stores blocks either at f32 ([`Precision::F32`], the
//! default, bit-exact with the per-sequence `FenwickState` oracle) or at
//! bf16 ([`Precision::Bf16`]): each element is the top 16 bits of its
//! f32 value, narrowed round-to-nearest-even by
//! [`crate::tensor::half::f32_to_bf16`]. Every *read* widens to f32
//! (exactly), and every *accumulate* — [`StatePool::axpy`], the
//! transition/write primitives in [`crate::state::update`], the batched
//! slab dispatch, the batched decode read — runs its arithmetic at f32
//! and narrows only the stored result, halving state bytes per sequence
//! at a bounded relative error (derivation in docs/PRECISION.md). The
//! f32 accessors ([`StatePool::get`]/[`StatePool::get_mut`]) panic in
//! bf16 mode so a precision-oblivious caller fails loudly instead of
//! reinterpreting bits.
//!
//! ## Freed-block contents
//!
//! The contract, pinned by `freed_blocks_never_leak_stale_bits` below:
//! a freed block's storage MAY keep its stale bytes until reallocation
//! (nothing scrubs on `release`), and [`StatePool::alloc`] therefore
//! ALWAYS zero-fills before handing a block out. No reader may touch a
//! block it doesn't own, so stale bytes are unobservable; the zero-fill
//! is what makes that true across realloc — including in bf16 mode,
//! where a narrowing write that skips zero-fill could otherwise leave
//! stale low bits visible next to freshly narrowed values (e.g. a
//! subnormal or `-0.0` resurrected into a new sequence's state).
//!
//! ## Refcounts and copy-on-write
//!
//! Blocks carry a reference count so the prefix-state cache
//! ([`crate::state::prefix_cache`]) can hand the *same* chunk-boundary
//! level states to many sequences without copying:
//!
//! - [`StatePool::alloc`] returns a block with refcount 1 (sole owner) —
//!   existing callers see no change.
//! - [`StatePool::retain`] adds an owner; [`StatePool::release`] drops
//!   one, and the block only returns to the free list when the last
//!   owner releases (so "release" is always safe to call, shared or
//!   not).
//! - A block with refcount > 1 ([`StatePool::is_shared`]) is
//!   **immutable**: [`StatePool::get_mut`] and the [`StatePool::axpy`]
//!   destination assert sole ownership, so any write to shared state is
//!   a loud bug, not silent corruption. Writers clone first
//!   ([`StatePool::clone_block`] — a bitwise copy into a fresh block)
//!   and release their shared handle: copy-on-write. The batched advance
//!   (`state::batched_advance`) and the per-sequence
//!   [`crate::state::pooled::PooledFenwickState::advance`] both perform
//!   this clone-before-mutate step, which is what lets a sequence
//!   admitted from cached blocks decode without ever touching shared
//!   state.

use crate::tensor::half::{bf16_to_f32, f32_to_bf16};

/// Handle to one pooled block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockId(pub usize);

/// Storage precision of a [`StatePool`] slab (module docs above).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// 4 bytes/element, bit-exact with the per-sequence oracle.
    F32,
    /// 2 bytes/element (bf16, RNE narrowing), f32 arithmetic on every
    /// read/accumulate; tolerance-bounded vs the f32 oracle.
    Bf16,
}

impl Precision {
    pub fn bytes_per_elem(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Bf16 => 2,
        }
    }
}

/// The backing storage — one contiguous slab per pool, element type
/// chosen at construction.
#[derive(Debug)]
enum Slab {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
}

/// Fixed-block-size pool with a free list.
#[derive(Debug)]
pub struct StatePool {
    block_elems: usize,
    storage: Slab,
    free: Vec<usize>,
    allocated: Vec<bool>,
    /// Owners per block (0 when free; `alloc` starts at 1). A count > 1
    /// marks the block shared and therefore immutable (see module docs).
    refcount: Vec<u32>,
    peak_blocks: usize,
}

impl StatePool {
    /// `block_elems` = d_k * d_v; `capacity` = max simultaneous blocks.
    /// Stores at f32 — see [`StatePool::with_precision`] for bf16.
    pub fn new(block_elems: usize, capacity: usize) -> StatePool {
        StatePool::with_precision(block_elems, capacity, Precision::F32)
    }

    /// A pool whose slab stores blocks at `precision`.
    pub fn with_precision(block_elems: usize, capacity: usize, precision: Precision) -> StatePool {
        StatePool {
            block_elems,
            storage: match precision {
                Precision::F32 => Slab::F32(vec![0.0; block_elems * capacity]),
                Precision::Bf16 => Slab::Bf16(vec![0u16; block_elems * capacity]),
            },
            free: (0..capacity).rev().collect(),
            allocated: vec![false; capacity],
            refcount: vec![0; capacity],
            peak_blocks: 0,
        }
    }

    /// The slab's storage precision.
    pub fn precision(&self) -> Precision {
        match self.storage {
            Slab::F32(_) => Precision::F32,
            Slab::Bf16(_) => Precision::Bf16,
        }
    }

    /// Resident bytes one block occupies in the slab (the
    /// `state_bytes_per_seq` bench headline sums this over live blocks).
    pub fn bytes_per_block(&self) -> usize {
        self.block_elems * self.precision().bytes_per_elem()
    }

    pub fn capacity(&self) -> usize {
        self.allocated.len()
    }

    pub fn in_use(&self) -> usize {
        self.capacity() - self.free.len()
    }

    pub fn peak(&self) -> usize {
        self.peak_blocks
    }

    /// Blocks still allocatable before the pool is exhausted.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Elements per block (d_k · d_v).
    pub fn block_elems(&self) -> usize {
        self.block_elems
    }

    /// Append `extra` zeroed blocks to the pool (capacity planning; the
    /// serving path prefers admission backpressure over growth so resident
    /// memory stays bounded, but offline drivers can expand freely).
    /// Existing [`BlockId`]s remain valid.
    pub fn grow(&mut self, extra: usize) {
        let old = self.capacity();
        match &mut self.storage {
            Slab::F32(s) => s.resize((old + extra) * self.block_elems, 0.0),
            Slab::Bf16(s) => s.resize((old + extra) * self.block_elems, 0u16),
        }
        self.allocated.resize(old + extra, false);
        self.refcount.resize(old + extra, 0);
        for idx in (old..old + extra).rev() {
            self.free.push(idx);
        }
    }

    /// Allocate a zeroed block; None if the pool is exhausted
    /// (backpressure signal for the batcher). The caller is the sole
    /// owner (refcount 1). The zero-fill here is the only scrub a block
    /// ever gets — see the freed-block contract in the module docs.
    // xtask: deny_alloc
    pub fn alloc(&mut self) -> Option<BlockId> {
        let idx = self.free.pop()?;
        debug_assert!(!self.allocated[idx]);
        self.allocated[idx] = true;
        self.refcount[idx] = 1;
        let s = idx * self.block_elems;
        match &mut self.storage {
            Slab::F32(slab) => slab[s..s + self.block_elems].fill(0.0),
            Slab::Bf16(slab) => slab[s..s + self.block_elems].fill(0u16),
        }
        self.peak_blocks = self.peak_blocks.max(self.in_use());
        Some(BlockId(idx))
    }

    /// Add an owner to a live block (prefix-cache insertion, shared
    /// admission). Every `retain` must be paired with a later
    /// [`StatePool::release`].
    // xtask: deny_alloc
    pub fn retain(&mut self, id: BlockId) {
        assert!(self.allocated[id.0], "retain of freed block {}", id.0);
        self.refcount[id.0] += 1;
    }

    /// Drop one ownership of a block; the block returns to the free list
    /// only when the last owner releases. Panics on double-free (more
    /// releases than `alloc` + `retain`s).
    // xtask: deny_alloc
    pub fn release(&mut self, id: BlockId) {
        assert!(self.allocated[id.0], "double free of block {}", id.0);
        self.refcount[id.0] -= 1;
        if self.refcount[id.0] == 0 {
            self.allocated[id.0] = false;
            self.free.push(id.0);
        }
    }

    /// Current owner count of a live block.
    pub fn ref_count(&self, id: BlockId) -> u32 {
        assert!(self.allocated[id.0], "use after free");
        self.refcount[id.0]
    }

    /// More than one owner ⇒ the block is immutable and writers must
    /// copy first (see module docs).
    pub fn is_shared(&self, id: BlockId) -> bool {
        self.ref_count(id) > 1
    }

    /// Bitwise copy of `src` into a freshly allocated block — THE
    /// copy-on-write step. `None` on exhaustion (clean backpressure, no
    /// mutation). `src` keeps its owners; the caller owns the clone.
    pub fn clone_block(&mut self, src: BlockId) -> Option<BlockId> {
        assert!(self.allocated[src.0], "clone of freed block {}", src.0);
        let dst = self.alloc()?;
        debug_assert_ne!(dst.0, src.0);
        let (d, s) = (dst.0 * self.block_elems, src.0 * self.block_elems);
        match &mut self.storage {
            Slab::F32(slab) => slab.copy_within(s..s + self.block_elems, d),
            Slab::Bf16(slab) => slab.copy_within(s..s + self.block_elems, d),
        }
        Some(dst)
    }

    #[inline]
    fn check_live(&self, id: BlockId) {
        assert!(self.allocated[id.0], "use after free");
        debug_assert!(
            self.refcount[id.0] > 0,
            "read of live block {} with zero refcount (accounting drift)",
            id.0
        );
    }

    // xtask: deny_alloc
    pub fn get(&self, id: BlockId) -> &[f32] {
        self.check_live(id);
        let s = id.0 * self.block_elems;
        match &self.storage {
            Slab::F32(slab) => &slab[s..s + self.block_elems],
            Slab::Bf16(_) => panic!("StatePool::get on a bf16 pool — use get_bf16/read_block_into"),
        }
    }

    // xtask: deny_alloc
    pub fn get_mut(&mut self, id: BlockId) -> &mut [f32] {
        self.check_live(id);
        assert!(
            self.refcount[id.0] == 1,
            "write to shared block {} (copy-on-write violation)",
            id.0
        );
        let s = id.0 * self.block_elems;
        match &mut self.storage {
            Slab::F32(slab) => &mut slab[s..s + self.block_elems],
            Slab::Bf16(_) => {
                panic!("StatePool::get_mut on a bf16 pool — use get_bf16_mut/write_block_from")
            }
        }
    }

    /// bf16-mode read access to a block's raw bf16 bits (widen with
    /// [`crate::tensor::half`]; the fused read path feeds them to
    /// `tensor::matvec_t_acc_slice_bf16` directly).
    // xtask: deny_alloc
    pub fn get_bf16(&self, id: BlockId) -> &[u16] {
        self.check_live(id);
        let s = id.0 * self.block_elems;
        match &self.storage {
            Slab::Bf16(slab) => &slab[s..s + self.block_elems],
            Slab::F32(_) => panic!("StatePool::get_bf16 on an f32 pool — use get"),
        }
    }

    /// bf16-mode write access; same copy-on-write contract as
    /// [`StatePool::get_mut`].
    // xtask: deny_alloc
    pub fn get_bf16_mut(&mut self, id: BlockId) -> &mut [u16] {
        self.check_live(id);
        assert!(
            self.refcount[id.0] == 1,
            "write to shared block {} (copy-on-write violation)",
            id.0
        );
        let s = id.0 * self.block_elems;
        match &mut self.storage {
            Slab::Bf16(slab) => &mut slab[s..s + self.block_elems],
            Slab::F32(_) => panic!("StatePool::get_bf16_mut on an f32 pool — use get_mut"),
        }
    }

    /// Precision-transparent block read: widen (bf16, exact) or copy
    /// (f32) the block into `out`. The seam the boundary-import and
    /// oracle-export paths use so they never match on precision.
    // xtask: deny_alloc
    pub fn read_block_into(&self, id: BlockId, out: &mut [f32]) {
        self.check_live(id);
        assert_eq!(out.len(), self.block_elems);
        let s = id.0 * self.block_elems;
        match &self.storage {
            Slab::F32(slab) => out.copy_from_slice(&slab[s..s + self.block_elems]),
            Slab::Bf16(slab) => crate::tensor::half::widen_into(&slab[s..s + self.block_elems], out),
        }
    }

    /// Precision-transparent block write: copy (f32) or narrow (bf16,
    /// RNE) `src` into the block. Copy-on-write contract as
    /// [`StatePool::get_mut`].
    // xtask: deny_alloc
    pub fn write_block_from(&mut self, id: BlockId, src: &[f32]) {
        self.check_live(id);
        assert!(
            self.refcount[id.0] == 1,
            "write to shared block {} (copy-on-write violation)",
            id.0
        );
        assert_eq!(src.len(), self.block_elems);
        let s = id.0 * self.block_elems;
        match &mut self.storage {
            Slab::F32(slab) => slab[s..s + self.block_elems].copy_from_slice(src),
            Slab::Bf16(slab) => crate::tensor::half::narrow_into(src, &mut slab[s..s + self.block_elems]),
        }
    }

    /// The raw f32 slab, for batched passes that partition work across
    /// many *allocated* blocks in one dispatch
    /// ([`crate::tensor::slab_block_dispatch`], driven by
    /// `state::batched_advance`). Callers must touch only ranges of
    /// blocks they hold live [`BlockId`]s for. Panics on a bf16 pool
    /// (use [`StatePool::slab_bf16_mut`]).
    pub(crate) fn slab_mut(&mut self) -> &mut [f32] {
        match &mut self.storage {
            Slab::F32(slab) => slab,
            Slab::Bf16(_) => panic!("StatePool::slab_mut on a bf16 pool — use slab_bf16_mut"),
        }
    }

    /// bf16 twin of [`StatePool::slab_mut`].
    pub(crate) fn slab_bf16_mut(&mut self) -> &mut [u16] {
        match &mut self.storage {
            Slab::Bf16(slab) => slab,
            Slab::F32(_) => panic!("StatePool::slab_bf16_mut on an f32 pool — use slab_mut"),
        }
    }

    /// Is this block currently allocated? (validation hook for the
    /// batched passes that bypass [`StatePool::get_mut`]).
    pub(crate) fn is_allocated(&self, id: BlockId) -> bool {
        self.allocated[id.0]
    }

    /// `dst += scale * src` across two blocks (bucket merge). `dst` must
    /// be solely owned (copy-on-write contract); `src` may be shared.
    /// In bf16 mode both operands widen, the multiply-add runs at f32,
    /// and only the stored result narrows (one rounding per element).
    // xtask: deny_alloc
    pub fn axpy(&mut self, dst: BlockId, src: BlockId, scale: f32) {
        assert!(self.allocated[dst.0] && self.allocated[src.0]);
        assert!(
            self.refcount[dst.0] == 1,
            "axpy into shared block {} (copy-on-write violation)",
            dst.0
        );
        assert_ne!(dst.0, src.0);
        let be = self.block_elems;
        let (d, s) = (dst.0 * be, src.0 * be);
        match &mut self.storage {
            Slab::F32(slab) => {
                // disjoint ranges: split_at_mut
                if d < s {
                    let (a, b) = slab.split_at_mut(s);
                    let dsl = &mut a[d..d + be];
                    let ssl = &b[..be];
                    for (x, &y) in dsl.iter_mut().zip(ssl) {
                        *x += scale * y;
                    }
                } else {
                    let (a, b) = slab.split_at_mut(d);
                    let ssl = &a[s..s + be];
                    let dsl = &mut b[..be];
                    for (x, &y) in dsl.iter_mut().zip(ssl) {
                        *x += scale * y;
                    }
                }
            }
            Slab::Bf16(slab) => {
                if d < s {
                    let (a, b) = slab.split_at_mut(s);
                    let dsl = &mut a[d..d + be];
                    let ssl = &b[..be];
                    for (x, &y) in dsl.iter_mut().zip(ssl) {
                        *x = f32_to_bf16(bf16_to_f32(*x) + scale * bf16_to_f32(y));
                    }
                } else {
                    let (a, b) = slab.split_at_mut(d);
                    let ssl = &a[s..s + be];
                    let dsl = &mut b[..be];
                    for (x, &y) in dsl.iter_mut().zip(ssl) {
                        *x = f32_to_bf16(bf16_to_f32(*x) + scale * bf16_to_f32(y));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, UsizeIn};
    use crate::util::Rng;

    #[test]
    fn alloc_release_cycle() {
        let mut pool = StatePool::new(16, 4);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!(pool.in_use(), 2);
        pool.get_mut(a)[0] = 1.0;
        pool.get_mut(b)[0] = 2.0;
        pool.axpy(a, b, 3.0);
        assert_eq!(pool.get(a)[0], 7.0);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut pool = StatePool::new(4, 2);
        let _a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        assert!(pool.alloc().is_none());
    }

    #[test]
    fn freshly_allocated_blocks_are_zeroed() {
        let mut pool = StatePool::new(8, 2);
        let a = pool.alloc().unwrap();
        pool.get_mut(a).fill(9.0);
        pool.release(a);
        let b = pool.alloc().unwrap();
        assert!(pool.get(b).iter().all(|&x| x == 0.0));
    }

    /// The freed-block-content contract (module docs): nothing scrubs on
    /// release, so `alloc`'s zero-fill is the only thing standing between
    /// a new owner and the previous owner's bits. Poison blocks with
    /// payloads whose *bit patterns* would survive a sloppy "write only
    /// what you need" reuse — subnormals, `-0.0` (all-zero except the
    /// sign bit) — then check every realloc, in both precisions and
    /// across `grow`, comes back all-bits-zero.
    #[test]
    fn freed_blocks_never_leak_stale_bits() {
        for precision in [Precision::F32, Precision::Bf16] {
            let mut pool = StatePool::with_precision(4, 2, precision);
            let poison = [2.5e-40f32, -0.0, f32::MIN_POSITIVE, -1.0e-39];
            let a = pool.alloc().unwrap();
            pool.write_block_from(a, &poison);
            let b = pool.alloc().unwrap();
            pool.write_block_from(b, &poison);
            pool.release(a);
            pool.release(b);
            // realloc from the free list: must observe pure zeros (bitwise
            // — a resurrected -0.0 sign bit is a failure even though
            // -0.0 == 0.0 numerically)
            let c = pool.alloc().unwrap();
            let mut out = [1.0f32; 4];
            pool.read_block_into(c, &mut out);
            assert!(out.iter().all(|x| x.to_bits() == 0), "stale bits after realloc ({precision:?})");
            // and across grow: old freed blocks keep the same contract
            pool.grow(2);
            let ids: Vec<_> = (0..3).map(|_| pool.alloc().unwrap()).collect();
            for id in &ids {
                pool.read_block_into(*id, &mut out);
                assert!(
                    out.iter().all(|x| x.to_bits() == 0),
                    "stale bits after grow ({precision:?})"
                );
            }
        }
    }

    #[test]
    fn bf16_pool_round_trips_through_narrowing() {
        let mut pool = StatePool::with_precision(4, 2, Precision::Bf16);
        assert_eq!(pool.precision(), Precision::Bf16);
        assert_eq!(pool.bytes_per_block(), 8); // 4 elems × 2 bytes
        let a = pool.alloc().unwrap();
        // exactly-representable values round-trip bit-exact
        let exact = [1.5f32, -0.0, 2.0, -0.625];
        pool.write_block_from(a, &exact);
        let mut out = [0f32; 4];
        pool.read_block_into(a, &mut out);
        for (o, w) in out.iter().zip(exact.iter()) {
            assert_eq!(o.to_bits(), w.to_bits());
        }
        // a non-representable value lands within one unit roundoff
        pool.write_block_from(a, &[1.001, 0.0, 0.0, 0.0]);
        pool.read_block_into(a, &mut out);
        assert!((out[0] - 1.001).abs() / 1.001 <= crate::tensor::half::BF16_UNIT_ROUNDOFF);
        // axpy widens, accumulates at f32, narrows once
        let b = pool.alloc().unwrap();
        pool.write_block_from(b, &[2.0, 4.0, -8.0, 0.5]);
        pool.write_block_from(a, &[1.0, 1.0, 1.0, 1.0]);
        pool.axpy(a, b, 0.5);
        pool.read_block_into(a, &mut out);
        assert_eq!(out, [2.0, 3.0, -3.0, 1.25]);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    #[should_panic(expected = "bf16 pool")]
    fn f32_accessor_on_bf16_pool_panics() {
        let mut pool = StatePool::with_precision(4, 1, Precision::Bf16);
        let a = pool.alloc().unwrap();
        let _ = pool.get(a);
    }

    #[test]
    fn grow_extends_capacity_and_keeps_blocks_valid() {
        let mut pool = StatePool::new(4, 1);
        let a = pool.alloc().unwrap();
        pool.get_mut(a)[0] = 5.0;
        assert!(pool.alloc().is_none());
        pool.grow(2);
        assert_eq!(pool.capacity(), 3);
        assert_eq!(pool.available(), 2);
        let b = pool.alloc().unwrap();
        assert!(pool.get(b).iter().all(|&x| x == 0.0));
        assert_eq!(pool.get(a)[0], 5.0, "grow must not move existing blocks' data");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut pool = StatePool::new(4, 2);
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }

    #[test]
    fn retain_defers_free_until_last_release() {
        let mut pool = StatePool::new(4, 2);
        let a = pool.alloc().unwrap();
        pool.get_mut(a)[0] = 3.0;
        pool.retain(a);
        assert_eq!(pool.ref_count(a), 2);
        assert!(pool.is_shared(a));
        pool.release(a); // one owner left; block stays live
        assert_eq!(pool.in_use(), 1);
        assert_eq!(pool.get(a)[0], 3.0);
        assert!(!pool.is_shared(a));
        pool.release(a);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn clone_block_is_a_bitwise_copy_with_private_ownership() {
        let mut pool = StatePool::new(4, 3);
        let a = pool.alloc().unwrap();
        pool.get_mut(a).copy_from_slice(&[1.5, -0.0, 2.5e-40, f32::MIN_POSITIVE]);
        pool.retain(a); // a is now shared (cache + sequence)
        let b = pool.clone_block(a).unwrap();
        assert_eq!(
            pool.get(a)
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<_>>(),
            pool.get(b).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "clone must be bit-identical"
        );
        assert_eq!(pool.ref_count(b), 1, "clone is privately owned");
        pool.get_mut(b)[0] = 9.0; // writable: sole owner
        assert_eq!(pool.get(a)[0], 1.5, "source untouched by writes to the clone");
        pool.release(a);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn clone_block_is_bitwise_in_bf16_mode_too() {
        let mut pool = StatePool::with_precision(4, 3, Precision::Bf16);
        let a = pool.alloc().unwrap();
        pool.get_bf16_mut(a).copy_from_slice(&[0x3FC0, 0x8000, 0x0001, 0x7F7F]);
        let b = pool.clone_block(a).unwrap();
        assert_eq!(pool.get_bf16(a), pool.get_bf16(b), "bf16 clone must be bit-identical");
        pool.get_bf16_mut(b)[0] = 0x4000;
        assert_eq!(pool.get_bf16(a)[0], 0x3FC0, "source untouched by writes to the clone");
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    #[should_panic(expected = "copy-on-write violation")]
    fn writing_a_shared_block_panics() {
        let mut pool = StatePool::new(4, 2);
        let a = pool.alloc().unwrap();
        pool.retain(a);
        pool.get_mut(a)[0] = 1.0;
    }

    #[test]
    #[should_panic(expected = "copy-on-write violation")]
    fn axpy_into_a_shared_block_panics() {
        let mut pool = StatePool::new(4, 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        pool.retain(a);
        pool.axpy(a, b, 1.0);
    }

    #[test]
    #[should_panic(expected = "copy-on-write violation")]
    fn write_block_from_into_a_shared_block_panics() {
        let mut pool = StatePool::with_precision(4, 2, Precision::Bf16);
        let a = pool.alloc().unwrap();
        pool.retain(a);
        pool.write_block_from(a, &[1.0; 4]);
    }

    #[test]
    fn random_retain_release_cow_traces_never_leak_property() {
        // The refcounted mirror of `random_workload_never_leaks_property`:
        // random alloc / retain / release / clone-on-write traces, with a
        // shadow refcount model. Invariants: in_use equals the number of
        // blocks with a live shadow count, no block is reused while any
        // owner remains (contents survive until the last release), and
        // everything drains to zero.
        check("pool refcount no-leak", 50, &UsizeIn(1, 500), |&seed| {
            let mut rng = Rng::new(seed as u64 ^ 0xC0DE);
            let mut pool = StatePool::new(4, 24);
            // (id, shadow_refcount, tag) — tag written at alloc, must
            // survive while any owner remains
            let mut live: Vec<(BlockId, u32, f32)> = Vec::new();
            let mut next_tag = 1.0f32;
            for _ in 0..300 {
                match rng.below(4) {
                    0 => {
                        if let Some(id) = pool.alloc() {
                            let tag = next_tag;
                            next_tag += 1.0;
                            pool.get_mut(id)[0] = tag;
                            live.push((id, 1, tag));
                        }
                    }
                    1 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        pool.retain(live[i].0);
                        live[i].1 += 1;
                    }
                    2 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        pool.release(live[i].0);
                        live[i].1 -= 1;
                        if live[i].1 == 0 {
                            live.swap_remove(i);
                        }
                    }
                    _ if !live.is_empty() => {
                        // copy-on-write: writers of shared blocks clone
                        // first; sole owners may write in place
                        let i = rng.below(live.len());
                        let (id, rc, tag) = live[i];
                        if rc > 1 {
                            if let Some(c) = pool.clone_block(id) {
                                if pool.get(c)[0] != tag {
                                    return false;
                                }
                                let tag2 = next_tag;
                                next_tag += 1.0;
                                pool.get_mut(c)[0] = tag2;
                                pool.release(id);
                                live[i].1 -= 1;
                                live.push((c, 1, tag2));
                            }
                        } else {
                            let tag2 = next_tag;
                            next_tag += 1.0;
                            pool.get_mut(id)[0] = tag2;
                            live[i].2 = tag2;
                        }
                    }
                    _ => {}
                }
                if pool.in_use() != live.len() {
                    return false;
                }
                // no premature reuse: every owned block still holds its tag
                if live.iter().any(|&(id, _, tag)| pool.get(id)[0] != tag) {
                    return false;
                }
            }
            for (id, rc, _) in live.drain(..) {
                for _ in 0..rc {
                    pool.release(id);
                }
            }
            pool.in_use() == 0 && pool.peak() <= 24
        });
    }

    #[test]
    fn random_workload_never_leaks_property() {
        // Random alloc/release traces: allocated count always equals
        // in_use, and everything released is reusable.
        check("pool no-leak", 50, &UsizeIn(1, 500), |&seed| {
            let mut rng = Rng::new(seed as u64);
            let mut pool = StatePool::new(4, 32);
            let mut live: Vec<BlockId> = Vec::new();
            for _ in 0..200 {
                if !live.is_empty() && rng.chance(0.45) {
                    let i = rng.below(live.len());
                    let id = live.swap_remove(i);
                    pool.release(id);
                } else if let Some(id) = pool.alloc() {
                    live.push(id);
                }
                if pool.in_use() != live.len() {
                    return false;
                }
            }
            for id in live.drain(..) {
                pool.release(id);
            }
            pool.in_use() == 0 && pool.peak() <= 32
        });
    }
}
