//! A slab/free-list pool of fixed-size state buffers for batched serving.
//!
//! Sequences in the decode server each hold `popcount(t)+1` live level
//! states; the pool recycles (d_k × d_v) blocks across sequences so the
//! server's memory footprint follows the *sum of live states*, analogous
//! to how paged KV-cache allocators (vLLM) track used pages rather than
//! max context. Invariants (no leak, no double-free, no use-after-free)
//! are property-tested below.
//!
//! This is the storage layer of the pooled decode path:
//! [`crate::state::pooled::PooledFenwickState`] keeps its live level
//! states as [`BlockId`]s here, and
//! [`crate::state::pooled::BatchedDecoder`] reads all live blocks across
//! a whole decode batch straight out of the contiguous `storage` slab —
//! one λ-weighted block-sparse GEMM over `(Σ live, d_k·d_v)` resident
//! floats instead of `Σ_i popcount(t_i)` scattered matvecs. Exhaustion is
//! a *backpressure signal*: [`StatePool::alloc`] returns `None` and the
//! serving coordinator defers admission (see
//! `coordinator::backend::PooledBackend`) rather than growing
//! unboundedly; capacity planning can use [`StatePool::grow`] and the
//! [`StatePool::peak`] accounting.

/// Handle to one pooled block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockId(pub usize);

/// Fixed-block-size pool with a free list.
#[derive(Debug)]
pub struct StatePool {
    block_elems: usize,
    storage: Vec<f32>,
    free: Vec<usize>,
    allocated: Vec<bool>,
    peak_blocks: usize,
}

impl StatePool {
    /// `block_elems` = d_k * d_v; `capacity` = max simultaneous blocks.
    pub fn new(block_elems: usize, capacity: usize) -> StatePool {
        StatePool {
            block_elems,
            storage: vec![0.0; block_elems * capacity],
            free: (0..capacity).rev().collect(),
            allocated: vec![false; capacity],
            peak_blocks: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.allocated.len()
    }

    pub fn in_use(&self) -> usize {
        self.capacity() - self.free.len()
    }

    pub fn peak(&self) -> usize {
        self.peak_blocks
    }

    /// Blocks still allocatable before the pool is exhausted.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Elements per block (d_k · d_v).
    pub fn block_elems(&self) -> usize {
        self.block_elems
    }

    /// Append `extra` zeroed blocks to the pool (capacity planning; the
    /// serving path prefers admission backpressure over growth so resident
    /// memory stays bounded, but offline drivers can expand freely).
    /// Existing [`BlockId`]s remain valid.
    pub fn grow(&mut self, extra: usize) {
        let old = self.capacity();
        self.storage.resize((old + extra) * self.block_elems, 0.0);
        self.allocated.resize(old + extra, false);
        for idx in (old..old + extra).rev() {
            self.free.push(idx);
        }
    }

    /// Allocate a zeroed block; None if the pool is exhausted
    /// (backpressure signal for the batcher).
    pub fn alloc(&mut self) -> Option<BlockId> {
        let idx = self.free.pop()?;
        debug_assert!(!self.allocated[idx]);
        self.allocated[idx] = true;
        let s = idx * self.block_elems;
        self.storage[s..s + self.block_elems].fill(0.0);
        self.peak_blocks = self.peak_blocks.max(self.in_use());
        Some(BlockId(idx))
    }

    /// Release a block back to the free list. Panics on double-free.
    pub fn release(&mut self, id: BlockId) {
        assert!(self.allocated[id.0], "double free of block {}", id.0);
        self.allocated[id.0] = false;
        self.free.push(id.0);
    }

    pub fn get(&self, id: BlockId) -> &[f32] {
        assert!(self.allocated[id.0], "use after free");
        let s = id.0 * self.block_elems;
        &self.storage[s..s + self.block_elems]
    }

    pub fn get_mut(&mut self, id: BlockId) -> &mut [f32] {
        assert!(self.allocated[id.0], "use after free");
        let s = id.0 * self.block_elems;
        &mut self.storage[s..s + self.block_elems]
    }

    /// The raw slab, for batched passes that partition work across many
    /// *allocated* blocks in one dispatch
    /// ([`crate::tensor::slab_block_dispatch`], driven by
    /// `state::batched_advance`). Callers must touch only ranges of
    /// blocks they hold live [`BlockId`]s for.
    pub(crate) fn slab_mut(&mut self) -> &mut [f32] {
        &mut self.storage
    }

    /// Is this block currently allocated? (validation hook for the
    /// batched passes that bypass [`StatePool::get_mut`]).
    pub(crate) fn is_allocated(&self, id: BlockId) -> bool {
        self.allocated[id.0]
    }

    /// `dst += scale * src` across two blocks (bucket merge).
    pub fn axpy(&mut self, dst: BlockId, src: BlockId, scale: f32) {
        assert!(self.allocated[dst.0] && self.allocated[src.0]);
        assert_ne!(dst.0, src.0);
        let (d, s) = (dst.0 * self.block_elems, src.0 * self.block_elems);
        // disjoint ranges: split_at_mut
        if d < s {
            let (a, b) = self.storage.split_at_mut(s);
            let dsl = &mut a[d..d + self.block_elems];
            let ssl = &b[..self.block_elems];
            for (x, &y) in dsl.iter_mut().zip(ssl) {
                *x += scale * y;
            }
        } else {
            let (a, b) = self.storage.split_at_mut(d);
            let ssl = &a[s..s + self.block_elems];
            let dsl = &mut b[..self.block_elems];
            for (x, &y) in dsl.iter_mut().zip(ssl) {
                *x += scale * y;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, UsizeIn};
    use crate::util::Rng;

    #[test]
    fn alloc_release_cycle() {
        let mut pool = StatePool::new(16, 4);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!(pool.in_use(), 2);
        pool.get_mut(a)[0] = 1.0;
        pool.get_mut(b)[0] = 2.0;
        pool.axpy(a, b, 3.0);
        assert_eq!(pool.get(a)[0], 7.0);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut pool = StatePool::new(4, 2);
        let _a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        assert!(pool.alloc().is_none());
    }

    #[test]
    fn freshly_allocated_blocks_are_zeroed() {
        let mut pool = StatePool::new(8, 2);
        let a = pool.alloc().unwrap();
        pool.get_mut(a).fill(9.0);
        pool.release(a);
        let b = pool.alloc().unwrap();
        assert!(pool.get(b).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn grow_extends_capacity_and_keeps_blocks_valid() {
        let mut pool = StatePool::new(4, 1);
        let a = pool.alloc().unwrap();
        pool.get_mut(a)[0] = 5.0;
        assert!(pool.alloc().is_none());
        pool.grow(2);
        assert_eq!(pool.capacity(), 3);
        assert_eq!(pool.available(), 2);
        let b = pool.alloc().unwrap();
        assert!(pool.get(b).iter().all(|&x| x == 0.0));
        assert_eq!(pool.get(a)[0], 5.0, "grow must not move existing blocks' data");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut pool = StatePool::new(4, 2);
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }

    #[test]
    fn random_workload_never_leaks_property() {
        // Random alloc/release traces: allocated count always equals
        // in_use, and everything released is reusable.
        check("pool no-leak", 50, &UsizeIn(1, 500), |&seed| {
            let mut rng = Rng::new(seed as u64);
            let mut pool = StatePool::new(4, 32);
            let mut live: Vec<BlockId> = Vec::new();
            for _ in 0..200 {
                if !live.is_empty() && rng.chance(0.45) {
                    let i = rng.below(live.len());
                    let id = live.swap_remove(i);
                    pool.release(id);
                } else if let Some(id) = pool.alloc() {
                    live.push(id);
                }
                if pool.in_use() != live.len() {
                    return false;
                }
            }
            for id in live.drain(..) {
                pool.release(id);
            }
            pool.in_use() == 0 && pool.peak() <= 32
        });
    }
}
