//! Decode-time state management (paper §3.2 + App. B.4).
//!
//! [`FenwickState`] is the token-granularity O(log T) state machine: at
//! step `t` the buckets `0..=lssb(t)` merge one level up, every surviving
//! state passes through the model's transition, and the fresh (k, v) pair
//! enters at level 0. Only `popcount(t)+1` of the `O(log T)` slots are
//! ever live — [`StatePool`] exploits exactly that for batched serving,
//! handing out fixed-size (d_k × d_v) buffers from a free list so a
//! sequence's resident memory tracks its live-state count, not the level
//! capacity.
//!
//! The step loop is allocation-free in steady state: merged-out level
//! buffers go to an internal free list and are recycled for the next
//! sentinel write, and the per-level read is the fused
//! [`crate::attention::loglinear::level_read_acc`] accumulate (the
//! decode-time analogue of the chunkwise engine's batched `Q @ S_cat`
//! read — for a single query the batch degenerates to one fused pass per
//! live level, no temporaries). The serving-side lift of that read —
//! every live level of every sequence in a decode batch folded into one
//! block-sparse GEMM over pooled storage — lives in [`pooled`]
//! ([`PooledFenwickState`] + [`BatchedDecoder`]), bit-exact with
//! [`FenwickState`] by sharing the same primitive in the same order. The
//! matching serving-side lift of the *update* — every sequence's merge,
//! transition, and sentinel write grouped by Fenwick level and executed
//! as scattered-slab dispatches — is [`batched_advance`]
//! ([`BatchedAdvance`]), bit-exact with the per-sequence
//! [`update::advance_levels`] skeleton by sharing its per-block
//! primitives. Position/head-dependent gate schedules live in [`gates`]
//! ([`GateTable`]). Cross-request sharing of chunk-boundary states —
//! refcounted pool blocks + copy-on-write advances + a radix tree over
//! token-id prefixes — lives in [`prefix_cache`] ([`PrefixCache`]). The
//! pool split into per-worker shards — sequences pinned to one shard at
//! admission so disjoint shards advance and read concurrently without
//! synchronizing on state — is [`sharded`] ([`ShardedStatePool`]; see
//! docs/SHARDING.md for the pinning rules and determinism argument).
//!
//! The same machinery measured against a softmax KV cache is experiment
//! E11 (decode time/memory vs. T — Table 1's right columns).

pub mod batched_advance;
pub mod gates;
pub mod pool;
pub mod pooled;
pub mod prefix_cache;
pub mod sharded;
pub(crate) mod update;

pub use batched_advance::{AdvanceJob, BatchedAdvance};
pub use gates::GateTable;
pub use pooled::{BatchedDecoder, PooledFenwickState};
pub use prefix_cache::PrefixCache;
pub use sharded::ShardedStatePool;

use crate::tensor::Mat;

/// λ weight for level `l`, clamping to the last table entry when a
/// sequence outgrows its λ table (`T > 2^lambda_width` makes levels live
/// beyond the table width). The old `unwrap_or(0.0)` silently *dropped*
/// the coarsest-level reads past that point; clamping keeps the distant
/// context contributing with the coarsest provided weight. Shared by
/// [`FenwickState`] and [`pooled::PooledFenwickState`] so both decode
/// paths agree bit-for-bit.
#[inline]
pub fn level_weight(lambda: &[f32], l: usize) -> f32 {
    match lambda.get(l) {
        Some(&w) => w,
        None => {
            debug_assert!(!lambda.is_empty(), "empty lambda table");
            lambda.last().copied().unwrap_or(0.0)
        }
    }
}

/// Transition applied to every live state at each step.
#[derive(Clone, Copy)]
pub enum Transition<'a> {
    /// Mamba-2 family: `S ← α S`.
    Decay(f32),
    /// (Gated) DeltaNet family: `S ← α (I − β k k^T) S`.
    GatedHouseholder { alpha: f32, beta: f32, k: &'a [f32] },
}

/// Which per-token state-transition *family* a serving model applies —
/// the model-level tag from which the per-step [`Transition`] values are
/// built (α/β drawn from a [`GateTable`], `k` from the token's key).
/// Shared by the chunkwise prefill stack ([`crate::prefill`]) and the
/// pooled decode backend (`coordinator::backend` re-exports this type),
/// so both serving paths dispatch on one tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransitionKind {
    /// Mamba-2 scalar decay: `S ← α S`, sentinel write scale 1.
    Mamba2,
    /// Gated DeltaNet: `S ← α (I − β k k^T) S`, sentinel write scale β
    /// (keys are L2-normalized so the Householder stays contractive).
    Gdn,
}

/// O(log T) Fenwick decode state for one sequence (one head).
#[derive(Debug, Clone)]
pub struct FenwickState {
    pub dk: usize,
    pub dv: usize,
    /// levels[l] = bucket state at level l (0 = sentinel)
    levels: Vec<Option<Mat>>,
    /// recycled (dk, dv) buffers from merged-out states
    free: Vec<Mat>,
    /// number of tokens processed so far
    pub t: usize,
}

impl FenwickState {
    pub fn new(dk: usize, dv: usize) -> FenwickState {
        FenwickState { dk, dv, levels: Vec::new(), free: Vec::new(), t: 0 }
    }

    /// Process one token: merge, transition, write, then read the output
    /// `o = Σ_l λ^(l) S^(l)T q` with per-level weights `lambda`.
    ///
    /// The merge/transition/write skeleton is the storage-generic
    /// [`update::advance_levels`] — the *same code* that drives
    /// [`pooled::PooledFenwickState::advance`], so the two decode paths
    /// are bit-identical by construction (the pooled bit-exactness test
    /// now guards the shared skeleton instead of a hand-mirrored copy).
    pub fn step(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        write_scale: f32,
        transition: Transition<'_>,
        lambda: &[f32],
    ) -> Vec<f32> {
        let mut store = update::MatStore { free: &mut self.free, dk: self.dk, dv: self.dv };
        update::advance_levels(&mut store, &mut self.levels, self.t, k, v, write_scale, transition)
            .expect("Mat-backed store never exhausts");
        // read: fused λ-weighted accumulate, no per-level temporaries
        let mut o = vec![0.0f32; self.dv];
        self.read_into(q, lambda, &mut o);
        self.t += 1;
        o
    }

    /// λ-weighted read `o = Σ_l λ^(l) S^(l)T q` without advancing the
    /// state (the per-sequence matvec loop — the baseline the pooled
    /// [`BatchedDecoder`] batches across sequences). Overwrites `out`.
    pub fn read_into(&self, q: &[f32], lambda: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dv);
        out.fill(0.0);
        for (l, s) in self.levels.iter().enumerate() {
            if let Some(s) = s {
                let lam = level_weight(lambda, l);
                if lam == 0.0 {
                    continue;
                }
                crate::attention::loglinear::level_read_acc(&s.data, self.dv, q, lam, out);
            }
        }
    }

    /// Number of live (non-empty) level states.
    pub fn live_states(&self) -> usize {
        self.levels.iter().filter(|s| s.is_some()).count()
    }

    /// Install an externally-built level layout — the Mat-backed mirror
    /// of [`pooled::PooledFenwickState::import_levels`], with the same
    /// validation: the sequence lands at the **post-merge boundary** of
    /// step `t` (sentinel empty, each `token_level ≥ 1` live in the
    /// Fenwick partition of `t`). Used by the per-sequence oracle replay
    /// of a chunkwise-prefilled serving sequence
    /// (`coordinator::backend::PooledOracle`): the prefill bridge exports
    /// the same engine states here instead of into pool blocks, so the
    /// oracle's decode trajectory is bit-identical to the pooled one.
    pub fn import_levels(dk: usize, dv: usize, t: usize, states: &[(usize, &[f32])]) -> FenwickState {
        let mut st = FenwickState::new(dk, dv);
        for &(level, data) in states {
            assert!(level >= 1, "level 0 is the sentinel; it is written by step");
            assert!(
                level <= usize::BITS as usize && (t >> (level - 1)) & 1 == 1,
                "level {level} is not live at position {t} (Fenwick misalignment)"
            );
            assert_eq!(data.len(), dk * dv, "state shape");
            if st.levels.len() <= level {
                st.levels.resize_with(level + 1, || None);
            }
            assert!(st.levels[level].is_none(), "duplicate level {level} in import");
            st.levels[level] = Some(Mat::from_vec(dk, dv, data.to_vec()));
        }
        st.t = t;
        st
    }

    /// Resident state bytes (the decode-memory metric of E11): live level
    /// states plus the recycled free-list buffers — everything the
    /// process actually holds for this sequence.
    pub fn state_bytes(&self) -> usize {
        (self.live_states() + self.free.len()) * self.dk * self.dv * 4
    }

    /// Level capacity currently allocated (≈ log2 t).
    pub fn level_capacity(&self) -> usize {
        self.levels.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{self, AttnInputs};
    use crate::util::Rng;

    #[test]
    fn replays_loglinear_mamba2_recurrent_oracle() {
        let mut rng = Rng::new(1);
        let t_len = 64;
        let x = AttnInputs::random(t_len, 8, 8, &mut rng);
        let oracle = attention::loglinear_mamba2::recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.lambda);
        let mut st = FenwickState::new(8, 8);
        for t in 0..t_len {
            let o = st.step(
                x.q.row(t),
                x.k.row(t),
                x.v.row(t),
                1.0,
                Transition::Decay(x.alpha[t]),
                x.lambda.row(t),
            );
            for j in 0..8 {
                assert!((o[j] - oracle.at(t, j)).abs() < 1e-4, "t={t} j={j}");
            }
        }
    }

    #[test]
    fn replays_loglinear_gdn_recurrent_oracle() {
        let mut rng = Rng::new(2);
        let t_len = 48;
        let x = AttnInputs::random(t_len, 8, 8, &mut rng);
        let oracle = attention::loglinear_gdn::recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda);
        let mut st = FenwickState::new(8, 8);
        for t in 0..t_len {
            let o = st.step(
                x.q.row(t),
                x.k.row(t),
                x.v.row(t),
                x.beta[t],
                Transition::GatedHouseholder { alpha: x.alpha[t], beta: x.beta[t], k: x.k.row(t) },
                x.lambda.row(t),
            );
            for j in 0..8 {
                assert!((o[j] - oracle.at(t, j)).abs() < 1e-4, "t={t} j={j}");
            }
        }
    }

    #[test]
    fn clamps_lambda_past_table_width_to_coarsest_level() {
        // T > 2^lambda_width: levels beyond the table must read with the
        // last provided weight (not silently drop). Oracle: the recurrent
        // form fed the clamp-extended full-width table.
        let mut rng = Rng::new(11);
        let t_len = 100; // live levels reach 7 > width
        let width = 4;
        let x = AttnInputs::random(t_len, 8, 8, &mut rng);
        let nl = crate::fenwick::num_levels(t_len);
        assert!(nl > width, "test must exceed the lambda table");
        let lam_trunc = Mat::from_fn(t_len, width, |t, l| x.lambda.at(t, l));
        let lam_ext = Mat::from_fn(t_len, nl, |t, l| x.lambda.at(t, l.min(width - 1)));
        let oracle =
            attention::loglinear_mamba2::recurrent(&x.q, &x.k, &x.v, &x.alpha, &lam_ext);
        let mut st = FenwickState::new(8, 8);
        for t in 0..t_len {
            let o = st.step(
                x.q.row(t),
                x.k.row(t),
                x.v.row(t),
                1.0,
                Transition::Decay(x.alpha[t]),
                lam_trunc.row(t),
            );
            for j in 0..8 {
                assert!((o[j] - oracle.at(t, j)).abs() < 1e-3, "t={t} j={j}");
            }
        }
    }

    #[test]
    fn live_state_count_is_popcount_plus_one() {
        let mut rng = Rng::new(3);
        let x = AttnInputs::random(300, 4, 4, &mut rng);
        let mut st = FenwickState::new(4, 4);
        for t in 0..300 {
            st.step(
                x.q.row(t), x.k.row(t), x.v.row(t), 1.0,
                Transition::Decay(x.alpha[t]), x.lambda.row(t.min(x.lambda.rows - 1)),
            );
            // after step t, the prefix [0, t] is partitioned -> popcount(t)+1
            assert_eq!(st.live_states(), (t).count_ones() as usize + 1, "t={t}");
        }
    }

    #[test]
    fn memory_grows_logarithmically() {
        let mut rng = Rng::new(4);
        let t_len = 1 << 12;
        let x = AttnInputs::random(64, 4, 4, &mut rng);
        let mut st = FenwickState::new(4, 4);
        let mut max_bytes = 0;
        for t in 0..t_len {
            let i = t % 64;
            st.step(
                x.q.row(i), x.k.row(i), x.v.row(i), 1.0,
                Transition::Decay(0.95), x.lambda.row(i),
            );
            max_bytes = max_bytes.max(st.state_bytes());
        }
        // <= (log2(T)+1) states of dk*dv*4 bytes
        let bound = (12 + 1) * 4 * 4 * 4;
        assert!(max_bytes <= bound, "{max_bytes} > {bound}");
    }
}
