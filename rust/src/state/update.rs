//! The single storage-generic Fenwick level update (ROADMAP item).
//!
//! [`FenwickState::step`](super::FenwickState::step) and
//! [`PooledFenwickState::advance`](super::pooled::PooledFenwickState::advance)
//! used to hand-mirror the same merge → transition → sentinel-write
//! skeleton, differing only in where level states live (owned [`Mat`]s
//! with a private free list vs [`StatePool`] blocks). That lock-step
//! contract was documented and enforced by a bit-exactness test, but any
//! edit still had to land twice. [`advance_levels`] is now the one copy of
//! the skeleton; the storage difference is a [`FenwickStore`] impl
//! ([`MatStore`] / [`PoolStore`]), and the bit-exactness of the two decode
//! paths is *by construction*: the same generic function drives the same
//! primitive op sequence (`axpy8`-based merges/writes, identical
//! transition loops) against either backing.
//!
//! The pooled path's backpressure semantics survive the unification:
//! [`FenwickStore::can_advance`] is checked **before any mutation**, so a
//! refused step leaves the sequence untouched (the admission-control
//! contract), and the Mat-backed store simply never refuses. The pooled
//! store additionally owns the **copy-on-write** step for prefix-cached
//! (shared) blocks — see [`AdvancePlan`] and
//! [`crate::state::pool`]'s module docs.

use crate::attention::deltanet::{apply_householder, apply_householder_slice};
use crate::fenwick;
use crate::state::pool::{BlockId, Precision, StatePool};
use crate::state::pooled::PoolExhausted;
use crate::state::Transition;
use crate::tensor::half::{bf16_to_f32, f32_to_bf16};
use crate::tensor::{self, Mat};

/// Apply `tr` to one row-major `(d_k, d_v)` state slice — THE per-token
/// transition primitive for slice-backed states, shared by the
/// per-sequence [`PoolStore`] and the pool-wide batched pass
/// ([`crate::state::batched_advance`]) so the two advance paths are
/// bit-exact by construction.
// xtask: deny_alloc
pub(crate) fn transition_block(s: &mut [f32], dv: usize, tr: &Transition<'_>) {
    match tr {
        Transition::Decay(a) => {
            for x in s.iter_mut() {
                *x *= *a;
            }
        }
        Transition::GatedHouseholder { alpha, beta, k } => {
            apply_householder_slice(s, dv, k, *beta);
            for x in s.iter_mut() {
                *x *= *alpha;
            }
        }
    }
}

/// Accumulate `write_scale · k v^T` into a (zeroed) row-major `(d_k, d_v)`
/// state slice — THE sentinel-write primitive, shared like
/// [`transition_block`].
// xtask: deny_alloc
pub(crate) fn write_block(s0: &mut [f32], dv: usize, k: &[f32], v: &[f32], write_scale: f32) {
    for (i, &ki) in k.iter().enumerate() {
        tensor::axpy8(&mut s0[i * dv..(i + 1) * dv], v, ki * write_scale);
    }
}

/// bf16-slab twin of [`transition_block`]: widen each stored element to
/// f32, run the transition arithmetic at f32, narrow the result once
/// (RNE). Shared by [`PoolStore`] and the batched slab pass exactly like
/// the f32 primitive, so the pooled and batched bf16 paths stay
/// bit-exact *with each other* (their divergence from the f32 oracle is
/// the tolerance-bounded narrowing only; docs/PRECISION.md).
// xtask: deny_alloc
pub(crate) fn transition_block_bf16(s: &mut [u16], dv: usize, tr: &Transition<'_>) {
    match tr {
        Transition::Decay(a) => {
            for x in s.iter_mut() {
                *x = f32_to_bf16(bf16_to_f32(*x) * *a);
            }
        }
        Transition::GatedHouseholder { alpha, beta, k } => {
            apply_householder_slice_bf16(s, dv, k, *beta);
            for x in s.iter_mut() {
                *x = f32_to_bf16(bf16_to_f32(*x) * *alpha);
            }
        }
    }
}

/// bf16 form of `attention::deltanet::apply_householder_slice`:
/// `S ← (I − β k k^T) S` with `k^T S` accumulated entirely at f32 (the
/// stored rows widen on the fly) and one narrowing per updated element.
/// Mirrors the f32 slice form's structure (scratch `k^T S` pass, then
/// per-row update with the same `β·k_i` zero-skip).
fn apply_householder_slice_bf16(s: &mut [u16], dv: usize, k: &[f32], beta: f32) {
    if beta == 0.0 {
        return;
    }
    debug_assert_eq!(s.len(), k.len() * dv);
    let mut kt_s = vec![0.0f32; dv];
    tensor::matvec_t_acc_slice_bf16(s, dv, k, 1.0, &mut kt_s);
    for (i, &ki) in k.iter().enumerate() {
        let scale = beta * ki;
        if scale == 0.0 {
            continue;
        }
        let row = &mut s[i * dv..(i + 1) * dv];
        for (r, &x) in row.iter_mut().zip(kt_s.iter()) {
            *r = f32_to_bf16(bf16_to_f32(*r) - scale * x);
        }
    }
}

/// bf16-slab twin of [`write_block`]: the outer product runs at f32 and
/// each freshly written element narrows once. `s0` must be zeroed (the
/// pool's alloc contract), so the accumulate degenerates to a store.
// xtask: deny_alloc
pub(crate) fn write_block_bf16(s0: &mut [u16], dv: usize, k: &[f32], v: &[f32], write_scale: f32) {
    for (i, &ki) in k.iter().enumerate() {
        let a = ki * write_scale;
        let row = &mut s0[i * dv..(i + 1) * dv];
        for (x, &vj) in row.iter_mut().zip(v.iter()) {
            *x = f32_to_bf16(bf16_to_f32(*x) + a * vj);
        }
    }
}

/// Refcount-aware block budget for one pooled sequence's advance at time
/// `t` — THE capacity-check formula, shared by [`advance_levels`]'s
/// pre-mutation check (via [`PoolStore::can_advance`]) and the batch-wide
/// admission simulation in [`crate::state::batched_advance`], so the "an
/// admission plan that succeeds sequentially succeeds batched" guarantee
/// holds by construction, not by two hand-synced copies.
///
/// The advance costs, in execution order:
/// 1. if the merge accumulator (lowest live level in `0..=lssb(t)`) is
///    shared, it is cloned **before** any merge source is released, so
///    one block must be available up front ([`AdvancePlan::clone_acc`]);
/// 2. each *privately owned* merge source returns a block when folded in
///    ([`AdvancePlan::freed_priv`]); shared sources merely drop a
///    refcount and free nothing;
/// 3. each shared level carried past the merge needs a private clone
///    before the in-place transition ([`AdvancePlan::carried_clones`]);
/// 4. the level-0 sentinel write takes one block.
///
/// Sharing can only *decrease* between planning and execution (nothing
/// retains mid-advance), so the plan is a conservative bound: a step it
/// admits always completes.
pub(crate) struct AdvancePlan {
    pub clone_acc: bool,
    pub freed_priv: usize,
    pub carried_clones: usize,
}

impl AdvancePlan {
    /// Can the advance run to completion with `available` free blocks?
    /// Two-phase check matching the execution order above: the acc clone
    /// precedes the merge frees; everything else follows them.
    pub fn feasible(&self, available: usize) -> bool {
        available >= self.clone_acc as usize
            && available + self.freed_priv - self.clone_acc as usize >= self.carried_clones + 1
    }

    /// Free-block delta once the advance completes (negative = consumed).
    pub fn net(&self) -> isize {
        self.freed_priv as isize - self.clone_acc as isize - self.carried_clones as isize - 1
    }
}

/// Compute the [`AdvancePlan`] for one pooled sequence (see there).
// xtask: deny_alloc
pub(crate) fn pool_advance_plan(
    pool: &StatePool,
    levels: &[Option<BlockId>],
    t: usize,
) -> AdvancePlan {
    let mut plan = AdvancePlan { clone_acc: false, freed_priv: 0, carried_clones: 0 };
    // merge range 0..=lssb(t), empty at t = 0
    let merge_hi = if t == 0 { 0 } else { fenwick::lssb(t) as usize + 1 };
    let mut acc_seen = false;
    for (lvl, slot) in levels.iter().enumerate() {
        let Some(id) = slot else { continue };
        let shared = pool.is_shared(*id);
        if lvl < merge_hi {
            if !acc_seen {
                acc_seen = true;
                plan.clone_acc = shared;
            } else if !shared {
                plan.freed_priv += 1;
            }
        } else if shared {
            plan.carried_clones += 1;
        }
    }
    plan
}

/// Storage backing for one sequence's Fenwick level states.
pub(crate) trait FenwickStore {
    type Slot;

    /// Can the full advance at time `t` (merge + copy-on-write clones +
    /// sentinel write) succeed against these levels? Checked before any
    /// mutation so a refusal is clean.
    fn can_advance(&self, levels: &[Option<Self::Slot>], t: usize) -> bool;

    /// Bucket merge: `acc += src`, then recycle `src`'s storage.
    fn merge(&mut self, acc: &mut Self::Slot, src: Self::Slot);

    /// Apply the per-token transition to one live state.
    fn transition(&mut self, slot: &mut Self::Slot, tr: &Transition<'_>);

    /// Fresh zeroed state holding `write_scale * k v^T`; `None` only if
    /// the backing is exhausted (never, after `can_write` returned true).
    fn write(&mut self, k: &[f32], v: &[f32], write_scale: f32) -> Option<Self::Slot>;
}

/// One token's state update — merge levels `0..=lssb(t)` one level up,
/// transition every carried state, write the fresh `(k, v)` sentinel at
/// level 0. `t` is the number of tokens processed so far. Fails (before
/// mutating anything) only if the store cannot supply the sentinel block.
// xtask: deny_alloc
pub(crate) fn advance_levels<S: FenwickStore>(
    store: &mut S,
    levels: &mut Vec<Option<S::Slot>>,
    t: usize,
    k: &[f32],
    v: &[f32],
    write_scale: f32,
    transition: Transition<'_>,
) -> Result<(), PoolExhausted> {
    // 0) capacity check first: merges free slots, copy-on-write clones
    //    and the sentinel write take them, so a refusal must come before
    //    any mutation.
    if !store.can_advance(levels, t) {
        return Err(PoolExhausted);
    }
    // 1) merge levels 0..=lssb(t) into lssb(t)+1; merged-out storage is
    //    recycled, not dropped.
    if t > 0 {
        let l = fenwick::lssb(t) as usize;
        let mut merged: Option<S::Slot> = None;
        for s in levels.iter_mut().take(l + 1) {
            if let Some(m) = s.take() {
                match merged {
                    None => merged = Some(m),
                    Some(ref mut acc) => store.merge(acc, m),
                }
            }
        }
        if let Some(m) = merged {
            if levels.len() <= l + 1 {
                levels.resize_with(l + 2, || None);
            }
            debug_assert!(levels[l + 1].is_none(), "Fenwick invariant");
            levels[l + 1] = Some(m);
        }
    }
    // 2) transition carried states
    for s in levels.iter_mut().flatten() {
        store.transition(s, &transition);
    }
    // 3) sentinel write
    let s0 = store.write(k, v, write_scale).expect("can_advance checked above");
    if levels.is_empty() {
        levels.resize_with(1, || None);
    }
    debug_assert!(levels[0].is_none(), "sentinel slot must be merged first");
    levels[0] = Some(s0);
    Ok(())
}

/// Owned-`Mat` backing with a recycled free list — the storage of
/// [`super::FenwickState`]. Never refuses a write.
pub(crate) struct MatStore<'a> {
    pub free: &'a mut Vec<Mat>,
    pub dk: usize,
    pub dv: usize,
}

impl FenwickStore for MatStore<'_> {
    type Slot = Mat;

    fn can_advance(&self, _levels: &[Option<Mat>], _t: usize) -> bool {
        true
    }

    fn merge(&mut self, acc: &mut Mat, src: Mat) {
        acc.axpy(1.0, &src);
        self.free.push(src);
    }

    fn transition(&mut self, s: &mut Mat, tr: &Transition<'_>) {
        match tr {
            Transition::Decay(a) => s.scale_inplace(*a),
            Transition::GatedHouseholder { alpha, beta, k } => {
                apply_householder(s, k, *beta);
                s.scale_inplace(*alpha);
            }
        }
    }

    fn write(&mut self, k: &[f32], v: &[f32], write_scale: f32) -> Option<Mat> {
        let mut s0 = match self.free.pop() {
            Some(mut m) => {
                m.data.fill(0.0);
                m
            }
            None => Mat::zeros(self.dk, self.dv),
        };
        tensor::outer_acc(&mut s0, k, v, write_scale);
        Some(s0)
    }
}

/// [`StatePool`]-block backing — the storage of
/// [`super::pooled::PooledFenwickState`]. Refuses cleanly on exhaustion
/// (the admission-backpressure signal), and performs the copy-on-write
/// clone for shared (prefix-cached) blocks: a merge accumulator or
/// transition target with other owners is bitwise-cloned into a private
/// block first, so cached state is never mutated.
pub(crate) struct PoolStore<'a> {
    pub pool: &'a mut StatePool,
    pub dv: usize,
}

impl PoolStore<'_> {
    /// Ensure `slot` is privately owned before an in-place write: clone
    /// shared blocks and swap the handle (dropping our shared ref). The
    /// clone never fails after [`AdvancePlan::feasible`] admitted the
    /// step.
    fn make_private(&mut self, slot: &mut BlockId) {
        if self.pool.is_shared(*slot) {
            let clone =
                self.pool.clone_block(*slot).expect("can_advance reserved the CoW clone");
            self.pool.release(*slot);
            *slot = clone;
        }
    }
}

impl FenwickStore for PoolStore<'_> {
    type Slot = BlockId;

    fn can_advance(&self, levels: &[Option<BlockId>], t: usize) -> bool {
        pool_advance_plan(self.pool, levels, t).feasible(self.pool.available())
    }

    fn merge(&mut self, acc: &mut BlockId, src: BlockId) {
        self.make_private(acc);
        self.pool.axpy(*acc, src, 1.0);
        self.pool.release(src);
    }

    fn transition(&mut self, slot: &mut BlockId, tr: &Transition<'_>) {
        self.make_private(slot);
        match self.pool.precision() {
            Precision::F32 => transition_block(self.pool.get_mut(*slot), self.dv, tr),
            Precision::Bf16 => transition_block_bf16(self.pool.get_bf16_mut(*slot), self.dv, tr),
        }
    }

    fn write(&mut self, k: &[f32], v: &[f32], write_scale: f32) -> Option<BlockId> {
        let id = self.pool.alloc()?;
        match self.pool.precision() {
            Precision::F32 => write_block(self.pool.get_mut(id), self.dv, k, v, write_scale),
            Precision::Bf16 => write_block_bf16(self.pool.get_bf16_mut(id), self.dv, k, v, write_scale),
        }
        Some(id)
    }
}
