//! The single storage-generic Fenwick level update (ROADMAP item).
//!
//! [`FenwickState::step`](super::FenwickState::step) and
//! [`PooledFenwickState::advance`](super::pooled::PooledFenwickState::advance)
//! used to hand-mirror the same merge → transition → sentinel-write
//! skeleton, differing only in where level states live (owned [`Mat`]s
//! with a private free list vs [`StatePool`] blocks). That lock-step
//! contract was documented and enforced by a bit-exactness test, but any
//! edit still had to land twice. [`advance_levels`] is now the one copy of
//! the skeleton; the storage difference is a [`FenwickStore`] impl
//! ([`MatStore`] / [`PoolStore`]), and the bit-exactness of the two decode
//! paths is *by construction*: the same generic function drives the same
//! primitive op sequence (`axpy8`-based merges/writes, identical
//! transition loops) against either backing.
//!
//! The pooled path's backpressure semantics survive the unification:
//! [`FenwickStore::can_write`] is checked **before any mutation**, so a
//! refused step leaves the sequence untouched (the admission-control
//! contract), and the Mat-backed store simply never refuses.

use crate::attention::deltanet::{apply_householder, apply_householder_slice};
use crate::fenwick;
use crate::state::pool::{BlockId, StatePool};
use crate::state::pooled::PoolExhausted;
use crate::state::Transition;
use crate::tensor::{self, Mat};

/// Apply `tr` to one row-major `(d_k, d_v)` state slice — THE per-token
/// transition primitive for slice-backed states, shared by the
/// per-sequence [`PoolStore`] and the pool-wide batched pass
/// ([`crate::state::batched_advance`]) so the two advance paths are
/// bit-exact by construction.
pub(crate) fn transition_block(s: &mut [f32], dv: usize, tr: &Transition<'_>) {
    match tr {
        Transition::Decay(a) => {
            for x in s.iter_mut() {
                *x *= *a;
            }
        }
        Transition::GatedHouseholder { alpha, beta, k } => {
            apply_householder_slice(s, dv, k, *beta);
            for x in s.iter_mut() {
                *x *= *alpha;
            }
        }
    }
}

/// Accumulate `write_scale · k v^T` into a (zeroed) row-major `(d_k, d_v)`
/// state slice — THE sentinel-write primitive, shared like
/// [`transition_block`].
pub(crate) fn write_block(s0: &mut [f32], dv: usize, k: &[f32], v: &[f32], write_scale: f32) {
    for (i, &ki) in k.iter().enumerate() {
        tensor::axpy8(&mut s0[i * dv..(i + 1) * dv], v, ki * write_scale);
    }
}

/// How many storage slots the merge of step `t` frees: the live levels in
/// the merge range `0..=lssb(t)` collapse into one accumulator, so
/// `live − 1` slots come back (none at `t = 0`, where nothing merges).
/// THE capacity-check formula — shared by [`advance_levels`]'s
/// pre-mutation `can_write` check and the batch-wide admission simulation
/// in [`crate::state::batched_advance`], so the "an admission plan that
/// succeeds sequentially succeeds batched" guarantee holds by
/// construction, not by two hand-synced copies.
pub(crate) fn merge_freed<T>(levels: &[Option<T>], t: usize) -> usize {
    if t == 0 {
        return 0;
    }
    let l = fenwick::lssb(t) as usize;
    levels.iter().take(l + 1).flatten().count().saturating_sub(1)
}

/// Storage backing for one sequence's Fenwick level states.
pub(crate) trait FenwickStore {
    type Slot;

    /// Can a sentinel write succeed after a merge that frees `freed`
    /// slots? Checked before any mutation so a refusal is clean.
    fn can_write(&self, freed: usize) -> bool;

    /// Bucket merge: `acc += src`, then recycle `src`'s storage.
    fn merge(&mut self, acc: &mut Self::Slot, src: Self::Slot);

    /// Apply the per-token transition to one live state.
    fn transition(&mut self, slot: &mut Self::Slot, tr: &Transition<'_>);

    /// Fresh zeroed state holding `write_scale * k v^T`; `None` only if
    /// the backing is exhausted (never, after `can_write` returned true).
    fn write(&mut self, k: &[f32], v: &[f32], write_scale: f32) -> Option<Self::Slot>;
}

/// One token's state update — merge levels `0..=lssb(t)` one level up,
/// transition every carried state, write the fresh `(k, v)` sentinel at
/// level 0. `t` is the number of tokens processed so far. Fails (before
/// mutating anything) only if the store cannot supply the sentinel block.
pub(crate) fn advance_levels<S: FenwickStore>(
    store: &mut S,
    levels: &mut Vec<Option<S::Slot>>,
    t: usize,
    k: &[f32],
    v: &[f32],
    write_scale: f32,
    transition: Transition<'_>,
) -> Result<(), PoolExhausted> {
    // 0) capacity check first: the merge below frees `live-1` slots and
    //    the write takes one, so a refusal must come before any mutation.
    let freed = merge_freed(levels, t);
    if !store.can_write(freed) {
        return Err(PoolExhausted);
    }
    // 1) merge levels 0..=lssb(t) into lssb(t)+1; merged-out storage is
    //    recycled, not dropped.
    if t > 0 {
        let l = fenwick::lssb(t) as usize;
        let mut merged: Option<S::Slot> = None;
        for s in levels.iter_mut().take(l + 1) {
            if let Some(m) = s.take() {
                match merged {
                    None => merged = Some(m),
                    Some(ref mut acc) => store.merge(acc, m),
                }
            }
        }
        if let Some(m) = merged {
            if levels.len() <= l + 1 {
                levels.resize_with(l + 2, || None);
            }
            debug_assert!(levels[l + 1].is_none(), "Fenwick invariant");
            levels[l + 1] = Some(m);
        }
    }
    // 2) transition carried states
    for s in levels.iter_mut().flatten() {
        store.transition(s, &transition);
    }
    // 3) sentinel write
    let s0 = store.write(k, v, write_scale).expect("can_write checked above");
    if levels.is_empty() {
        levels.resize_with(1, || None);
    }
    debug_assert!(levels[0].is_none(), "sentinel slot must be merged first");
    levels[0] = Some(s0);
    Ok(())
}

/// Owned-`Mat` backing with a recycled free list — the storage of
/// [`super::FenwickState`]. Never refuses a write.
pub(crate) struct MatStore<'a> {
    pub free: &'a mut Vec<Mat>,
    pub dk: usize,
    pub dv: usize,
}

impl FenwickStore for MatStore<'_> {
    type Slot = Mat;

    fn can_write(&self, _freed: usize) -> bool {
        true
    }

    fn merge(&mut self, acc: &mut Mat, src: Mat) {
        acc.axpy(1.0, &src);
        self.free.push(src);
    }

    fn transition(&mut self, s: &mut Mat, tr: &Transition<'_>) {
        match tr {
            Transition::Decay(a) => s.scale_inplace(*a),
            Transition::GatedHouseholder { alpha, beta, k } => {
                apply_householder(s, k, *beta);
                s.scale_inplace(*alpha);
            }
        }
    }

    fn write(&mut self, k: &[f32], v: &[f32], write_scale: f32) -> Option<Mat> {
        let mut s0 = match self.free.pop() {
            Some(mut m) => {
                m.data.fill(0.0);
                m
            }
            None => Mat::zeros(self.dk, self.dv),
        };
        tensor::outer_acc(&mut s0, k, v, write_scale);
        Some(s0)
    }
}

/// [`StatePool`]-block backing — the storage of
/// [`super::pooled::PooledFenwickState`]. Refuses cleanly on exhaustion
/// (the admission-backpressure signal).
pub(crate) struct PoolStore<'a> {
    pub pool: &'a mut StatePool,
    pub dv: usize,
}

impl FenwickStore for PoolStore<'_> {
    type Slot = BlockId;

    fn can_write(&self, freed: usize) -> bool {
        self.pool.available() + freed >= 1
    }

    fn merge(&mut self, acc: &mut BlockId, src: BlockId) {
        self.pool.axpy(*acc, src, 1.0);
        self.pool.release(src);
    }

    fn transition(&mut self, slot: &mut BlockId, tr: &Transition<'_>) {
        transition_block(self.pool.get_mut(*slot), self.dv, tr);
    }

    fn write(&mut self, k: &[f32], v: &[f32], write_scale: f32) -> Option<BlockId> {
        let id = self.pool.alloc()?;
        write_block(self.pool.get_mut(id), self.dv, k, v, write_scale);
        Some(id)
    }
}
