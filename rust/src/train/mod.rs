//! Training orchestrator: drives the fused `train_step` HLO artifact over
//! the synthetic corpus, with LR scheduling, loss logging, and
//! checkpointing. Python never runs here — the whole fwd+bwd+Adam update
//! is one compiled executable per step.

use std::path::PathBuf;

use anyhow::Result;

use crate::data::corpus::Corpus;
use crate::runtime::{ModelHandle, Runtime};
use crate::util::stats::Ema;
use crate::util::Rng;

/// Linear warmup then cosine decay to 10% of peak.
pub fn lr_schedule(step: usize, total: usize, peak: f64, warmup: usize) -> f64 {
    if step < warmup {
        return peak * (step + 1) as f64 / warmup as f64;
    }
    let t = (step - warmup) as f64 / (total - warmup).max(1) as f64;
    let min_lr = 0.1 * peak;
    min_lr + 0.5 * (peak - min_lr) * (1.0 + (std::f64::consts::PI * t).cos())
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    pub warmup: usize,
    pub log_every: usize,
    pub seed: u64,
    pub checkpoint: Option<PathBuf>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            lr: 3e-3,
            warmup: 20,
            log_every: 10,
            seed: 0,
            checkpoint: None,
        }
    }
}

/// One (step, raw loss, smoothed loss) record.
pub type LossCurve = Vec<(usize, f32, f32)>;

/// Train `model` on `corpus` for `cfg.steps` steps. Returns the loss curve.
pub fn train(
    rt: &Runtime,
    model: &mut ModelHandle,
    corpus: &Corpus,
    cfg: &TrainConfig,
) -> Result<LossCurve> {
    model.ensure_train(rt)?;
    let batch = model.manifest.batch;
    let mut rng = Rng::new(cfg.seed);
    let mut curve = Vec::new();
    let mut ema = Ema::new(0.1);
    let t0 = std::time::Instant::now();
    for step in 1..=cfg.steps {
        let tokens = corpus.train_batch(batch, &mut rng);
        let lr = lr_schedule(step - 1, cfg.steps, cfg.lr, cfg.warmup) as f32;
        let out = model.train_step(step as i32, &tokens, lr)?;
        let sm = ema.update(out.loss as f64) as f32;
        curve.push((step, out.loss, sm));
        if step % cfg.log_every == 0 || step == 1 || step == cfg.steps {
            let tps = (step * batch * model.manifest.cfg("seq_len")) as f64
                / t0.elapsed().as_secs_f64();
            crate::info!(
                "step {step:>5}/{} loss {:.4} (ema {:.4}) lr {lr:.2e} tok/s {tps:.0}",
                cfg.steps,
                out.loss,
                sm
            );
        }
        if !out.loss.is_finite() {
            anyhow::bail!("loss diverged at step {step}");
        }
    }
    if let Some(path) = &cfg.checkpoint {
        model.save_checkpoint(path)?;
        crate::info!("checkpoint -> {}", path.display());
    }
    Ok(curve)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_warms_up_then_decays() {
        let peak = 1e-3;
        assert!(lr_schedule(0, 100, peak, 10) < peak * 0.2);
        assert!((lr_schedule(10, 100, peak, 10) - peak).abs() < peak * 0.1);
        assert!(lr_schedule(99, 100, peak, 10) < peak * 0.2);
        // monotone decay after warmup
        let a = lr_schedule(20, 100, peak, 10);
        let b = lr_schedule(60, 100, peak, 10);
        assert!(a > b);
    }
}
