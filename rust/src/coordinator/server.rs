//! The decode engine: continuous batching over a pluggable
//! [`DecodeBackend`] with per-sequence Fenwick states.
//!
//! The server owns the request queue, the bucketed batch policy, greedy
//! sampling, retirement, and metrics; the backend owns state storage and
//! the batched step itself (PJRT artifacts via [`PjrtBackend`], or the
//! pure-Rust pooled engine via
//! [`PooledBackend`](super::backend::PooledBackend) — see
//! `coordinator::backend`).
//!
//! Scheduling properties (regression-tested below):
//! - **Chunked prefill interleaves with decode**: when the backend has a
//!   chunkwise prefill path ([`DecodeBackend::prefill_chunk_size`] > 0),
//!   a sequence whose remaining prompt still holds a full chunk (plus the
//!   final token the decode step needs) advances **one chunk per engine
//!   step** through [`DecodeBackend::prefill_chunk`] — state-only, off
//!   the decode bucket — while the running decode rows step in the same
//!   loop iteration. A long prompt therefore cannot starve in-flight
//!   decode rows, and decode traffic cannot stall prompt ingestion. The
//!   sub-chunk prompt tail (and the final prompt token, whose logits seed
//!   sampling) feed through the decode step as before.
//! - **Round-robin fairness**: processed survivors go to the back of the
//!   running list each step, so when `ready > bucket` the tail advances
//!   on the next step instead of starving behind a fixed prefix.
//! - **The batch policy's hold is honored**: when
//!   [`BatchPolicy::plan`](super::batcher::BatchPolicy::plan) says wait
//!   for a fuller bucket, the engine *waits* (bounded by `max_wait` via
//!   the hold clock) instead of immediately running a padded bucket —
//!   occupancy under bursty traffic is the point of dynamic batching.
//! - **Admission backpressure**: a backend may refuse admission
//!   ([`AdmitError::Exhausted`], e.g. state-pool exhaustion); the request
//!   stays queued, FIFO order intact, until capacity frees up.
//! - **Degenerate requests**: empty prompts are rejected at submit;
//!   `max_new == 0` completes immediately without touching the engine.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::runtime::{ModelHandle, Runtime};
use crate::util::stats::Summary;

use super::backend::{AdmitError, DecodeBackend, PjrtBackend, SeqSlot};
use super::batcher::{BatchPolicy, RequestQueue};
use super::{GenRequest, GenResult, SubmitError};

struct Seq {
    id: u64,
    prompt: Vec<i32>,
    generated: Vec<i32>,
    /// index of the next token to feed (position of that token)
    pos: usize,
    /// backend-side state handle
    slot: SeqSlot,
    max_new: usize,
    submitted: Instant,
    /// engine advances: prefill chunks + decode rows (reported in results)
    steps: usize,
    /// decode rows only — the "is a batch mid-generation" signal the
    /// batcher's hold logic keys on (prefill chunks must NOT defeat the
    /// hold: a prompt streaming chunks is not a running decode batch)
    decode_steps: usize,
}

impl Seq {
    /// next token to feed: prompt token while prefilling, else last sample
    fn next_token(&self) -> i32 {
        if self.pos < self.prompt.len() {
            self.prompt[self.pos]
        } else {
            *self
                .generated
                .last()
                .expect("non-empty prompt + max_new >= 1 guarantee a sample before feedback")
        }
    }

    fn done(&self) -> bool {
        self.generated.len() >= self.max_new
    }
}

/// Serving metrics.
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub steps: usize,
    pub tokens_processed: usize,
    pub step_seconds: Vec<f64>,
    pub batch_occupancy: Vec<f64>,
    pub completed: usize,
    pub peak_state_bytes: usize,
    /// prompt chunks ingested through the chunkwise prefill path
    pub prefill_chunks: usize,
    /// prompt tokens those chunks covered (not counted in
    /// `tokens_processed`, which tracks decode-step rows)
    pub prefill_tokens: usize,
}

impl ServerStats {
    pub fn tokens_per_second(&self) -> f64 {
        let total: f64 = self.step_seconds.iter().sum();
        if total == 0.0 {
            0.0
        } else {
            self.tokens_processed as f64 / total
        }
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        if self.step_seconds.is_empty() {
            None
        } else {
            Some(Summary::of(&self.step_seconds))
        }
    }

    pub fn mean_occupancy(&self) -> f64 {
        if self.batch_occupancy.is_empty() {
            0.0
        } else {
            self.batch_occupancy.iter().sum::<f64>() / self.batch_occupancy.len() as f64
        }
    }
}

/// Synchronous decode server (single engine thread — the queue/batcher
/// interfaces are thread-safe by construction), generic over the decode
/// backend.
pub struct DecodeServer<B: DecodeBackend> {
    backend: B,
    policy: BatchPolicy,
    queue: RequestQueue<GenRequest>,
    running: Vec<Seq>,
    finished: Vec<GenResult>,
    pub stats: ServerStats,
    /// when the current "wait for a fuller bucket" hold started
    hold_since: Option<Instant>,
    /// record every decode row's logits (differential-test hook)
    capture_logits: bool,
    /// captured (sequence id, position, logits) rows, in execution order
    logit_log: Vec<(u64, usize, Vec<f32>)>,
}

impl DecodeServer<PjrtBackend> {
    /// The AOT/PJRT server (compiles decode executables for every policy
    /// bucket up front).
    pub fn new(rt: &Runtime, model: ModelHandle, policy: BatchPolicy) -> Result<DecodeServer<PjrtBackend>> {
        let backend = PjrtBackend::new(rt, model, &policy.buckets)?;
        Ok(DecodeServer::with_backend(backend, policy))
    }

    pub fn model(&self) -> &ModelHandle {
        self.backend.model()
    }
}

impl<B: DecodeBackend> DecodeServer<B> {
    pub fn with_backend(backend: B, policy: BatchPolicy) -> DecodeServer<B> {
        DecodeServer {
            backend,
            policy,
            queue: RequestQueue::new(),
            running: Vec::new(),
            finished: Vec::new(),
            stats: ServerStats::default(),
            hold_since: None,
            capture_logits: false,
            logit_log: Vec::new(),
        }
    }

    /// Record every decode row's logits from here on — the serving-trace
    /// differential harness compares them bit-for-bit against a
    /// per-sequence oracle replay (see `coordinator::trace`). Test-scale
    /// traffic only: every row's `(id, position, logits)` is kept.
    pub fn enable_logit_capture(&mut self) {
        self.capture_logits = true;
    }

    /// Drain the captured `(id, position, logits)` rows (execution order).
    pub fn take_captured_logits(&mut self) -> Vec<(u64, usize, Vec<f32>)> {
        std::mem::take(&mut self.logit_log)
    }

    /// Enqueue a request. Empty prompts are rejected (there is no token
    /// to feed at position 0); `max_new == 0` completes immediately.
    pub fn submit(&mut self, req: GenRequest) -> Result<(), SubmitError> {
        if req.prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt);
        }
        if req.max_new == 0 {
            self.finished.push(GenResult {
                id: req.id,
                tokens: Vec::new(),
                latency: 0.0,
                steps: 0,
            });
            self.stats.completed += 1;
            return Ok(());
        }
        self.queue.push(req);
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.running.len()
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// (id, position, steps) of every running sequence, in scheduling
    /// order — monitoring + fairness regression tests.
    pub fn running_progress(&self) -> Vec<(u64, usize, usize)> {
        self.running.iter().map(|s| (s.id, s.pos, s.steps)).collect()
    }

    /// Admit queued requests, FIFO, stopping at the first the backend
    /// refuses (resource backpressure keeps it — and everything behind
    /// it — queued). The running set is allowed to exceed the largest
    /// bucket by 2× (continuous-batching headroom: retirements backfill
    /// from already-admitted sequences, round-robined through the
    /// bucket, instead of paying admission latency).
    fn admit(&mut self) -> Result<()> {
        let cap = 2 * *self.policy.buckets.last().unwrap();
        while self.running.len() < cap {
            let Some(req) = self.queue.peek() else { break };
            let max_steps = req.prompt.len() + req.max_new - 1;
            let slot = match self.backend.admit(max_steps.max(1)) {
                Ok(slot) => slot,
                Err(AdmitError::Exhausted) => break,
                Err(AdmitError::TooLarge) => {
                    // drop the impossible request before erroring so it
                    // can't wedge the queue head: the caller sees the
                    // failure once, traffic behind it still serves
                    let req = self.queue.pop().expect("peeked above");
                    bail!(
                        "request {} needs more decode state than the backend can ever hold \
                         ({} steps); request dropped",
                        req.id,
                        max_steps
                    );
                }
            };
            // keep the queue-entry timestamp: latency must include the
            // time a request waited under backpressure/holds
            let (req, submitted) = self.queue.pop_timed().expect("peeked above");
            self.running.push(Seq {
                id: req.id,
                prompt: req.prompt,
                generated: Vec::new(),
                pos: 0,
                slot,
                max_new: req.max_new,
                submitted,
                steps: 0,
                decode_steps: 0,
            });
        }
        Ok(())
    }

    /// Still at least one full prefill chunk (plus the final prompt token
    /// the decode step needs for sampling) ahead of this sequence?
    fn mid_prefill(seq: &Seq, chunk: usize) -> bool {
        chunk > 0 && seq.pos % chunk == 0 && seq.pos + chunk < seq.prompt.len()
    }

    /// Run one engine iteration; returns how many sequences advanced —
    /// decode rows plus prefill chunks (0 while the batcher holds out for
    /// a fuller bucket and no prompt is mid-prefill).
    pub fn step(&mut self) -> Result<usize> {
        self.admit()?;

        // ---- chunked prefill pass: every sequence still a full chunk
        // away from its last prompt token ingests one chunk, state-only.
        // These don't occupy the decode bucket, so a long prompt and the
        // running decode rows advance in the same engine iteration.
        let chunk = self.backend.prefill_chunk_size();
        let mut prefilled = 0usize;
        if chunk > 0 {
            let jobs: Vec<(usize, SeqSlot, usize, Vec<i32>)> = self
                .running
                .iter()
                .enumerate()
                .filter(|(_, s)| Self::mid_prefill(s, chunk))
                .map(|(i, s)| (i, s.slot, s.pos, s.prompt[s.pos..s.pos + chunk].to_vec()))
                .collect();
            for (i, slot, pos, tokens) in jobs {
                self.backend.prefill_chunk(slot, &tokens, pos)?;
                let seq = &mut self.running[i];
                seq.pos += chunk;
                seq.steps += 1;
                prefilled += 1;
                self.stats.prefill_chunks += 1;
                self.stats.prefill_tokens += chunk;
            }
            // prefill-engine states live outside the pool; sample the peak
            // here too, since a held/prefill-only iteration exits early
            if prefilled > 0 {
                self.stats.peak_state_bytes =
                    self.stats.peak_state_bytes.max(self.backend.state_bytes());
            }
        }

        // ---- decode pass over everything past its prefill chunks
        let decode_idx: Vec<usize> = self
            .running
            .iter()
            .enumerate()
            .filter(|(_, s)| !Self::mid_prefill(s, chunk))
            .map(|(i, _)| i)
            .collect();
        let ready = decode_idx.len();
        // the hold clock: how long runnable work has been waiting — the
        // queue's oldest age while queued, the hold timer once admitted
        let held = self.hold_since.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);
        let waited = self.queue.oldest_age().max(held);
        // a hold only ever applies to a *fresh* batch (no decode row
        // executed yet): once any sequence is mid-generation, stalling it
        // for max_wait on every plan refusal — or on every new arrival —
        // would collapse decode throughput to one step per max_wait.
        // Prefill chunks deliberately don't count: a prompt streaming
        // chunks is not a running decode batch, so the hold still gets to
        // gather a fuller first bucket while long prompts ingest.
        let in_flight = self.running.iter().any(|s| s.decode_steps > 0);
        let bucket = match self.policy.plan(ready, waited) {
            Some(b) => {
                self.hold_since = None;
                b
            }
            None if ready > 0 && in_flight => {
                self.hold_since = None;
                // force expired-hold planning: smallest covering bucket
                match self.policy.plan(ready, self.policy.max_wait) {
                    Some(b) => b,
                    None => return Ok(prefilled), // unreachable: expired plan with ready > 0 is Some
                }
            }
            None => {
                if ready > 0 && self.hold_since.is_none() {
                    // start the hold the policy asked for; max_wait later
                    // plan() will release it
                    self.hold_since = Some(Instant::now());
                }
                return Ok(prefilled);
            }
        };
        let n = ready.min(bucket);

        // gather the scheduling prefix of the decode-ready list
        // (processed survivors go to the back after each step, so over
        // consecutive steps this round-robins the batch)
        let sched: Vec<usize> = decode_idx[..n].to_vec();
        let rows: Vec<(SeqSlot, i32, i32)> = sched
            .iter()
            .map(|&i| {
                let s = &self.running[i];
                (s.slot, s.next_token(), s.pos as i32)
            })
            .collect();

        // execute
        let t0 = Instant::now();
        let logits = self.backend.step(bucket, &rows)?;
        let dt = t0.elapsed().as_secs_f64();

        // sample + advance
        let vocab = logits.len() / n;
        for (j, &i) in sched.iter().enumerate() {
            let seq = &mut self.running[i];
            if self.capture_logits {
                self.logit_log.push((seq.id, seq.pos, logits[j * vocab..(j + 1) * vocab].to_vec()));
            }
            seq.pos += 1;
            seq.steps += 1;
            seq.decode_steps += 1;
            // still feeding prompt? only sample once the prompt is consumed
            if seq.pos >= seq.prompt.len() {
                let row = &logits[j * vocab..(j + 1) * vocab];
                let tok = crate::tensor::ops::argmax(row) as i32;
                seq.generated.push(tok);
            }
        }
        // retire finished sequences and move processed survivors to the
        // back (so the unprocessed tail leads the next step) in one O(R)
        // compaction pass — no per-row Vec::remove shifting
        let mut scheduled = vec![false; self.running.len()];
        for &i in &sched {
            scheduled[i] = true;
        }
        let old = std::mem::take(&mut self.running);
        let mut processed_survivors: Vec<Seq> = Vec::with_capacity(n);
        for (i, seq) in old.into_iter().enumerate() {
            if !scheduled[i] {
                self.running.push(seq);
            } else if seq.done() {
                self.backend.retire(seq.slot);
                self.finished.push(GenResult {
                    id: seq.id,
                    tokens: seq.generated,
                    latency: seq.submitted.elapsed().as_secs_f64(),
                    steps: seq.steps,
                });
                self.stats.completed += 1;
            } else {
                processed_survivors.push(seq);
            }
        }
        self.running.extend(processed_survivors);

        self.stats.steps += 1;
        self.stats.tokens_processed += n;
        self.stats.step_seconds.push(dt);
        self.stats.batch_occupancy.push(n as f64 / bucket as f64);
        self.stats.peak_state_bytes = self.stats.peak_state_bytes.max(self.backend.state_bytes());
        Ok(n + prefilled)
    }

    /// Drive until all submitted work completes; returns the results.
    /// While the batcher holds for a fuller bucket, naps briefly so the
    /// hold can expire (bounded by the policy's `max_wait`).
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        while self.pending() > 0 {
            if self.step()? == 0 {
                let nap = (self.policy.max_wait / 8)
                    .clamp(Duration::from_micros(50), Duration::from_millis(5));
                std::thread::sleep(nap);
            }
        }
        Ok(std::mem::take(&mut self.finished))
    }

    pub fn take_finished(&mut self) -> Vec<GenResult> {
        std::mem::take(&mut self.finished)
    }

    /// Results sorted by id (BTreeMap for determinism in demos).
    pub fn results_by_id(results: Vec<GenResult>) -> BTreeMap<u64, GenResult> {
        results.into_iter().map(|r| (r.id, r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::PooledBackend;

    fn pooled_server(pool_blocks: usize, buckets: Vec<usize>, max_wait: Duration) -> DecodeServer<PooledBackend> {
        let backend = PooledBackend::new(64, 8, 8, pool_blocks, 7);
        DecodeServer::with_backend(backend, BatchPolicy::new(buckets, max_wait))
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: (0..prompt_len as i32).map(|i| (id as i32 * 13 + i * 7) % 64).collect(),
            max_new,
        }
    }

    #[test]
    fn round_robin_rotates_the_tail_into_the_batch() {
        // 12 sequences share bucket 8: under the old fixed-prefix gather
        // the tail 4 never advanced until the head retired.
        let mut srv = pooled_server(256, vec![8], Duration::ZERO);
        for id in 0..12 {
            srv.submit(req(id, 2, 4)).unwrap();
        }
        srv.step().unwrap();
        srv.step().unwrap();
        let progress = srv.running_progress();
        assert_eq!(progress.len(), 12);
        for (id, pos, steps) in progress {
            assert!(steps >= 1, "seq {id} starved after two steps (pos {pos})");
        }
        // and everything completes with the same per-sequence step count
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 12);
        for r in &results {
            assert_eq!(r.steps, 2 + 4 - 1, "req {}", r.id);
            assert_eq!(r.tokens.len(), 4);
        }
    }

    #[test]
    fn hold_for_fuller_bucket_is_honored_and_improves_occupancy() {
        // bursty traffic: 3 requests arrive, then 5 more. The held server
        // must not run a padded 3/4 bucket immediately.
        let mut held = pooled_server(256, vec![1, 4, 8], Duration::from_secs(5));
        for id in 0..3 {
            held.submit(req(id, 2, 2)).unwrap();
        }
        assert_eq!(held.step().unwrap(), 0, "must hold for a fuller bucket");
        assert_eq!(held.stats.steps, 0, "a held step must not record a batch");
        for id in 3..8 {
            held.submit(req(id, 2, 2)).unwrap();
        }
        assert_eq!(held.step().unwrap(), 8, "full bucket runs immediately");
        let results = held.run_to_completion().unwrap();
        assert_eq!(results.len(), 8);
        assert!(
            held.stats.batch_occupancy.iter().all(|&o| o == 1.0),
            "held server should only run full buckets: {:?}",
            held.stats.batch_occupancy
        );

        // same traffic with max_wait = 0 (the old always-run-now
        // behavior): strictly worse occupancy
        let mut eager = pooled_server(256, vec![1, 4, 8], Duration::ZERO);
        for id in 0..3 {
            eager.submit(req(id, 2, 2)).unwrap();
        }
        eager.step().unwrap();
        for id in 3..8 {
            eager.submit(req(id, 2, 2)).unwrap();
        }
        let _ = eager.run_to_completion().unwrap();
        assert!(
            held.stats.mean_occupancy() > eager.stats.mean_occupancy(),
            "hold must improve occupancy: held {} vs eager {}",
            held.stats.mean_occupancy(),
            eager.stats.mean_occupancy()
        );
    }

    #[test]
    fn hold_never_stalls_in_flight_sequences() {
        // The hold applies to a *fresh* batch exactly once: after the
        // first executed step, neither plan refusals nor new arrivals may
        // pause the running batch for another max_wait.
        let mut srv = pooled_server(256, vec![1, 4, 8], Duration::from_millis(2));
        for id in 0..4 {
            srv.submit(req(id, 2, 4)).unwrap();
        }
        assert_eq!(srv.step().unwrap(), 0, "initial hold for a fuller bucket");
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(srv.step().unwrap(), 4, "hold expires once");
        // consecutive sub-bucket steps run back-to-back, no fresh hold
        assert_eq!(srv.step().unwrap(), 4, "re-armed hold stalled a running batch");
        // a trickle arrival joins immediately instead of re-arming the hold
        srv.submit(req(4, 2, 4)).unwrap();
        assert_eq!(srv.step().unwrap(), 5, "new arrival must not stall in-flight sequences");
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 5);
        for r in &results {
            assert_eq!(r.steps, 2 + 4 - 1, "req {}", r.id);
        }
    }

    #[test]
    fn prefill_chunks_do_not_defeat_the_batchers_hold() {
        // Long prompts stream prefill chunks while the batcher holds for
        // a fuller bucket. Prefill steps are not decode progress, so the
        // hold must survive them (a regression here would re-introduce
        // the padded-bucket eagerness the hold exists to prevent), and
        // the first decode batch runs only once the bucket fills.
        let backend = PooledBackend::with_config(64, 1, 8, 8, 4, 512, 7);
        let mut srv = DecodeServer::with_backend(
            backend,
            BatchPolicy::new(vec![1, 4, 8], Duration::from_secs(5)),
        );
        for id in 0..3 {
            srv.submit(req(id, 10, 2)).unwrap(); // 2 chunks + a 2-token tail
        }
        assert_eq!(srv.step().unwrap(), 3, "chunk 1 of each prompt");
        assert_eq!(srv.step().unwrap(), 3, "chunk 2; decode now holds at 3/8");
        assert_eq!(srv.stats.steps, 0, "held decode batch must not have run");
        assert_eq!(
            srv.step().unwrap(),
            0,
            "prefill steps must not arm in_flight and break the hold"
        );
        assert_eq!(srv.stats.steps, 0);
        // five more arrivals prefill, then fill the bucket: the first
        // decode batch runs full
        for id in 3..8 {
            srv.submit(req(id, 10, 2)).unwrap();
        }
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 8);
        assert!(
            srv.stats.batch_occupancy.iter().all(|&o| o == 1.0),
            "held server should only run full decode buckets: {:?}",
            srv.stats.batch_occupancy
        );
        for r in &results {
            assert_eq!(r.tokens.len(), 2, "req {}", r.id);
            assert_eq!(r.steps, 2 + 3, "req {}: 2 chunks + 3 decode rows", r.id);
        }
    }

    #[test]
    fn lone_request_still_completes_after_max_wait() {
        // the hold is bounded: a single request must not wait forever
        let mut srv = pooled_server(64, vec![1, 4], Duration::from_millis(2));
        srv.submit(req(0, 3, 2)).unwrap();
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].tokens.len(), 2);
    }

    #[test]
    fn empty_prompt_rejected_and_zero_max_new_short_circuits() {
        let mut srv = pooled_server(64, vec![4], Duration::ZERO);
        assert_eq!(
            srv.submit(GenRequest { id: 1, prompt: vec![], max_new: 5 }),
            Err(SubmitError::EmptyPrompt)
        );
        // max_new == 0 retires cleanly without ever touching the engine
        srv.submit(GenRequest { id: 2, prompt: vec![1, 2], max_new: 0 }).unwrap();
        assert_eq!(srv.pending(), 0);
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].id, 2);
        assert!(results[0].tokens.is_empty());
        assert_eq!(results[0].steps, 0);
        assert_eq!(srv.stats.steps, 0, "no engine step for a zero-length generation");
    }

    #[test]
    fn pool_backpressure_defers_admission_and_everything_completes() {
        // Each request needs blocks_for_steps(2+3-1) = 3 blocks; a
        // 7-block pool admits at most 2 at a time. All 6 must still
        // complete, FIFO-fairly, with the pool never over-committed.
        let mut srv = pooled_server(7, vec![4], Duration::ZERO);
        for id in 0..6 {
            srv.submit(req(id, 2, 3)).unwrap();
        }
        let mut max_running = 0;
        let mut max_in_use = 0;
        let mut guard = 0;
        while srv.pending() > 0 {
            srv.step().unwrap();
            max_running = max_running.max(srv.running_progress().len());
            max_in_use = max_in_use.max(srv.backend().pool().in_use());
            guard += 1;
            assert!(guard < 200, "no forward progress under backpressure");
        }
        assert!(max_running <= 2, "admission over-committed: {max_running} concurrent");
        assert!(max_in_use <= 7, "pool over-committed: {max_in_use} blocks");
        let results = srv.take_finished();
        assert_eq!(results.len(), 6);
        assert_eq!(srv.backend().pool().in_use(), 0, "retirement leaked pool blocks");
        for r in &results {
            assert_eq!(r.tokens.len(), 3, "req {}", r.id);
        }
    }

    #[test]
    fn oversized_request_fails_loudly_without_wedging_the_queue() {
        // needs blocks_for_steps(1+200-1) = 8 blocks > 4-block pool
        let mut srv = pooled_server(4, vec![4], Duration::ZERO);
        srv.submit(req(0, 1, 200)).unwrap();
        srv.submit(req(1, 2, 2)).unwrap();
        srv.submit(req(2, 2, 2)).unwrap();
        assert!(srv.step().is_err(), "impossible request must error, not spin");
        // the poisoned request was dropped: traffic behind it still serves
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 2);
        for r in &results {
            assert_eq!(r.tokens.len(), 2, "req {}", r.id);
        }
    }

    #[test]
    fn long_prefill_interleaves_with_decode_rows() {
        // One long prompt (4 full chunks of 8 + a 3-token tail) next to
        // three short decoding requests: every engine step must advance
        // BOTH the prefill (exactly one chunk) and every decode row —
        // chunked prefill may not starve in-flight decode, and decode may
        // not stall prompt ingestion.
        let backend = PooledBackend::with_config(64, 2, 8, 8, 8, 512, 11);
        let mut srv =
            DecodeServer::with_backend(backend, BatchPolicy::new(vec![4], Duration::ZERO));
        srv.submit(req(0, 8 * 4 + 3, 2)).unwrap();
        for id in 1..4 {
            srv.submit(req(id, 2, 12)).unwrap();
        }
        for step in 1..=3usize {
            srv.step().unwrap();
            let prog = srv.running_progress();
            let &(_, pos0, _) = prog.iter().find(|(id, _, _)| *id == 0).unwrap();
            assert_eq!(pos0, 8 * step, "prefill must advance one chunk per engine step");
            for &(id, pos, steps) in &prog {
                if id != 0 {
                    assert_eq!(steps, step, "decode seq {id} starved at step {step} (pos {pos})");
                }
            }
            assert_eq!(srv.backend().prefilling(), 1, "id 0 still mid-prefill");
        }
        // step 4: the last chunk ingests (pos 24 → 32), after which the
        // tail no longer holds a full chunk, so id 0 joins the decode
        // batch in the same iteration (pos 32 → 33) and flips to pooled
        // decode states via the export bridge
        srv.step().unwrap();
        let prog = srv.running_progress();
        let &(_, pos0, _) = prog.iter().find(|(id, _, _)| *id == 0).unwrap();
        assert_eq!(pos0, 33, "tail decode must start the moment chunks are exhausted");
        assert_eq!(srv.backend().prefilling(), 0, "export bridge must have run");
        assert_eq!(srv.stats.prefill_chunks, 4);
        assert_eq!(srv.stats.prefill_tokens, 32);

        let results = DecodeServer::<PooledBackend>::results_by_id(srv.run_to_completion().unwrap());
        assert_eq!(results.len(), 4);
        assert_eq!(results[&0].tokens.len(), 2);
        // 4 chunk-steps + 4 decode rows (tail 32/33/34 + one feedback)
        assert_eq!(results[&0].steps, 4 + 4, "req 0 step accounting");
        for id in 1..4u64 {
            assert_eq!(results[&id].tokens.len(), 12, "req {id}");
            assert_eq!(results[&id].steps, 2 + 12 - 1, "req {id}");
        }
        assert_eq!(srv.backend().pool().in_use(), 0, "retirement leaked pool blocks");
    }

    #[test]
    fn chunked_prefill_is_deterministic_across_batch_schedules_with_per_token_gates() {
        // Multi-head + chunked prefill + a per-token α/λ schedule: the
        // same request decoded alone and inside a batch of 8 must yield
        // identical tokens (prefill GEMMs are per-sequence, the batched
        // read is bit-exact, and both paths read one GateTable).
        use crate::state::GateTable;
        use crate::tensor::Mat;
        use crate::util::Rng;
        let gates = || {
            let mut grng = Rng::new(0x6A7E);
            let alpha: Vec<f32> = (0..64).map(|_| grng.range_f32(0.9, 1.0)).collect();
            let lambda = Mat::rand_uniform(64, 8, 0.05, 1.0, &mut grng);
            GateTable::per_token(alpha, lambda)
        };
        let server = |buckets: Vec<usize>| {
            let mut backend = PooledBackend::with_config(64, 2, 8, 8, 4, 512, 7);
            backend.set_gates(gates());
            DecodeServer::with_backend(backend, BatchPolicy::new(buckets, Duration::ZERO))
        };
        let solo_tokens = {
            let mut srv = server(vec![1]);
            srv.submit(req(3, 11, 5)).unwrap(); // 2 chunks + 3-token tail
            let results = srv.run_to_completion().unwrap();
            results.into_iter().next().unwrap().tokens
        };
        let mut srv = server(vec![8]);
        for id in 0..8 {
            srv.submit(req(id, 11, 5)).unwrap();
        }
        let results = DecodeServer::<PooledBackend>::results_by_id(srv.run_to_completion().unwrap());
        assert_eq!(results[&3].tokens, solo_tokens, "batching changed a prefilled decode");
        assert!(srv.stats.prefill_chunks > 0, "prompts this long must prefill chunkwise");
    }

    #[test]
    fn pooled_decode_is_deterministic_across_batch_schedules() {
        // The same request decoded alone and inside a big batch must
        // yield identical tokens (batched read is bit-exact and per-row
        // logits don't depend on batchmates).
        let solo_tokens = {
            let mut srv = pooled_server(64, vec![1], Duration::ZERO);
            srv.submit(req(3, 4, 6)).unwrap();
            let results = srv.run_to_completion().unwrap();
            results.into_iter().next().unwrap().tokens
        };
        let mut srv = pooled_server(256, vec![8], Duration::ZERO);
        for id in 0..8 {
            srv.submit(req(id, 4, 6)).unwrap();
        }
        let results = DecodeServer::<PooledBackend>::results_by_id(srv.run_to_completion().unwrap());
        assert_eq!(results[&3].tokens, solo_tokens, "batching changed a sequence's decode");
    }
}
