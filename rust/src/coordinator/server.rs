//! The decode engine: continuous batching over the AOT `decode_step`
//! artifacts with per-sequence Fenwick states.
//!
//! Each live sequence owns one flat state buffer per layer (the dense
//! (L, H, dk, dv) stack the artifact expects — App. B.4's "half the
//! levels are zero" sparsity is tracked in the memory accounting and
//! exploited by the pure-Rust `state::pool` path; the HLO path keeps
//! dense stacks for fixed shapes). A step: take up to `bucket` runnable
//! sequences (mixed positions — the artifact's per-sequence `pos` vector
//! makes continuous batching sound), gather states, execute, scatter,
//! sample greedily, retire finished sequences.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{ModelHandle, Runtime};
use crate::util::stats::Summary;

use super::batcher::{BatchPolicy, RequestQueue};
use super::{GenRequest, GenResult};

struct Seq {
    id: u64,
    prompt: Vec<i32>,
    generated: Vec<i32>,
    /// index of the next token to feed (position of that token)
    pos: usize,
    /// per-layer flat state (numel per layer, batch dim excluded)
    states: Vec<Vec<f32>>,
    max_new: usize,
    submitted: Instant,
    steps: usize,
}

impl Seq {
    /// next token to feed: prompt token while prefilling, else last sample
    fn next_token(&self) -> i32 {
        if self.pos < self.prompt.len() {
            self.prompt[self.pos]
        } else {
            *self.generated.last().unwrap()
        }
    }

    fn done(&self) -> bool {
        self.generated.len() >= self.max_new
    }
}

/// Serving metrics.
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub steps: usize,
    pub tokens_processed: usize,
    pub step_seconds: Vec<f64>,
    pub batch_occupancy: Vec<f64>,
    pub completed: usize,
    pub peak_state_bytes: usize,
}

impl ServerStats {
    pub fn tokens_per_second(&self) -> f64 {
        let total: f64 = self.step_seconds.iter().sum();
        if total == 0.0 {
            0.0
        } else {
            self.tokens_processed as f64 / total
        }
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        if self.step_seconds.is_empty() {
            None
        } else {
            Some(Summary::of(&self.step_seconds))
        }
    }
}

/// Synchronous decode server (single engine thread — the testbed has one
/// core; the queue/batcher interfaces are thread-safe by construction).
pub struct DecodeServer {
    model: ModelHandle,
    policy: BatchPolicy,
    queue: RequestQueue<GenRequest>,
    running: Vec<Seq>,
    finished: Vec<GenResult>,
    pub stats: ServerStats,
    state_numels: Vec<usize>,
    /// memory accounting: live (non-zero) blocks per state stack
    dense_state_bytes_per_seq: usize,
}

impl DecodeServer {
    pub fn new(rt: &Runtime, mut model: ModelHandle, policy: BatchPolicy) -> Result<DecodeServer> {
        for &b in &policy.buckets {
            model.ensure_decode(rt, b)?;
        }
        let state_numels: Vec<usize> = model
            .manifest
            .state_shapes
            .iter()
            .map(|s| s.iter().product())
            .collect();
        let dense: usize = state_numels.iter().sum::<usize>() * 4;
        Ok(DecodeServer {
            model,
            policy,
            queue: RequestQueue::new(),
            running: Vec::new(),
            finished: Vec::new(),
            stats: ServerStats::default(),
            state_numels,
            dense_state_bytes_per_seq: dense,
        })
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.running.len()
    }

    /// Admit queued requests (zero states) up to the largest bucket.
    fn admit(&mut self) {
        let cap = *self.policy.buckets.last().unwrap();
        if self.running.len() >= cap {
            return;
        }
        for req in self.queue.take(cap - self.running.len()) {
            let states = self
                .state_numels
                .iter()
                .map(|&n| vec![0.0f32; n])
                .collect();
            self.running.push(Seq {
                id: req.id,
                prompt: req.prompt,
                generated: Vec::new(),
                pos: 0,
                states,
                max_new: req.max_new,
                submitted: Instant::now(),
                steps: 0,
            });
        }
    }

    /// Run one engine iteration; returns how many sequences advanced.
    pub fn step(&mut self) -> Result<usize> {
        self.admit();
        let ready = self.running.len();
        let bucket = match self.policy.plan(ready, self.queue.oldest_age()) {
            Some(b) => b,
            None if ready > 0 => *self.policy.buckets.first().unwrap().max(&1),
            None => return Ok(0),
        };
        let n = ready.min(bucket);
        let layers = self.state_numels.len();

        // gather
        let mut tokens = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        let mut batched: Vec<Vec<f32>> = self
            .state_numels
            .iter()
            .map(|&numel| vec![0.0f32; bucket * numel])
            .collect();
        for (i, seq) in self.running.iter().take(n).enumerate() {
            tokens[i] = seq.next_token();
            pos[i] = seq.pos as i32;
            for (l, st) in seq.states.iter().enumerate() {
                let numel = self.state_numels[l];
                batched[l][i * numel..(i + 1) * numel].copy_from_slice(st);
            }
        }

        // execute
        let t0 = Instant::now();
        let logits = self.model.decode_step(bucket, &mut batched, &tokens, &pos)?;
        let dt = t0.elapsed().as_secs_f64();

        // scatter + sample
        let vocab = logits.len() / bucket;
        let mut retired = Vec::new();
        for i in 0..n {
            let seq = &mut self.running[i];
            for l in 0..layers {
                let numel = self.state_numels[l];
                seq.states[l].copy_from_slice(&batched[l][i * numel..(i + 1) * numel]);
            }
            seq.pos += 1;
            seq.steps += 1;
            // still prefilling? only sample once the prompt is consumed
            if seq.pos >= seq.prompt.len() {
                let row = &logits[i * vocab..(i + 1) * vocab];
                let tok = crate::tensor::ops::argmax(row) as i32;
                seq.generated.push(tok);
            }
            if seq.done() {
                retired.push(i);
            }
        }
        for &i in retired.iter().rev() {
            let seq = self.running.swap_remove(i);
            self.finished.push(GenResult {
                id: seq.id,
                tokens: seq.generated,
                latency: seq.submitted.elapsed().as_secs_f64(),
                steps: seq.steps,
            });
            self.stats.completed += 1;
        }

        self.stats.steps += 1;
        self.stats.tokens_processed += n;
        self.stats.step_seconds.push(dt);
        self.stats.batch_occupancy.push(n as f64 / bucket as f64);
        let live_bytes = self.running.len() * self.dense_state_bytes_per_seq;
        self.stats.peak_state_bytes = self.stats.peak_state_bytes.max(live_bytes);
        Ok(n)
    }

    /// Drive until all submitted work completes; returns the results.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        while self.pending() > 0 {
            self.step()?;
        }
        Ok(std::mem::take(&mut self.finished))
    }

    pub fn take_finished(&mut self) -> Vec<GenResult> {
        std::mem::take(&mut self.finished)
    }

    pub fn model(&self) -> &ModelHandle {
        &self.model
    }

    /// Results sorted by id (BTreeMap for determinism in demos).
    pub fn results_by_id(results: Vec<GenResult>) -> BTreeMap<u64, GenResult> {
        results.into_iter().map(|r| (r.id, r)).collect()
    }
}
