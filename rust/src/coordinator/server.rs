//! The decode engine: continuous batching over a pluggable
//! [`DecodeBackend`] with per-sequence Fenwick states.
//!
//! The server owns the request queue, the bucketed batch policy, greedy
//! sampling, retirement, and metrics; the backend owns state storage and
//! the batched step itself (PJRT artifacts via [`PjrtBackend`], or the
//! pure-Rust pooled engine via
//! [`PooledBackend`](super::backend::PooledBackend) — see
//! `coordinator::backend`).
//!
//! Scheduling properties (regression-tested below):
//! - **Budgeted chunk ingestion interleaves with decode**: when the
//!   backend has a chunkwise prefill path
//!   ([`DecodeBackend::prefill_chunk_size`] > 0), sequences whose
//!   remaining prompt still holds a full chunk (plus the final token the
//!   decode step needs) advance through [`DecodeBackend::prefill_chunk`]
//!   — state-only, off the decode bucket — while the running decode rows
//!   step in the same loop iteration. Prompt work is **flop-budgeted**:
//!   at most [`BatchPolicy::prefill_budget`] chunks advance per engine
//!   step (generation prompts and scoring work combined), round-robin
//!   fair across sequences, so MANY concurrent long prompts cannot crowd
//!   out decode latency — and decode traffic still cannot stall prompt
//!   ingestion (each step grants the budget before planning the decode
//!   bucket). The sub-chunk prompt tail (and the final prompt token,
//!   whose logits seed sampling) feed through the decode step as before.
//! - **Prompt scoring never enters the decode loop**: a
//!   [`ScoreRequest`] ingests its full chunks through
//!   [`DecodeBackend::score_chunk`] (per-token logits straight from the
//!   sequential stack's chunk outputs) under the same chunk budget, then
//!   token-steps its sub-chunk tail via [`DecodeBackend::score_tail`] —
//!   producing per-token log-probs without ever occupying a decode
//!   bucket row. Tail logits are bit-exact with the decode rows the same
//!   prompt would produce (same boundary, same token machinery).
//! - **Round-robin fairness**: processed survivors go to the back of the
//!   running list each step, so when `ready > bucket` the tail advances
//!   on the next step instead of starving behind a fixed prefix.
//! - **The batch policy's hold is honored**: when
//!   [`BatchPolicy::plan`](super::batcher::BatchPolicy::plan) says wait
//!   for a fuller bucket, the engine *waits* (bounded by `max_wait` via
//!   the hold clock) instead of immediately running a padded bucket —
//!   occupancy under bursty traffic is the point of dynamic batching.
//! - **Admission backpressure**: a backend may refuse admission
//!   ([`AdmitError::Exhausted`], e.g. state-pool exhaustion); the request
//!   stays queued, FIFO order intact, until capacity frees up.
//! - **Degenerate requests**: empty prompts are rejected at submit;
//!   `max_new == 0` (and 1-token score prompts) complete immediately
//!   without touching the engine.
//! - **Streaming + cancellation**: every sampled token is emitted as a
//!   [`StreamEvent::Token`] the moment its decode step lands (drained
//!   via [`DecodeServer::take_stream_events`]), and
//!   [`DecodeServer::cancel`] tears a request down mid-flight — its
//!   backend slot retires immediately, so a cancelled sequence's private
//!   state blocks return to the pool without waiting for `max_new`.
//!   Cancellation reaches **scoring** traffic too: a queued or
//!   mid-flight [`ScoreRequest`] cancels the same way (immediate slot
//!   retirement, [`StreamEvent::Cancelled`], no [`ScoreResult`]) —
//!   scoring requests used to be un-cancellable and held their backend
//!   slot until completion.
//! - **Live ids are unique**: `submit`/`submit_score` reject an id that
//!   is still queued, running, or scoring
//!   ([`SubmitError::DuplicateId`]) — stream events, per-request
//!   timelines, and `cancel` all key on the id, so a duplicate would
//!   make cancellation remove an arbitrary first match. Finished ids
//!   may be reused.
//! - **Prefix-cache admission**: admission goes through
//!   [`DecodeBackend::admit_prompt`]; when the backend reports `cached`
//!   leading prompt tokens already covered by cached boundary states
//!   (see `PooledBackend::enable_prefix_cache`), the sequence starts at
//!   `pos = cached` — those tokens are never fed again, counted in
//!   [`ServerStats::prefix_cache_hits`] /
//!   [`ServerStats::prefill_tokens_saved`].

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::obs::{self, LogHistogram, Metric, Registry, SpanCat};
use crate::runtime::{ModelHandle, Runtime};
use crate::util::stats::Summary;

use super::backend::{fold_score_logprobs, AdmitError, DecodeBackend, PjrtBackend, SeqSlot};
use super::batcher::{BatchPolicy, RequestQueue};
use super::{GenRequest, GenResult, ScoreRequest, ScoreResult, StreamEvent, SubmitError};

struct Seq {
    id: u64,
    prompt: Vec<i32>,
    generated: Vec<i32>,
    /// index of the next token to feed (position of that token)
    pos: usize,
    /// backend-side state handle
    slot: SeqSlot,
    max_new: usize,
    submitted: Instant,
    /// when the most recent generated token streamed (None until the
    /// first) — drives the TTFT / inter-token latency histograms
    last_token_at: Option<Instant>,
    /// engine advances: prefill chunks + decode rows (reported in results)
    steps: usize,
    /// decode rows only — the "is a batch mid-generation" signal the
    /// batcher's hold logic keys on (prefill chunks must NOT defeat the
    /// hold: a prompt streaming chunks is not a running decode batch)
    decode_steps: usize,
}

impl Seq {
    /// next token to feed: prompt token while prefilling, else last sample
    fn next_token(&self) -> i32 {
        if self.pos < self.prompt.len() {
            self.prompt[self.pos]
        } else {
            *self
                .generated
                .last()
                .expect("non-empty prompt + max_new >= 1 guarantee a sample before feedback")
        }
    }

    fn done(&self) -> bool {
        self.generated.len() >= self.max_new
    }
}

/// One in-flight scoring request: chunk position, accumulated log-probs,
/// and the backend slot holding its stack/tail states.
struct ScoreSeq {
    id: u64,
    tokens: Vec<i32>,
    pos: usize,
    slot: SeqSlot,
    logprobs: Vec<f32>,
    chunks: usize,
    submitted: Instant,
    done: bool,
}

/// Serving metrics.
///
/// Latency-shaped series are streaming [`LogHistogram`] accumulators —
/// fixed memory on a long-lived server (they used to be unbounded
/// `Vec<f64>` sample logs), with exact n/mean/min/max and log-bucketed
/// p50/p90/p99 still available to benches via
/// [`ServerStats::latency_summary`] / [`LogHistogram::summary`].
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub steps: usize,
    pub tokens_processed: usize,
    /// decode-step wall time (seconds per executed engine step)
    pub step_seconds: LogHistogram,
    /// decode bucket occupancy (`rows / bucket`) per executed step
    pub batch_occupancy: LogHistogram,
    /// time-to-first-token: submit → first streamed token, per request
    pub ttft_seconds: LogHistogram,
    /// gap between consecutive streamed tokens of the same request
    pub inter_token_seconds: LogHistogram,
    /// submit → admission wait, per admitted request (queue time under
    /// holds and backpressure)
    pub queue_wait_seconds: LogHistogram,
    /// admission attempts refused by backend backpressure
    /// ([`AdmitError::Exhausted`]) — the queue head stayed queued
    pub admission_refusals: usize,
    pub completed: usize,
    pub peak_state_bytes: usize,
    /// prompt chunks ingested through the chunkwise prefill path
    pub prefill_chunks: usize,
    /// prompt tokens those chunks covered (not counted in
    /// `tokens_processed`, which tracks decode-step rows)
    pub prefill_tokens: usize,
    /// completed scoring requests
    pub score_requests: usize,
    /// scoring chunks ingested (budgeted alongside prefill chunks)
    pub score_chunks: usize,
    /// prompt tokens scored (across completed scoring requests)
    pub score_tokens: usize,
    /// admissions that reused prefix-cached state (backend returned a
    /// non-zero cached-token count from `admit_prompt`)
    pub prefix_cache_hits: usize,
    /// prompt tokens never prefilled because cached boundary states
    /// covered them (summed over all hits)
    pub prefill_tokens_saved: usize,
    /// requests cancelled via [`DecodeServer::cancel`] (queued or
    /// mid-flight)
    pub cancelled: usize,
    /// backend state-store occupancy (pool blocks) at the last sample
    pub pool_in_use: usize,
    /// peak backend state-store occupancy observed by the backend
    pub pool_peak: usize,
}

impl ServerStats {
    pub fn tokens_per_second(&self) -> f64 {
        let total = self.step_seconds.sum();
        if total == 0.0 {
            0.0
        } else {
            self.tokens_processed as f64 / total
        }
    }

    /// Decode-step latency summary (`None` before the first step).
    /// Moments and extrema are exact; p50/p90/p99 are log-bucketed
    /// (≤ ~9% relative error).
    pub fn latency_summary(&self) -> Option<Summary> {
        self.step_seconds.summary()
    }

    pub fn mean_occupancy(&self) -> f64 {
        self.batch_occupancy.mean()
    }

    /// Time-to-first-token summary (`None` until a token streamed).
    pub fn ttft_summary(&self) -> Option<Summary> {
        self.ttft_seconds.summary()
    }

    /// Snapshot every serving metric into an [`obs::Registry`] — one
    /// enumerable document for export
    /// ([`Registry::to_json`] / [`Registry::render_table`]) instead of
    /// a bag of struct fields.
    pub fn registry(&self) -> Registry {
        let mut reg = Registry::new();
        for (name, v) in [
            ("steps", self.steps),
            ("tokens_processed", self.tokens_processed),
            ("completed", self.completed),
            ("prefill_chunks", self.prefill_chunks),
            ("prefill_tokens", self.prefill_tokens),
            ("score_requests", self.score_requests),
            ("score_chunks", self.score_chunks),
            ("score_tokens", self.score_tokens),
            ("prefix_cache_hits", self.prefix_cache_hits),
            ("prefill_tokens_saved", self.prefill_tokens_saved),
            ("admission_refusals", self.admission_refusals),
            ("cancelled", self.cancelled),
        ] {
            let id = reg.counter(name);
            reg.inc(id, v as u64);
        }
        for (name, v) in [
            ("tokens_per_second", self.tokens_per_second()),
            ("peak_state_bytes", self.peak_state_bytes as f64),
            ("pool_in_use", self.pool_in_use as f64),
            ("pool_peak", self.pool_peak as f64),
        ] {
            let id = reg.gauge(name);
            reg.set(id, v);
        }
        for (name, h) in [
            ("step_seconds", &self.step_seconds),
            ("batch_occupancy", &self.batch_occupancy),
            ("ttft_seconds", &self.ttft_seconds),
            ("inter_token_seconds", &self.inter_token_seconds),
            ("queue_wait_seconds", &self.queue_wait_seconds),
        ] {
            let id = reg.histogram(name);
            if let Some(Metric::Histogram(slot)) = reg.get_mut(id) {
                *slot = h.clone();
            }
        }
        reg
    }
}

/// Synchronous decode server (single engine thread — the queue/batcher
/// interfaces are thread-safe by construction), generic over the decode
/// backend.
pub struct DecodeServer<B: DecodeBackend> {
    backend: B,
    policy: BatchPolicy,
    queue: RequestQueue<GenRequest>,
    running: Vec<Seq>,
    finished: Vec<GenResult>,
    score_queue: RequestQueue<ScoreRequest>,
    scoring: Vec<ScoreSeq>,
    finished_scores: Vec<ScoreResult>,
    pub stats: ServerStats,
    /// when the current "wait for a fuller bucket" hold started
    hold_since: Option<Instant>,
    /// rotation cursor for the budgeted prefill/scoring pass
    prefill_rr: usize,
    /// record every decode row's logits (differential-test hook)
    capture_logits: bool,
    /// captured (sequence id, position, logits) rows, in execution order
    logit_log: Vec<(u64, usize, Vec<f32>)>,
    /// incremental events (token/finished/cancelled) awaiting drain
    stream: Vec<StreamEvent>,
}

impl DecodeServer<PjrtBackend> {
    /// The AOT/PJRT server (compiles decode executables for every policy
    /// bucket up front).
    pub fn new(rt: &Runtime, model: ModelHandle, policy: BatchPolicy) -> Result<DecodeServer<PjrtBackend>> {
        let backend = PjrtBackend::new(rt, model, &policy.buckets)?;
        Ok(DecodeServer::with_backend(backend, policy))
    }

    pub fn model(&self) -> &ModelHandle {
        self.backend.model()
    }
}

impl<B: DecodeBackend> DecodeServer<B> {
    pub fn with_backend(backend: B, policy: BatchPolicy) -> DecodeServer<B> {
        DecodeServer {
            backend,
            policy,
            queue: RequestQueue::new(),
            running: Vec::new(),
            finished: Vec::new(),
            score_queue: RequestQueue::new(),
            scoring: Vec::new(),
            finished_scores: Vec::new(),
            stats: ServerStats::default(),
            hold_since: None,
            prefill_rr: 0,
            capture_logits: false,
            logit_log: Vec::new(),
            stream: Vec::new(),
        }
    }

    /// Record every decode row's logits from here on — the serving-trace
    /// differential harness compares them bit-for-bit against a
    /// per-sequence oracle replay (see `coordinator::trace`). Test-scale
    /// traffic only: every row's `(id, position, logits)` is kept.
    pub fn enable_logit_capture(&mut self) {
        self.capture_logits = true;
    }

    /// Drain the captured `(id, position, logits)` rows (execution order).
    pub fn take_captured_logits(&mut self) -> Vec<(u64, usize, Vec<f32>)> {
        std::mem::take(&mut self.logit_log)
    }

    /// Drain the incremental serving events accumulated since the last
    /// drain, in emission order: every sampled token the moment its
    /// decode step lands ([`StreamEvent::Token`]), completions
    /// ([`StreamEvent::Finished`]), and cancellations
    /// ([`StreamEvent::Cancelled`]). Streaming consumers call this
    /// between engine steps for per-token delivery.
    pub fn take_stream_events(&mut self) -> Vec<StreamEvent> {
        std::mem::take(&mut self.stream)
    }

    /// Cancel a request wherever it is: still queued (it is dequeued and
    /// never admitted) or mid-flight (its backend slot is retired
    /// **immediately**, handing the sequence's private state blocks back
    /// to the pool — shared prefix-cache blocks just drop a refcount).
    /// Generation *and* scoring requests cancel the same way: a queued
    /// [`ScoreRequest`] is dequeued, a mid-flight one retires its slot
    /// and produces no [`ScoreResult`] (already-streamed
    /// [`StreamEvent::Score`] rows stay delivered). Emits
    /// [`StreamEvent::Cancelled`]; a cancelled generation produces no
    /// [`GenResult`]. Returns false only if `id` is not live anywhere
    /// (unknown or already finished).
    pub fn cancel(&mut self, id: u64) -> bool {
        if self.queue.remove_first(|r| r.id == id).is_some()
            || self.score_queue.remove_first(|r| r.id == id).is_some()
        {
            obs::instant(SpanCat::Cancel, id);
            self.stats.cancelled += 1;
            self.stream.push(StreamEvent::Cancelled { id });
            return true;
        }
        let slot = if let Some(i) = self.running.iter().position(|s| s.id == id) {
            self.running.remove(i).slot
        } else if let Some(i) = self.scoring.iter().position(|s| s.id == id) {
            self.scoring.remove(i).slot
        } else {
            return false;
        };
        obs::instant(SpanCat::Cancel, id);
        self.backend.retire(slot);
        let (in_use, peak) = self.backend.pool_occupancy();
        self.stats.pool_in_use = in_use;
        self.stats.pool_peak = peak;
        self.stats.cancelled += 1;
        self.stream.push(StreamEvent::Cancelled { id });
        true
    }

    /// Is `id` live anywhere in the server (queued, running, or
    /// scoring)? Finished/cancelled ids are not live — they may be
    /// reused by a later submit.
    fn id_is_live(&self, id: u64) -> bool {
        self.queue.any(|r| r.id == id)
            || self.running.iter().any(|s| s.id == id)
            || self.score_queue.any(|r| r.id == id)
            || self.scoring.iter().any(|s| s.id == id)
    }

    /// Enqueue a request. Empty prompts are rejected (there is no token
    /// to feed at position 0); an id that is already live anywhere in
    /// the server is rejected ([`SubmitError::DuplicateId`]);
    /// `max_new == 0` completes immediately.
    pub fn submit(&mut self, req: GenRequest) -> Result<(), SubmitError> {
        if req.prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt);
        }
        if self.id_is_live(req.id) {
            return Err(SubmitError::DuplicateId);
        }
        obs::instant(SpanCat::Submit, req.id);
        if req.max_new == 0 {
            self.finished.push(GenResult {
                id: req.id,
                tokens: Vec::new(),
                latency: 0.0,
                steps: 0,
            });
            self.stats.completed += 1;
            self.stream.push(StreamEvent::Finished { id: req.id });
            return Ok(());
        }
        self.queue.push(req);
        Ok(())
    }

    /// Enqueue a prompt-scoring request (per-token log-probs, no decode).
    /// Empty prompts are rejected; an id that is already live anywhere
    /// in the server is rejected ([`SubmitError::DuplicateId`]); a
    /// 1-token prompt has nothing to score and completes immediately
    /// with empty log-probs.
    pub fn submit_score(&mut self, req: ScoreRequest) -> Result<(), SubmitError> {
        if !self.backend.supports_scoring() {
            return Err(SubmitError::ScoringUnsupported);
        }
        if req.tokens.is_empty() {
            return Err(SubmitError::EmptyPrompt);
        }
        if self.id_is_live(req.id) {
            return Err(SubmitError::DuplicateId);
        }
        obs::instant(SpanCat::Submit, req.id);
        if req.tokens.len() == 1 {
            self.finished_scores.push(ScoreResult {
                id: req.id,
                logprobs: Vec::new(),
                latency: 0.0,
                chunks: 0,
            });
            self.stats.score_requests += 1;
            self.stats.score_tokens += 1;
            return Ok(());
        }
        self.score_queue.push(req);
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.running.len() + self.score_queue.len() + self.scoring.len()
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Mutable backend access (configuration between traffic runs —
    /// e.g. dropping a pooled backend's prefix cache).
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// (id, position, steps) of every running sequence, in scheduling
    /// order — monitoring + fairness regression tests.
    pub fn running_progress(&self) -> Vec<(u64, usize, usize)> {
        self.running.iter().map(|s| (s.id, s.pos, s.steps)).collect()
    }

    /// Admit queued requests, FIFO, stopping at the first the backend
    /// refuses (resource backpressure keeps it — and everything behind
    /// it — queued). The running set is allowed to exceed the largest
    /// bucket by 2× (continuous-batching headroom: retirements backfill
    /// from already-admitted sequences, round-robined through the
    /// bucket, instead of paying admission latency).
    fn admit(&mut self) -> Result<()> {
        let cap = 2 * *self.policy.buckets.last().unwrap();
        while self.running.len() < cap {
            let Some(req) = self.queue.peek() else { break };
            let max_steps = req.prompt.len() + req.max_new - 1;
            // prompt-aware admission: a backend with a prefix-state
            // cache may hand back `cached` leading prompt tokens whose
            // boundary state it already holds — the server skips feeding
            // them (neither prefill chunks nor decode rows re-cover a
            // cached position)
            let (slot, cached) = match self.backend.admit_prompt(max_steps.max(1), &req.prompt) {
                Ok(r) => r,
                Err(AdmitError::Exhausted) => {
                    self.stats.admission_refusals += 1;
                    break;
                }
                Err(AdmitError::TooLarge) => {
                    // drop the impossible request before erroring so it
                    // can't wedge the queue head: the caller sees the
                    // failure once, traffic behind it still serves
                    let req = self.queue.pop().expect("peeked above");
                    bail!(
                        "request {} needs more decode state than the backend can ever hold \
                         ({} steps); request dropped",
                        req.id,
                        max_steps
                    );
                }
            };
            if cached > 0 {
                self.stats.prefix_cache_hits += 1;
                self.stats.prefill_tokens_saved += cached;
            }
            // keep the queue-entry timestamp: latency must include the
            // time a request waited under backpressure/holds
            let (req, submitted) = self.queue.pop_timed().expect("peeked above");
            let waited = submitted.elapsed();
            self.stats.queue_wait_seconds.record(waited.as_secs_f64());
            let now_ns = obs::now_ns();
            obs::record_closed(
                SpanCat::QueueWait,
                now_ns.saturating_sub(waited.as_nanos() as u64),
                now_ns,
                req.id,
            );
            obs::instant(SpanCat::Admit, req.id);
            debug_assert!(cached < req.prompt.len(), "cache may not cover the final prompt token");
            self.running.push(Seq {
                id: req.id,
                prompt: req.prompt,
                generated: Vec::new(),
                pos: cached,
                slot,
                max_new: req.max_new,
                submitted,
                last_token_at: None,
                steps: 0,
                decode_steps: 0,
            });
        }
        Ok(())
    }

    /// Admit queued scoring requests (same 2× headroom cap; scoring never
    /// holds pool blocks on the pooled backend, so Exhausted is rare but
    /// honored the same way).
    fn admit_scores(&mut self) -> Result<()> {
        if !self.backend.supports_scoring() {
            return Ok(());
        }
        let cap = 2 * *self.policy.buckets.last().unwrap();
        while self.scoring.len() < cap && self.score_queue.peek().is_some() {
            match self.backend.score_admit() {
                Ok(slot) => {
                    let (req, submitted) = self.score_queue.pop_timed().expect("peeked above");
                    let waited = submitted.elapsed();
                    self.stats.queue_wait_seconds.record(waited.as_secs_f64());
                    let now_ns = obs::now_ns();
                    obs::record_closed(
                        SpanCat::QueueWait,
                        now_ns.saturating_sub(waited.as_nanos() as u64),
                        now_ns,
                        req.id,
                    );
                    obs::instant(SpanCat::Admit, req.id);
                    self.scoring.push(ScoreSeq {
                        id: req.id,
                        tokens: req.tokens,
                        pos: 0,
                        slot,
                        logprobs: Vec::new(),
                        chunks: 0,
                        submitted,
                        done: false,
                    });
                }
                Err(AdmitError::Exhausted) => {
                    self.stats.admission_refusals += 1;
                    break;
                }
                Err(AdmitError::TooLarge) => {
                    let req = self.score_queue.pop().expect("peeked above");
                    bail!("score request {} rejected by the backend; request dropped", req.id);
                }
            }
        }
        Ok(())
    }

    /// Still at least one full prefill chunk (plus the final prompt token
    /// the decode step needs for sampling) ahead of this sequence?
    fn mid_prefill(seq: &Seq, chunk: usize) -> bool {
        chunk > 0 && seq.pos % chunk == 0 && seq.pos + chunk < seq.prompt.len()
    }

    /// Advance one scoring sequence by one budgeted work unit: a full
    /// chunk through `score_chunk` (logits folded into log-probs), or the
    /// sub-chunk tail through `score_tail` — which completes the request.
    fn advance_score(&mut self, i: usize, chunk: usize) -> Result<()> {
        let (id, slot, pos, len) = {
            let sc = &self.scoring[i];
            (sc.id, sc.slot, sc.pos, sc.tokens.len())
        };
        let streamed = self.scoring[i].logprobs.len();
        if chunk > 0 && pos % chunk == 0 && pos + chunk < len {
            let toks: Vec<i32> = self.scoring[i].tokens[pos..pos + chunk].to_vec();
            let logits = {
                let _sp = obs::span(SpanCat::ScoreChunk, id);
                self.backend.score_chunk(slot, &toks, pos)?
            };
            let sc = &mut self.scoring[i];
            // row r predicts the token at position pos + r + 1; the one
            // shared fold (the scoring oracle runs the same helper)
            fold_score_logprobs(&logits, chunk, &sc.tokens, pos, &mut sc.logprobs);
            sc.pos += chunk;
            sc.chunks += 1;
            self.stats.score_chunks += 1;
        } else {
            // tail: token-step positions pos..len−1 (the final token is
            // never fed — nothing reads after it), then finish
            let toks: Vec<i32> = self.scoring[i].tokens[pos..len - 1].to_vec();
            let logits = {
                let _sp = obs::span(SpanCat::ScoreChunk, id);
                self.backend.score_tail(slot, &toks, pos)?
            };
            let sc = &mut self.scoring[i];
            fold_score_logprobs(&logits, toks.len(), &sc.tokens, pos, &mut sc.logprobs);
            sc.pos = len;
            sc.done = true;
        }
        // row-by-row score streaming: every log-prob this work unit
        // produced goes out the moment it lands, not only on completion
        let sc = &self.scoring[i];
        for (index, &logprob) in sc.logprobs.iter().enumerate().skip(streamed) {
            obs::instant(SpanCat::StreamEmit, id);
            self.stream.push(StreamEvent::Score { id, index, logprob });
        }
        Ok(())
    }

    /// Run one engine iteration; returns how many sequences advanced —
    /// decode rows plus budgeted ingest units (prefill chunks + scoring
    /// work; 0 while the batcher holds out for a fuller bucket and no
    /// ingest work exists).
    pub fn step(&mut self) -> Result<usize> {
        self.admit()?;
        self.admit_scores()?;

        // ---- budgeted ingest pass: generation prompts still a full
        // chunk away from their last prompt token, plus scoring
        // sequences, share BatchPolicy::prefill_budget chunk-units per
        // step, round-robin fair (at most one unit per sequence per
        // step). These don't occupy the decode bucket, so long prompts
        // and the running decode rows advance in the same iteration —
        // but bounded prompt flops per step keep decode latency flat no
        // matter how many long prompts are in flight.
        let chunk = self.backend.prefill_chunk_size();
        let mut ingest_units = 0usize;
        {
            #[derive(Clone, Copy)]
            enum Item {
                Gen(usize),
                Score(usize),
            }
            let mut items: Vec<Item> = Vec::new();
            if chunk > 0 {
                for (i, s) in self.running.iter().enumerate() {
                    if Self::mid_prefill(s, chunk) {
                        items.push(Item::Gen(i));
                    }
                }
            }
            for i in 0..self.scoring.len() {
                items.push(Item::Score(i));
            }
            if !items.is_empty() {
                let rot = self.prefill_rr % items.len();
                items.rotate_left(rot);
                for &it in items.iter().take(self.policy.prefill_budget) {
                    match it {
                        Item::Gen(i) => {
                            let (id, slot, pos, tokens) = {
                                let s = &self.running[i];
                                (s.id, s.slot, s.pos, s.prompt[s.pos..s.pos + chunk].to_vec())
                            };
                            {
                                let _sp = obs::span(SpanCat::PrefillChunk, id);
                                self.backend.prefill_chunk(slot, &tokens, pos)?;
                            }
                            let seq = &mut self.running[i];
                            seq.pos += chunk;
                            seq.steps += 1;
                            self.stats.prefill_chunks += 1;
                            self.stats.prefill_tokens += chunk;
                        }
                        Item::Score(i) => self.advance_score(i, chunk)?,
                    }
                    ingest_units += 1;
                }
                // skipped items lead the next step's grant order
                self.prefill_rr = self.prefill_rr.wrapping_add(ingest_units.max(1));
                // stack/scoring states live outside the pool; sample the
                // peak here too, since a held iteration exits early
                self.stats.peak_state_bytes =
                    self.stats.peak_state_bytes.max(self.backend.state_bytes());
            }
        }
        // retire completed scoring requests
        if self.scoring.iter().any(|s| s.done) {
            let old = std::mem::take(&mut self.scoring);
            for sc in old {
                if sc.done {
                    self.backend.retire(sc.slot);
                    self.stats.score_requests += 1;
                    self.stats.score_tokens += sc.tokens.len();
                    self.finished_scores.push(ScoreResult {
                        id: sc.id,
                        logprobs: sc.logprobs,
                        latency: sc.submitted.elapsed().as_secs_f64(),
                        chunks: sc.chunks,
                    });
                } else {
                    self.scoring.push(sc);
                }
            }
        }

        // ---- decode pass over everything past its prefill chunks
        let decode_idx: Vec<usize> = self
            .running
            .iter()
            .enumerate()
            .filter(|(_, s)| !Self::mid_prefill(s, chunk))
            .map(|(i, _)| i)
            .collect();
        let ready = decode_idx.len();
        // the hold clock: how long runnable work has been waiting — the
        // queue's oldest age while queued, the hold timer once admitted
        let held = self.hold_since.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);
        let waited = self.queue.oldest_age().max(held);
        // a hold only ever applies to a *fresh* batch (no decode row
        // executed yet): once any sequence is mid-generation, stalling it
        // for max_wait on every plan refusal — or on every new arrival —
        // would collapse decode throughput to one step per max_wait.
        // Ingest units deliberately don't count: a prompt streaming
        // chunks (or a scoring request) is not a running decode batch, so
        // the hold still gets to gather a fuller first bucket while long
        // prompts ingest.
        let in_flight = self.running.iter().any(|s| s.decode_steps > 0);
        let bucket = match self.policy.plan(ready, waited) {
            Some(b) => {
                self.hold_since = None;
                b
            }
            None if ready > 0 && in_flight => {
                self.hold_since = None;
                // force expired-hold planning: smallest covering bucket
                match self.policy.plan(ready, self.policy.max_wait) {
                    Some(b) => b,
                    None => return Ok(ingest_units), // unreachable: expired plan with ready > 0 is Some
                }
            }
            None => {
                if ready > 0 && self.hold_since.is_none() {
                    // start the hold the policy asked for; max_wait later
                    // plan() will release it
                    self.hold_since = Some(Instant::now());
                }
                return Ok(ingest_units);
            }
        };
        let n = ready.min(bucket);

        // gather the scheduling prefix of the decode-ready list
        // (processed survivors go to the back after each step, so over
        // consecutive steps this round-robins the batch)
        let sched: Vec<usize> = decode_idx[..n].to_vec();
        let rows: Vec<(SeqSlot, i32, i32)> = sched
            .iter()
            .map(|&i| {
                let s = &self.running[i];
                (s.slot, s.next_token(), s.pos as i32)
            })
            .collect();

        // execute
        let t0 = Instant::now();
        let logits = {
            let _sp = obs::span(SpanCat::DecodeStep, n as u64);
            self.backend.step(bucket, &rows)?
        };
        let dt = t0.elapsed().as_secs_f64();

        // sample + advance. The backend contract is pinned, not
        // inferred: it reports its vocab and must return exactly one
        // vocab-sized row per SCHEDULED sequence (n rows), even when the
        // planned bucket is larger (padded rows never come back). The
        // old `vocab = logits.len() / n` derivation silently mis-split
        // rows when a backend returned `bucket * vocab` entries.
        let vocab = self.backend.vocab();
        ensure!(
            logits.len() == n * vocab,
            "backend decode contract violated: {} logits for {} scheduled rows x vocab {} \
             (planned bucket {}; padded rows must not be returned)",
            logits.len(),
            n,
            vocab,
            bucket
        );
        for (j, &i) in sched.iter().enumerate() {
            let seq = &mut self.running[i];
            if self.capture_logits {
                self.logit_log.push((seq.id, seq.pos, logits[j * vocab..(j + 1) * vocab].to_vec()));
            }
            seq.pos += 1;
            seq.steps += 1;
            seq.decode_steps += 1;
            // still feeding prompt? only sample once the prompt is consumed
            if seq.pos >= seq.prompt.len() {
                let row = &logits[j * vocab..(j + 1) * vocab];
                let tok = crate::tensor::ops::argmax(row) as i32;
                seq.generated.push(tok);
                let now = Instant::now();
                match seq.last_token_at {
                    None => self
                        .stats
                        .ttft_seconds
                        .record(now.duration_since(seq.submitted).as_secs_f64()),
                    Some(prev) => self
                        .stats
                        .inter_token_seconds
                        .record(now.duration_since(prev).as_secs_f64()),
                }
                seq.last_token_at = Some(now);
                // stream the token the moment its step lands
                obs::instant(SpanCat::StreamEmit, seq.id);
                self.stream.push(StreamEvent::Token {
                    id: seq.id,
                    index: seq.generated.len() - 1,
                    token: tok,
                });
            }
        }
        // retire finished sequences and move processed survivors to the
        // back (so the unprocessed tail leads the next step) in one O(R)
        // compaction pass — no per-row Vec::remove shifting
        let mut scheduled = vec![false; self.running.len()];
        for &i in &sched {
            scheduled[i] = true;
        }
        let old = std::mem::take(&mut self.running);
        let mut processed_survivors: Vec<Seq> = Vec::with_capacity(n);
        for (i, seq) in old.into_iter().enumerate() {
            if !scheduled[i] {
                self.running.push(seq);
            } else if seq.done() {
                self.backend.retire(seq.slot);
                self.stream.push(StreamEvent::Finished { id: seq.id });
                self.finished.push(GenResult {
                    id: seq.id,
                    tokens: seq.generated,
                    latency: seq.submitted.elapsed().as_secs_f64(),
                    steps: seq.steps,
                });
                self.stats.completed += 1;
            } else {
                processed_survivors.push(seq);
            }
        }
        self.running.extend(processed_survivors);

        self.stats.steps += 1;
        self.stats.tokens_processed += n;
        self.stats.step_seconds.record(dt);
        self.stats.batch_occupancy.record(n as f64 / bucket as f64);
        self.stats.peak_state_bytes = self.stats.peak_state_bytes.max(self.backend.state_bytes());
        let (in_use, peak) = self.backend.pool_occupancy();
        self.stats.pool_in_use = in_use;
        self.stats.pool_peak = peak;
        Ok(n + ingest_units)
    }

    /// Drive until all submitted work completes; returns the generation
    /// results (scoring results via
    /// [`DecodeServer::take_score_results`]). While the batcher holds for
    /// a fuller bucket, naps briefly so the hold can expire (bounded by
    /// the policy's `max_wait`).
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        while self.pending() > 0 {
            if self.step()? == 0 {
                let nap = (self.policy.max_wait / 8)
                    .clamp(Duration::from_micros(50), Duration::from_millis(5));
                std::thread::sleep(nap);
            }
        }
        Ok(std::mem::take(&mut self.finished))
    }

    pub fn take_finished(&mut self) -> Vec<GenResult> {
        std::mem::take(&mut self.finished)
    }

    /// Completed scoring results, in completion order.
    pub fn take_score_results(&mut self) -> Vec<ScoreResult> {
        std::mem::take(&mut self.finished_scores)
    }

    /// Results sorted by id (BTreeMap for determinism in demos).
    pub fn results_by_id(results: Vec<GenResult>) -> BTreeMap<u64, GenResult> {
        results.into_iter().map(|r| (r.id, r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{tok_index, PooledBackend, TransitionKind};
    use crate::tensor::ops;

    fn pooled_server(pool_blocks: usize, buckets: Vec<usize>, max_wait: Duration) -> DecodeServer<PooledBackend> {
        let backend = PooledBackend::new(64, 8, 8, pool_blocks, 7);
        DecodeServer::with_backend(backend, BatchPolicy::new(buckets, max_wait))
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: (0..prompt_len as i32).map(|i| (id as i32 * 13 + i * 7) % 64).collect(),
            max_new,
        }
    }

    #[test]
    fn round_robin_rotates_the_tail_into_the_batch() {
        // 12 sequences share bucket 8: under the old fixed-prefix gather
        // the tail 4 never advanced until the head retired.
        let mut srv = pooled_server(256, vec![8], Duration::ZERO);
        for id in 0..12 {
            srv.submit(req(id, 2, 4)).unwrap();
        }
        srv.step().unwrap();
        srv.step().unwrap();
        let progress = srv.running_progress();
        assert_eq!(progress.len(), 12);
        for (id, pos, steps) in progress {
            assert!(steps >= 1, "seq {id} starved after two steps (pos {pos})");
        }
        // and everything completes with the same per-sequence step count
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 12);
        for r in &results {
            assert_eq!(r.steps, 2 + 4 - 1, "req {}", r.id);
            assert_eq!(r.tokens.len(), 4);
        }
    }

    #[test]
    fn hold_for_fuller_bucket_is_honored_and_improves_occupancy() {
        // bursty traffic: 3 requests arrive, then 5 more. The held server
        // must not run a padded 3/4 bucket immediately.
        let mut held = pooled_server(256, vec![1, 4, 8], Duration::from_secs(5));
        for id in 0..3 {
            held.submit(req(id, 2, 2)).unwrap();
        }
        assert_eq!(held.step().unwrap(), 0, "must hold for a fuller bucket");
        assert_eq!(held.stats.steps, 0, "a held step must not record a batch");
        for id in 3..8 {
            held.submit(req(id, 2, 2)).unwrap();
        }
        assert_eq!(held.step().unwrap(), 8, "full bucket runs immediately");
        let results = held.run_to_completion().unwrap();
        assert_eq!(results.len(), 8);
        assert!(
            held.stats.batch_occupancy.min() == 1.0 && held.stats.batch_occupancy.max() == 1.0,
            "held server should only run full buckets: {:?}",
            held.stats.batch_occupancy.summary()
        );

        // same traffic with max_wait = 0 (the old always-run-now
        // behavior): strictly worse occupancy
        let mut eager = pooled_server(256, vec![1, 4, 8], Duration::ZERO);
        for id in 0..3 {
            eager.submit(req(id, 2, 2)).unwrap();
        }
        eager.step().unwrap();
        for id in 3..8 {
            eager.submit(req(id, 2, 2)).unwrap();
        }
        let _ = eager.run_to_completion().unwrap();
        assert!(
            held.stats.mean_occupancy() > eager.stats.mean_occupancy(),
            "hold must improve occupancy: held {} vs eager {}",
            held.stats.mean_occupancy(),
            eager.stats.mean_occupancy()
        );
    }

    #[test]
    fn hold_never_stalls_in_flight_sequences() {
        // The hold applies to a *fresh* batch exactly once: after the
        // first executed step, neither plan refusals nor new arrivals may
        // pause the running batch for another max_wait.
        let mut srv = pooled_server(256, vec![1, 4, 8], Duration::from_millis(2));
        for id in 0..4 {
            srv.submit(req(id, 2, 4)).unwrap();
        }
        assert_eq!(srv.step().unwrap(), 0, "initial hold for a fuller bucket");
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(srv.step().unwrap(), 4, "hold expires once");
        // consecutive sub-bucket steps run back-to-back, no fresh hold
        assert_eq!(srv.step().unwrap(), 4, "re-armed hold stalled a running batch");
        // a trickle arrival joins immediately instead of re-arming the hold
        srv.submit(req(4, 2, 4)).unwrap();
        assert_eq!(srv.step().unwrap(), 5, "new arrival must not stall in-flight sequences");
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 5);
        for r in &results {
            assert_eq!(r.steps, 2 + 4 - 1, "req {}", r.id);
        }
    }

    #[test]
    fn prefill_chunks_do_not_defeat_the_batchers_hold() {
        // Long prompts stream prefill chunks while the batcher holds for
        // a fuller bucket. Prefill steps are not decode progress, so the
        // hold must survive them (a regression here would re-introduce
        // the padded-bucket eagerness the hold exists to prevent), and
        // the first decode batch runs only once the bucket fills.
        let backend = PooledBackend::with_config(64, 1, 8, 8, 4, 512, 7);
        let mut srv = DecodeServer::with_backend(
            backend,
            BatchPolicy::new(vec![1, 4, 8], Duration::from_secs(5)),
        );
        for id in 0..3 {
            srv.submit(req(id, 10, 2)).unwrap(); // 2 chunks + a 2-token tail
        }
        assert_eq!(srv.step().unwrap(), 3, "chunk 1 of each prompt");
        assert_eq!(srv.step().unwrap(), 3, "chunk 2; decode now holds at 3/8");
        assert_eq!(srv.stats.steps, 0, "held decode batch must not have run");
        assert_eq!(
            srv.step().unwrap(),
            0,
            "prefill steps must not arm in_flight and break the hold"
        );
        assert_eq!(srv.stats.steps, 0);
        // five more arrivals prefill, then fill the bucket: the first
        // decode batch runs full
        for id in 3..8 {
            srv.submit(req(id, 10, 2)).unwrap();
        }
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 8);
        assert!(
            srv.stats.batch_occupancy.min() == 1.0 && srv.stats.batch_occupancy.max() == 1.0,
            "held server should only run full decode buckets: {:?}",
            srv.stats.batch_occupancy.summary()
        );
        for r in &results {
            assert_eq!(r.tokens.len(), 2, "req {}", r.id);
            assert_eq!(r.steps, 2 + 3, "req {}: 2 chunks + 3 decode rows", r.id);
        }
    }

    #[test]
    fn lone_request_still_completes_after_max_wait() {
        // the hold is bounded: a single request must not wait forever
        let mut srv = pooled_server(64, vec![1, 4], Duration::from_millis(2));
        srv.submit(req(0, 3, 2)).unwrap();
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].tokens.len(), 2);
    }

    #[test]
    fn empty_prompt_rejected_and_zero_max_new_short_circuits() {
        let mut srv = pooled_server(64, vec![4], Duration::ZERO);
        assert_eq!(
            srv.submit(GenRequest { id: 1, prompt: vec![], max_new: 5 }),
            Err(SubmitError::EmptyPrompt)
        );
        // max_new == 0 retires cleanly without ever touching the engine
        srv.submit(GenRequest { id: 2, prompt: vec![1, 2], max_new: 0 }).unwrap();
        assert_eq!(srv.pending(), 0);
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].id, 2);
        assert!(results[0].tokens.is_empty());
        assert_eq!(results[0].steps, 0);
        assert_eq!(srv.stats.steps, 0, "no engine step for a zero-length generation");
        // scoring degenerate cases mirror: empty rejected, 1-token
        // completes immediately with nothing to score
        assert_eq!(
            srv.submit_score(ScoreRequest { id: 3, tokens: vec![] }),
            Err(SubmitError::EmptyPrompt)
        );
        srv.submit_score(ScoreRequest { id: 4, tokens: vec![5] }).unwrap();
        assert_eq!(srv.pending(), 0);
        let scores = srv.take_score_results();
        assert_eq!(scores.len(), 1);
        assert_eq!(scores[0].id, 4);
        assert!(scores[0].logprobs.is_empty());
    }

    #[test]
    fn pool_backpressure_defers_admission_and_everything_completes() {
        // Each request needs blocks_for_steps(2+3-1) = 3 blocks; a
        // 7-block pool admits at most 2 at a time. All 6 must still
        // complete, FIFO-fairly, with the pool never over-committed.
        let mut srv = pooled_server(7, vec![4], Duration::ZERO);
        for id in 0..6 {
            srv.submit(req(id, 2, 3)).unwrap();
        }
        let mut max_running = 0;
        let mut max_in_use = 0;
        let mut guard = 0;
        while srv.pending() > 0 {
            srv.step().unwrap();
            max_running = max_running.max(srv.running_progress().len());
            max_in_use = max_in_use.max(srv.backend().pool().in_use());
            guard += 1;
            assert!(guard < 200, "no forward progress under backpressure");
        }
        assert!(max_running <= 2, "admission over-committed: {max_running} concurrent");
        assert!(max_in_use <= 7, "pool over-committed: {max_in_use} blocks");
        assert!(srv.stats.admission_refusals > 0, "backpressure must be counted");
        let results = srv.take_finished();
        assert_eq!(results.len(), 6);
        assert_eq!(srv.backend().pool().in_use(), 0, "retirement leaked pool blocks");
        for r in &results {
            assert_eq!(r.tokens.len(), 3, "req {}", r.id);
        }
    }

    #[test]
    fn oversized_request_fails_loudly_without_wedging_the_queue() {
        // needs blocks_for_steps(1+200-1) = 8 blocks > 4-block pool
        let mut srv = pooled_server(4, vec![4], Duration::ZERO);
        srv.submit(req(0, 1, 200)).unwrap();
        srv.submit(req(1, 2, 2)).unwrap();
        srv.submit(req(2, 2, 2)).unwrap();
        assert!(srv.step().is_err(), "impossible request must error, not spin");
        // the poisoned request was dropped: traffic behind it still serves
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 2);
        for r in &results {
            assert_eq!(r.tokens.len(), 2, "req {}", r.id);
        }
    }

    #[test]
    fn long_prefill_interleaves_with_decode_rows() {
        // One long prompt (4 full chunks of 8 + a 3-token tail) next to
        // three short decoding requests: every engine step must advance
        // BOTH the prefill (exactly one chunk) and every decode row —
        // chunked prefill may not starve in-flight decode, and decode may
        // not stall prompt ingestion.
        let backend = PooledBackend::with_config(64, 2, 8, 8, 8, 512, 11);
        let mut srv =
            DecodeServer::with_backend(backend, BatchPolicy::new(vec![4], Duration::ZERO));
        srv.submit(req(0, 8 * 4 + 3, 2)).unwrap();
        for id in 1..4 {
            srv.submit(req(id, 2, 12)).unwrap();
        }
        for step in 1..=3usize {
            srv.step().unwrap();
            let prog = srv.running_progress();
            let &(_, pos0, _) = prog.iter().find(|(id, _, _)| *id == 0).unwrap();
            assert_eq!(pos0, 8 * step, "prefill must advance one chunk per engine step");
            for &(id, pos, steps) in &prog {
                if id != 0 {
                    assert_eq!(steps, step, "decode seq {id} starved at step {step} (pos {pos})");
                }
            }
            assert_eq!(srv.backend().prefilling(), 1, "id 0 still mid-prefill");
        }
        // step 4: the last chunk ingests (pos 24 → 32), after which the
        // tail no longer holds a full chunk, so id 0 joins the decode
        // batch in the same iteration (pos 32 → 33) and flips to pooled
        // decode states via the export bridge
        srv.step().unwrap();
        let prog = srv.running_progress();
        let &(_, pos0, _) = prog.iter().find(|(id, _, _)| *id == 0).unwrap();
        assert_eq!(pos0, 33, "tail decode must start the moment chunks are exhausted");
        assert_eq!(srv.backend().prefilling(), 0, "export bridge must have run");
        assert_eq!(srv.stats.prefill_chunks, 4);
        assert_eq!(srv.stats.prefill_tokens, 32);

        let results = DecodeServer::<PooledBackend>::results_by_id(srv.run_to_completion().unwrap());
        assert_eq!(results.len(), 4);
        assert_eq!(results[&0].tokens.len(), 2);
        // 4 chunk-steps + 4 decode rows (tail 32/33/34 + one feedback)
        assert_eq!(results[&0].steps, 4 + 4, "req 0 step accounting");
        for id in 1..4u64 {
            assert_eq!(results[&id].tokens.len(), 12, "req {id}");
            assert_eq!(results[&id].steps, 2 + 12 - 1, "req {id}");
        }
        assert_eq!(srv.backend().pool().in_use(), 0, "retirement leaked pool blocks");
    }

    #[test]
    fn prefill_budget_caps_chunk_work_and_decode_never_starves() {
        // THE flop-budget regression: 8 long prompts next to a live
        // decode bucket of 4. With prefill_budget = 2, every step must
        // (a) grant at most 2 chunks, (b) still run the decode batch,
        // and (c) rotate the grant across prompts so none starves.
        let backend = PooledBackend::with_config(64, 1, 8, 8, 4, 4096, 7);
        let policy = BatchPolicy::new(vec![1, 4, 8], Duration::ZERO).with_prefill_budget(2);
        let mut srv = DecodeServer::with_backend(backend, policy);
        for id in 0..4 {
            srv.submit(req(id, 2, 40)).unwrap(); // short prompts, long decode
        }
        srv.step().unwrap(); // decode batch is live
        assert_eq!(srv.stats.steps, 1);
        for id in 4..12 {
            srv.submit(req(id, 4 * 6 + 2, 2)).unwrap(); // 6 chunks + 2-token tail
        }
        for i in 0..8 {
            let chunks_before = srv.stats.prefill_chunks;
            let decode_before = srv.stats.steps;
            srv.step().unwrap();
            let granted = srv.stats.prefill_chunks - chunks_before;
            assert_eq!(granted, 2, "step {i}: budget must be saturated with 8 prompts waiting");
            assert_eq!(srv.stats.steps, decode_before + 1, "step {i}: decode batch starved");
        }
        // 16 chunks round-robined over 8 prompts: every prompt advanced
        // exactly 2 chunks — no starvation, no favoritism
        let prog = srv.running_progress();
        for id in 4..12u64 {
            let &(_, pos, _) = prog.iter().find(|(pid, _, _)| *pid == id).unwrap();
            assert_eq!(pos, 8, "prompt {id} not fairly rotated (pos {pos})");
        }
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 12);
        assert_eq!(srv.backend().pool().in_use(), 0, "retirement leaked pool blocks");
    }

    #[test]
    fn chunked_prefill_is_deterministic_across_batch_schedules_with_per_token_gates() {
        // Multi-head + chunked prefill + a per-token α/λ schedule: the
        // same request decoded alone and inside a batch of 8 must yield
        // identical tokens (prefill GEMMs are per-sequence, the batched
        // read is bit-exact, and both paths read one GateTable).
        use crate::state::GateTable;
        use crate::tensor::Mat;
        use crate::util::Rng;
        let gates = || {
            let mut grng = Rng::new(0x6A7E);
            let alpha: Vec<f32> = (0..64).map(|_| grng.range_f32(0.9, 1.0)).collect();
            let lambda = Mat::rand_uniform(64, 8, 0.05, 1.0, &mut grng);
            GateTable::per_token(alpha, lambda)
        };
        let server = |buckets: Vec<usize>| {
            let mut backend = PooledBackend::with_config(64, 2, 8, 8, 4, 512, 7);
            backend.set_gates(gates());
            DecodeServer::with_backend(backend, BatchPolicy::new(buckets, Duration::ZERO))
        };
        let solo_tokens = {
            let mut srv = server(vec![1]);
            srv.submit(req(3, 11, 5)).unwrap(); // 2 chunks + 3-token tail
            let results = srv.run_to_completion().unwrap();
            results.into_iter().next().unwrap().tokens
        };
        let mut srv = server(vec![8]);
        for id in 0..8 {
            srv.submit(req(id, 11, 5)).unwrap();
        }
        let results = DecodeServer::<PooledBackend>::results_by_id(srv.run_to_completion().unwrap());
        assert_eq!(results[&3].tokens, solo_tokens, "batching changed a prefilled decode");
        assert!(srv.stats.prefill_chunks > 0, "prompts this long must prefill chunkwise");
    }

    #[test]
    fn pooled_decode_is_deterministic_across_batch_schedules() {
        // The same request decoded alone and inside a big batch must
        // yield identical tokens (batched read is bit-exact and per-row
        // logits don't depend on batchmates).
        let solo_tokens = {
            let mut srv = pooled_server(64, vec![1], Duration::ZERO);
            srv.submit(req(3, 4, 6)).unwrap();
            let results = srv.run_to_completion().unwrap();
            results.into_iter().next().unwrap().tokens
        };
        let mut srv = pooled_server(256, vec![8], Duration::ZERO);
        for id in 0..8 {
            srv.submit(req(id, 4, 6)).unwrap();
        }
        let results = DecodeServer::<PooledBackend>::results_by_id(srv.run_to_completion().unwrap());
        assert_eq!(results[&3].tokens, solo_tokens, "batching changed a sequence's decode");
    }

    /// Drive a server until its scoring work drains, returning the
    /// results sorted by id.
    fn run_scores<B: DecodeBackend>(srv: &mut DecodeServer<B>) -> Vec<ScoreResult> {
        let mut guard = 0;
        while srv.pending() > 0 {
            srv.step().unwrap();
            guard += 1;
            assert!(guard < 10_000, "scoring made no progress");
        }
        let mut out = srv.take_score_results();
        out.sort_by_key(|r| r.id);
        out
    }

    #[test]
    fn score_logprobs_match_token_by_token_decode_replay_bit_exact() {
        // With chunked prefill DISABLED, both the decode path and the
        // scoring path are per-token recurrences over the same sequential
        // stack — so a prompt's score log-probs must equal the log-probs
        // computed from the captured decode logits EXACTLY (f32 equality,
        // no tolerance), for both transition families and L = 2 layers.
        for (seed, kind) in [(21u64, TransitionKind::Mamba2), (22, TransitionKind::Gdn)] {
            let mk = || {
                PooledBackend::with_model_config(64, 2, 2, kind, 8, 8, 0, 4096, seed)
            };
            let prompt: Vec<i32> = (0..9).map(|i| (i * 11 + 3) % 64).collect();
            // decode replay: feed the whole prompt through decode steps
            let mut srv = DecodeServer::with_backend(
                mk(),
                BatchPolicy::new(vec![1], Duration::ZERO),
            );
            srv.enable_logit_capture();
            srv.submit(GenRequest { id: 0, prompt: prompt.clone(), max_new: 1 }).unwrap();
            srv.run_to_completion().unwrap();
            let captured = srv.take_captured_logits();
            let vocab = captured[0].2.len();
            let mut want = Vec::new();
            for p in 1..prompt.len() {
                let row = &captured.iter().find(|(_, pos, _)| *pos == p - 1).unwrap().2;
                want.push(-ops::cross_entropy(row, tok_index(prompt[p], vocab)));
            }
            // score on a fresh identical server
            let mut ssrv = DecodeServer::with_backend(
                mk(),
                BatchPolicy::new(vec![1], Duration::ZERO),
            );
            ssrv.submit_score(ScoreRequest { id: 0, tokens: prompt.clone() }).unwrap();
            let res = run_scores(&mut ssrv);
            assert_eq!(res.len(), 1);
            assert_eq!(res[0].logprobs, want, "{kind:?}: score != decode replay");
            // and the one-shot oracle agrees bit-for-bit too
            assert_eq!(
                res[0].logprobs,
                ssrv.backend().oracle_score_logprobs(&prompt),
                "{kind:?}: score != oracle"
            );
        }
    }

    #[test]
    fn chunked_score_matches_oracle_and_decode_tail_bit_exact() {
        // With chunked prefill ON: (a) the served score equals the
        // one-shot scoring oracle bit-for-bit (scheduling independence —
        // interleaved budgeted chunks change nothing), and (b) the
        // sub-chunk tail log-probs equal the captured decode rows of the
        // same prompt served as a generation request, bit-for-bit (score
        // and decode share the prefill boundary and the token machinery).
        for (seed, kind) in [(31u64, TransitionKind::Mamba2), (32, TransitionKind::Gdn)] {
            let mk = || {
                PooledBackend::with_model_config(64, 2, 2, kind, 8, 8, 4, 4096, seed)
            };
            let prompt: Vec<i32> = (0..11).map(|i| (i * 7 + 5) % 64).collect(); // pe = 8
            let mut gsrv = DecodeServer::with_backend(
                mk(),
                BatchPolicy::new(vec![1], Duration::ZERO),
            );
            gsrv.enable_logit_capture();
            gsrv.submit(GenRequest { id: 0, prompt: prompt.clone(), max_new: 1 }).unwrap();
            gsrv.run_to_completion().unwrap();
            let captured = gsrv.take_captured_logits();
            let vocab = captured[0].2.len();

            let mut ssrv = DecodeServer::with_backend(
                mk(),
                BatchPolicy::new(vec![1], Duration::ZERO),
            );
            // a second scoring request rides along so budgeted
            // round-robin interleaving is actually exercised
            ssrv.submit_score(ScoreRequest { id: 0, tokens: prompt.clone() }).unwrap();
            ssrv.submit_score(ScoreRequest { id: 1, tokens: prompt[..7].to_vec() }).unwrap();
            let res = run_scores(&mut ssrv);
            assert_eq!(res.len(), 2);
            assert_eq!(res[0].logprobs.len(), prompt.len() - 1);
            assert_eq!(res[0].chunks, 2, "11-token prompt at C=4 scores 2 chunks");
            assert_eq!(
                res[0].logprobs,
                ssrv.backend().oracle_score_logprobs(&prompt),
                "{kind:?}: served score != one-shot oracle"
            );
            assert_eq!(
                res[1].logprobs,
                ssrv.backend().oracle_score_logprobs(&prompt[..7]),
                "{kind:?}: interleaved score != one-shot oracle"
            );
            // tail positions (8, 9 → targets 9, 10) match decode rows
            let pe = ssrv.backend().prefill_boundary(prompt.len());
            assert_eq!(pe, 8);
            for p in pe + 1..prompt.len() {
                let row = &captured.iter().find(|(_, pos, _)| *pos == p - 1).unwrap().2;
                let want = -ops::cross_entropy(row, tok_index(prompt[p], vocab));
                assert_eq!(
                    res[0].logprobs[p - 1],
                    want,
                    "{kind:?}: tail target {p} != decode replay"
                );
            }
            assert!(ssrv.stats.score_chunks > 0);
            assert_eq!(ssrv.stats.score_requests, 2);
            assert_eq!(ssrv.backend().pool().in_use(), 0, "scoring must not hold pool blocks");
        }
    }

    #[test]
    fn scoring_interleaves_with_generation_traffic() {
        // Score requests share the budgeted ingest pass with generation
        // prompts; both kinds of work complete and the score result is
        // scheduling-independent (equals the one-shot oracle).
        let backend = PooledBackend::with_model_config(
            64, 2, 2, TransitionKind::Mamba2, 8, 8, 4, 4096, 41,
        );
        let policy = BatchPolicy::new(vec![4], Duration::ZERO).with_prefill_budget(2);
        let mut srv = DecodeServer::with_backend(backend, policy);
        let long: Vec<i32> = (0..23).map(|i| (i * 5 + 2) % 64).collect();
        for id in 0..4 {
            srv.submit(req(id, 14, 6)).unwrap();
        }
        srv.submit_score(ScoreRequest { id: 100, tokens: long.clone() }).unwrap();
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 4);
        let scores = srv.take_score_results();
        assert_eq!(scores.len(), 1);
        assert_eq!(scores[0].logprobs, srv.backend().oracle_score_logprobs(&long));
        assert_eq!(srv.backend().pool().in_use(), 0);
    }

    #[test]
    fn scoring_unsupported_backend_rejects_at_submit() {
        // A backend without a scoring path refuses at submit time instead
        // of erroring mid-loop.
        struct NoScore;
        impl DecodeBackend for NoScore {
            fn admit(&mut self, _max_steps: usize) -> Result<SeqSlot, AdmitError> {
                Ok(SeqSlot(0))
            }
            fn retire(&mut self, _slot: SeqSlot) {}
            fn vocab(&self) -> usize {
                1
            }
            fn step(&mut self, _bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>> {
                Ok(vec![0.0; rows.len()])
            }
            fn state_bytes(&self) -> usize {
                0
            }
        }
        let mut srv = DecodeServer::with_backend(NoScore, BatchPolicy::new(vec![1], Duration::ZERO));
        assert_eq!(
            srv.submit_score(ScoreRequest { id: 0, tokens: vec![1, 2, 3] }),
            Err(SubmitError::ScoringUnsupported)
        );
    }

    fn event_id(e: &StreamEvent) -> u64 {
        match *e {
            StreamEvent::Token { id, .. }
            | StreamEvent::Score { id, .. }
            | StreamEvent::Finished { id }
            | StreamEvent::Cancelled { id } => id,
        }
    }

    #[test]
    fn stream_events_deliver_every_token_incrementally_then_finished() {
        let mut srv = pooled_server(256, vec![4], Duration::ZERO);
        for id in 0..3 {
            srv.submit(req(id, 3, 5)).unwrap();
        }
        // drain between steps: tokens must arrive while requests are
        // still in flight, not only at completion
        let mut events = Vec::new();
        let mut saw_token_mid_flight = false;
        let mut guard = 0;
        while srv.pending() > 0 {
            srv.step().unwrap();
            let drained = srv.take_stream_events();
            if srv.pending() > 0
                && drained.iter().any(|e| matches!(e, StreamEvent::Token { .. }))
            {
                saw_token_mid_flight = true;
            }
            events.extend(drained);
            guard += 1;
            assert!(guard < 1000, "no forward progress");
        }
        assert!(saw_token_mid_flight, "streaming must not buffer until completion");
        assert!(srv.take_stream_events().is_empty(), "drain must consume the buffer");
        let results = DecodeServer::<PooledBackend>::results_by_id(srv.take_finished());
        for id in 0..3u64 {
            let evs: Vec<&StreamEvent> =
                events.iter().filter(|e| event_id(e) == id).collect();
            // 5 tokens in index order, then exactly one Finished, last
            assert_eq!(evs.len(), 6, "req {id}: events {evs:?}");
            for (i, e) in evs[..5].iter().enumerate() {
                let StreamEvent::Token { index, token, .. } = e else {
                    panic!("req {id}: expected a token event, got {e:?}");
                };
                assert_eq!(*index, i, "req {id}: out-of-order stream");
                assert_eq!(*token, results[&id].tokens[i], "req {id}: stream/result mismatch");
            }
            assert!(matches!(evs[5], StreamEvent::Finished { .. }), "req {id}: missing finish");
        }
    }

    #[test]
    fn score_rows_stream_incrementally_as_chunks_land() {
        // Row-by-row score streaming: each budgeted scoring work unit
        // (chunk or tail) emits its newly-landed log-prob rows as
        // StreamEvent::Score the moment it completes — in index order,
        // mid-flight, and bit-identical to the final ScoreResult.
        let backend = PooledBackend::with_model_config(
            64, 2, 2, TransitionKind::Mamba2, 8, 8, 4, 4096, 51,
        );
        let mut srv =
            DecodeServer::with_backend(backend, BatchPolicy::new(vec![1], Duration::ZERO));
        let prompt: Vec<i32> = (0..11).map(|i| (i * 7 + 5) % 64).collect(); // 2 chunks + tail
        srv.submit_score(ScoreRequest { id: 9, tokens: prompt.clone() }).unwrap();
        let mut streamed = Vec::new();
        let mut saw_rows_mid_flight = false;
        let mut guard = 0;
        while srv.pending() > 0 {
            srv.step().unwrap();
            let drained = srv.take_stream_events();
            if srv.pending() > 0
                && drained.iter().any(|e| matches!(e, StreamEvent::Score { .. }))
            {
                saw_rows_mid_flight = true;
            }
            for e in drained {
                let StreamEvent::Score { id, index, logprob } = e else {
                    panic!("unexpected event {e:?} in a scoring-only run");
                };
                assert_eq!(id, 9);
                assert_eq!(index, streamed.len(), "rows must stream in index order");
                streamed.push(logprob);
            }
            guard += 1;
            assert!(guard < 100, "scoring made no progress");
        }
        assert!(saw_rows_mid_flight, "rows must stream before completion, not only at the end");
        let res = srv.take_score_results();
        assert_eq!(res.len(), 1);
        assert_eq!(streamed, res[0].logprobs, "streamed rows must equal the final result");
    }

    #[test]
    fn stats_accumulators_and_registry_snapshot() {
        // The latency series are streaming histograms now (fixed memory
        // on a long-lived server): counts must match the event totals,
        // and the registry snapshot must carry every metric as one
        // parseable JSON document.
        let mut srv = pooled_server(256, vec![4], Duration::ZERO);
        for id in 0..4 {
            srv.submit(req(id, 3, 5)).unwrap();
        }
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 4);
        let stats = &srv.stats;
        assert_eq!(stats.step_seconds.count(), stats.steps);
        assert_eq!(stats.batch_occupancy.count(), stats.steps);
        assert_eq!(stats.ttft_seconds.count(), 4, "one TTFT per request");
        assert_eq!(
            stats.inter_token_seconds.count(),
            4 * (5 - 1),
            "one gap per consecutive token pair"
        );
        assert_eq!(stats.queue_wait_seconds.count(), 4, "one wait per admission");
        let lat = stats.latency_summary().expect("steps ran");
        assert!(lat.p99 >= lat.p50 && lat.p50 > 0.0);
        assert!(stats.mean_occupancy() > 0.0 && stats.mean_occupancy() <= 1.0);
        assert!(stats.ttft_summary().is_some());
        let reg = stats.registry();
        assert_eq!(reg.counter_value("completed"), Some(4));
        assert_eq!(
            reg.counter_value("tokens_processed"),
            Some(stats.tokens_processed as u64)
        );
        assert_eq!(reg.histogram_ref("ttft_seconds").unwrap().count(), 4);
        let j = crate::util::json::Json::parse(&reg.to_json().to_string()).unwrap();
        assert_eq!(j.get("completed").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(
            j.get("step_seconds").and_then(|v| v.get("n")).and_then(|v| v.as_f64()),
            Some(stats.steps as f64)
        );
    }

    #[test]
    fn prefix_cache_hits_save_prefill_and_preserve_outputs() {
        let mk = |cache: bool| {
            let mut backend = PooledBackend::with_config(64, 2, 8, 8, 4, 4096, 7);
            if cache {
                backend.enable_prefix_cache();
            }
            DecodeServer::with_backend(backend, BatchPolicy::new(vec![1], Duration::ZERO))
        };
        let prompt: Vec<i32> = (0..13).map(|i| (i * 7 + 3) % 64).collect(); // boundary 12
        let mut srv = mk(true);
        srv.submit(GenRequest { id: 0, prompt: prompt.clone(), max_new: 4 }).unwrap();
        let first = srv.run_to_completion().unwrap();
        assert_eq!(srv.stats.prefix_cache_hits, 0, "first prompt is cold");
        srv.submit(GenRequest { id: 1, prompt: prompt.clone(), max_new: 4 }).unwrap();
        let second = srv.run_to_completion().unwrap();
        assert_eq!(srv.stats.prefix_cache_hits, 1);
        assert_eq!(srv.stats.prefill_tokens_saved, 12);
        assert_eq!(first[0].tokens, second[0].tokens, "cache hit changed the decode");
        // the hit skipped all 3 chunks: 4 decode rows only, vs 3 + 4 cold
        assert_eq!(first[0].steps, 3 + 4);
        assert_eq!(second[0].steps, 4);
        assert!(srv.stats.pool_peak > 0, "occupancy counters must be sampled");
        // a cache-disabled server serves the same tokens
        let mut off = mk(false);
        off.submit(GenRequest { id: 0, prompt: prompt.clone(), max_new: 4 }).unwrap();
        let base = off.run_to_completion().unwrap();
        assert_eq!(base[0].tokens, first[0].tokens, "cache-off baseline diverged");
        assert_eq!(off.stats.prefix_cache_hits, 0);
        assert_eq!(off.stats.prefill_tokens_saved, 0);
    }

    #[test]
    fn cancel_returns_exactly_the_private_blocks_and_emits_cancelled() {
        let mut backend = PooledBackend::with_config(64, 2, 8, 8, 4, 4096, 7);
        backend.enable_prefix_cache();
        let mut srv =
            DecodeServer::with_backend(backend, BatchPolicy::new(vec![1, 2], Duration::ZERO));
        let prompt: Vec<i32> = (0..13).map(|i| (i * 7 + 3) % 64).collect();
        // populate the cache, then verify only cache blocks stay resident
        srv.submit(GenRequest { id: 0, prompt: prompt.clone(), max_new: 2 }).unwrap();
        srv.run_to_completion().unwrap();
        let cache_held = srv.backend().prefix_cache().unwrap().blocks_held();
        assert!(cache_held > 0);
        assert_eq!(srv.backend().pool().in_use(), cache_held);
        // a long-running full hit: adopts shared blocks, CoW makes them
        // private over the first steps
        srv.submit(GenRequest { id: 1, prompt: prompt.clone(), max_new: 50 }).unwrap();
        for _ in 0..6 {
            srv.step().unwrap();
        }
        assert!(
            srv.backend().pool().in_use() > cache_held,
            "a decoding sequence must hold private blocks"
        );
        assert!(srv.cancel(1), "mid-flight cancel");
        assert_eq!(
            srv.backend().pool().in_use(),
            cache_held,
            "cancel must return exactly the cancelled sequence's private blocks"
        );
        assert_eq!(srv.stats.cancelled, 1);
        assert_eq!(srv.pending(), 0, "cancelled sequence must leave the running set");
        assert!(!srv.cancel(1), "a cancelled id is no longer live");
        // queued requests cancel too (dequeued before admission)
        srv.submit(GenRequest { id: 2, prompt: prompt.clone(), max_new: 4 }).unwrap();
        assert!(srv.cancel(2));
        assert_eq!(srv.pending(), 0);
        assert_eq!(srv.stats.cancelled, 2);
        let events = srv.take_stream_events();
        let cancelled: Vec<u64> = events
            .iter()
            .filter(|e| matches!(e, StreamEvent::Cancelled { .. }))
            .map(event_id)
            .collect();
        assert_eq!(cancelled, vec![1, 2]);
        // no GenResult for either cancelled request
        assert!(srv.take_finished().iter().all(|r| r.id == 0));
    }

    #[test]
    fn cancel_reaches_queued_and_mid_flight_scoring_requests() {
        // THE cancel-scoring regression: before the fix, cancel only
        // searched the generation queue and running set, so a scoring id
        // returned false and its backend slot stayed held to completion.
        let backend = PooledBackend::with_model_config(
            64, 2, 2, TransitionKind::Mamba2, 8, 8, 4, 4096, 61,
        );
        let mut srv =
            DecodeServer::with_backend(backend, BatchPolicy::new(vec![1], Duration::ZERO));
        let long: Vec<i32> = (0..23).map(|i| (i * 5 + 2) % 64).collect();
        // queued (never admitted): submit and cancel before any step
        srv.submit_score(ScoreRequest { id: 7, tokens: long.clone() }).unwrap();
        assert!(srv.cancel(7), "a queued scoring request must be cancellable");
        assert_eq!(srv.pending(), 0);
        // mid-flight: admit + a couple of budgeted chunks, then cancel
        srv.submit_score(ScoreRequest { id: 8, tokens: long.clone() }).unwrap();
        srv.step().unwrap();
        srv.step().unwrap();
        assert_eq!(srv.pending(), 1, "id 8 is mid-scoring");
        let held_mid_flight = srv.backend().state_bytes();
        assert!(held_mid_flight > 0, "a mid-flight scoring stack holds state");
        assert!(srv.cancel(8), "a mid-flight scoring request must be cancellable");
        assert_eq!(srv.pending(), 0, "cancelled scoring must leave the scoring set");
        assert!(
            srv.backend().state_bytes() < held_mid_flight,
            "cancel must retire the scoring slot immediately, not at completion"
        );
        assert!(!srv.cancel(8), "a cancelled scoring id is no longer live");
        assert_eq!(srv.stats.cancelled, 2);
        // no ScoreResult for either; the Cancelled events streamed
        assert!(srv.take_score_results().is_empty());
        let cancelled: Vec<u64> = srv
            .take_stream_events()
            .iter()
            .filter(|e| matches!(e, StreamEvent::Cancelled { .. }))
            .map(event_id)
            .collect();
        assert_eq!(cancelled, vec![7, 8]);
        // the retired slot is reusable: the same prompt still scores
        // correctly on the same server
        srv.submit_score(ScoreRequest { id: 9, tokens: long.clone() }).unwrap();
        let res = run_scores(&mut srv);
        assert_eq!(res.len(), 1);
        assert_eq!(res[0].logprobs, srv.backend().oracle_score_logprobs(&long));
    }

    #[test]
    fn duplicate_live_ids_are_rejected_at_submit() {
        // THE duplicate-id regression: before the fix a live id could be
        // resubmitted, after which cancel(id) removed an arbitrary first
        // match and stream-event attribution by id was ambiguous.
        let backend = PooledBackend::with_model_config(
            64, 2, 2, TransitionKind::Mamba2, 8, 8, 4, 4096, 62,
        );
        let mut srv =
            DecodeServer::with_backend(backend, BatchPolicy::new(vec![4], Duration::ZERO));
        srv.submit(req(1, 3, 4)).unwrap();
        // duplicate while queued — and across kinds (gen id blocks score)
        assert_eq!(srv.submit(req(1, 2, 2)), Err(SubmitError::DuplicateId));
        assert_eq!(
            srv.submit_score(ScoreRequest { id: 1, tokens: vec![1, 2, 3] }),
            Err(SubmitError::DuplicateId)
        );
        srv.step().unwrap();
        // duplicate while running
        assert_eq!(srv.submit(req(1, 2, 2)), Err(SubmitError::DuplicateId));
        // scoring ids are part of the live set too
        let long: Vec<i32> = (0..23).map(|i| (i * 5 + 2) % 64).collect();
        srv.submit_score(ScoreRequest { id: 2, tokens: long.clone() }).unwrap();
        srv.step().unwrap(); // admit id 2 into the scoring set
        assert_eq!(
            srv.submit_score(ScoreRequest { id: 2, tokens: long }),
            Err(SubmitError::DuplicateId)
        );
        assert_eq!(srv.submit(req(2, 2, 2)), Err(SubmitError::DuplicateId));
        // a cancelled or finished id is reusable
        assert!(srv.cancel(2));
        srv.submit(req(2, 2, 2)).unwrap();
        let results = srv.run_to_completion().unwrap();
        assert_eq!(results.len(), 2);
        srv.submit(req(1, 2, 2)).unwrap();
        assert_eq!(srv.run_to_completion().unwrap().len(), 1);
    }

    #[test]
    fn step_rejects_backends_returning_padded_logit_rows() {
        // THE logits-contract regression: `step` used to derive
        // `vocab = logits.len() / n`, so a backend returning
        // `bucket * vocab` entries (padded rows) for n < bucket silently
        // mis-split every row. The contract is now pinned: the backend
        // reports vocab and must return exactly n rows.
        struct PaddedRows;
        impl DecodeBackend for PaddedRows {
            fn admit(&mut self, _max_steps: usize) -> Result<SeqSlot, AdmitError> {
                Ok(SeqSlot(0))
            }
            fn retire(&mut self, _slot: SeqSlot) {}
            fn vocab(&self) -> usize {
                3
            }
            fn step(&mut self, bucket: usize, _rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>> {
                // the buggy shape: one row per PLANNED bucket slot
                Ok(vec![0.0; bucket * 3])
            }
            fn state_bytes(&self) -> usize {
                0
            }
        }
        let mut srv =
            DecodeServer::with_backend(PaddedRows, BatchPolicy::new(vec![4], Duration::ZERO));
        // 2 ready rows in a planned bucket of 4: n = 2 < bucket
        srv.submit(req(0, 2, 2)).unwrap();
        srv.submit(req(1, 2, 2)).unwrap();
        let err = srv.step().expect_err("padded logit rows must be rejected");
        assert!(
            err.to_string().contains("decode contract"),
            "unexpected error: {err}"
        );
    }

    /// Serve `prompts` sequentially (29 tokens each, boundary 28,
    /// max_new 2) through a 1-layer 1-head pooled server with
    /// `pool_blocks` capacity; returns each request's tokens plus the
    /// final stats and pool/cache accounting.
    fn serve_under_pressure(
        prompts: &[Vec<i32>],
        pool_blocks: usize,
        cache: bool,
    ) -> (Vec<Vec<i32>>, ServerStats, usize, usize) {
        let mut backend = PooledBackend::with_config(64, 1, 8, 8, 4, pool_blocks, 7);
        if cache {
            backend.enable_prefix_cache();
        }
        let mut srv =
            DecodeServer::with_backend(backend, BatchPolicy::new(vec![1], Duration::ZERO));
        let mut tokens = Vec::new();
        for (id, prompt) in prompts.iter().enumerate() {
            srv.submit(GenRequest { id: id as u64, prompt: prompt.clone(), max_new: 2 }).unwrap();
            let mut res = srv.run_to_completion().unwrap();
            assert_eq!(res.len(), 1, "request {id} must complete under pool pressure");
            tokens.push(res.remove(0).tokens);
        }
        let held = srv.backend().prefix_cache().map(|c| c.blocks_held()).unwrap_or(0);
        let in_use = srv.backend().pool().in_use();
        (tokens, srv.stats.clone(), held, in_use)
    }

    #[test]
    fn cache_eviction_under_pool_pressure_keeps_serving_exact() {
        // Capacity 8 fits one 5-block reservation (blocks_for_steps(30))
        // plus a 3-block cache entry, but NOT two entries plus a live
        // sequence: the third request's first advance must LRU-evict the
        // first prompt's entry mid-serving. The cache-hit request (same
        // prompt as the first) and the evicting request must both decode
        // exactly as a cache-disabled server does.
        let p1: Vec<i32> = (0..29).map(|i| (i * 7 + 3) % 64).collect(); // boundary 28
        let p2: Vec<i32> = (0..29).map(|i| (i * 11 + 5) % 64).collect();
        let traffic = [p1.clone(), p1, p2];
        let (with_cache, stats, held, in_use) = serve_under_pressure(&traffic, 8, true);
        let (baseline, base_stats, _, _) = serve_under_pressure(&traffic, 8, false);
        assert_eq!(with_cache, baseline, "eviction under pressure corrupted a served decode");
        assert_eq!(stats.prefix_cache_hits, 1, "second P1 request must hit");
        assert_eq!(stats.prefill_tokens_saved, 28);
        assert_eq!(base_stats.prefix_cache_hits, 0);
        // P1's entry was evicted for P2's sequence; P2's entry remains —
        // and retirement left exactly those blocks resident
        assert!(held > 0, "P2's boundary must have been cached");
        assert_eq!(in_use, held, "pool must hold exactly the cache's blocks after retirement");
    }

    #[test]
    fn cache_eviction_with_a_live_reader_preserves_adopted_state() {
        // Capacity 5 is exactly one reservation: the cache entry itself
        // is the excess, so the OWNER's first advance forces its own
        // entry out while the owner still shares every block. Eviction
        // only drops the cache's refcounts — the live sequence keeps the
        // bytes and must decode exactly as the cache-disabled baseline.
        // The follow-up identical prompt then finds an empty cache
        // (entries cannot survive at this capacity), not stale handles.
        let p1: Vec<i32> = (0..29).map(|i| (i * 7 + 3) % 64).collect();
        let traffic = [p1.clone(), p1];
        let (with_cache, stats, held, in_use) = serve_under_pressure(&traffic, 5, true);
        let (baseline, base_stats, _, _) = serve_under_pressure(&traffic, 5, false);
        assert_eq!(with_cache, baseline, "live-reader eviction corrupted a served decode");
        assert_eq!(with_cache[0], with_cache[1], "identical prompts must decode identically");
        assert_eq!(stats.prefix_cache_hits, 0, "no entry can survive at this capacity");
        assert_eq!(base_stats.prefix_cache_hits, 0);
        assert_eq!(held, 0);
        assert_eq!(in_use, 0, "everything must return to the pool");
    }
}
