//! Layer-3 serving coordinator.
//!
//! The paper's decoding contribution is the O(log T) Fenwick state
//! recurrence; serving it looks like serving any recurrent LM — except
//! the per-sequence state is a *set of level states* instead of a KV
//! cache, so memory scales with `Σ_seq popcount(t_seq)` rather than
//! `Σ_seq t_seq`. The coordinator mirrors a vLLM-style layout:
//!
//! - [`batcher`]: queueing + bucketed dynamic batching (batch sizes are
//!   bound to AOT-compiled decode artifacts on the PJRT backend; the
//!   pooled backend accepts any bucket),
//! - [`backend`]: pluggable decode engines. [`backend::PjrtBackend`]
//!   gathers per-sequence dense state stacks into batched PJRT buffers
//!   and steps the compiled `decode_step`. [`backend::PooledBackend`] is
//!   the pure-Rust **pooled decode path**: every sequence's live Fenwick
//!   level states are [`crate::state::pool::StatePool`] blocks, each
//!   step reads *all live states of all sequences in the batch* with one
//!   λ-weighted block-sparse GEMM
//!   ([`crate::state::pooled::BatchedDecoder`] — the decode-time
//!   analogue of the chunkwise trainer's `read_levels_into`), and pool
//!   exhaustion surfaces as admission backpressure instead of OOM:
//!   admission reserves `heads · blocks_for_steps(max_steps)` blocks per
//!   sequence and requests wait in the FIFO queue while the pool is
//!   committed. Each step first advances every (sequence, layer, head)
//!   entry's state through the pool-wide batched Fenwick pass
//!   ([`crate::state::BatchedAdvance`] — merges, transitions, and
//!   sentinel writes grouped by level and executed as slab dispatches).
//!   Models are **sequential** L-layer, H-head stacks (layer ℓ+1's
//!   q/k/v are projections of layer ℓ's per-token outputs), Mamba-2 or
//!   GDN ([`backend::TransitionKind`]), with per-layer (optionally
//!   per-head) gate tables; each decode step runs the batched
//!   advance+read per layer and one last-layer logits GEMM. Prompts
//!   ingest **chunkwise** through one sequential
//!   [`crate::prefill::LayerStack`] per sequence
//!   ([`backend::DecodeBackend::prefill_chunk`]; the per-token
//!   chunk-output mode carries outputs layer-to-layer) and flip into
//!   pool blocks via the export bridge on their first decode row.
//!   **Prompt scoring** ([`ScoreRequest`] → [`ScoreResult`]) reuses the
//!   same stack to return per-token log-probs straight from prefill
//!   chunk outputs, never entering the decode loop. The serving-trace
//!   differential suite ([`server`] tests + the `trace` property module)
//!   pins every path to a per-sequence `FenwickState` oracle replay,
//!   bit-exactly.
//! - [`server`]: the engine loop — admits (honoring backpressure),
//!   advances prefill chunks and scoring work under a **per-step chunk
//!   budget** ([`batcher::BatchPolicy::prefill_budget`], round-robin
//!   fair, so many concurrent long prompts cannot crowd out decode
//!   latency), schedules decode rows round-robin through the batch
//!   policy's bucket, samples greedily, retires finished sequences, and
//!   *honors the batcher's hold* (when [`batcher::BatchPolicy::plan`]
//!   says wait for a fuller bucket, the decode batch waits — bounded by
//!   `max_wait` — rather than running padded buckets; prefill chunks
//!   proceed regardless).
//!
//! Rust owns the event loop, queueing, metrics, and memory accounting;
//! Python never runs at serve time.

pub mod backend;
pub mod batcher;
pub mod server;
#[cfg(test)]
mod trace;

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// A prompt-scoring request: per-token log-probs for a fixed token
/// stream, computed from the chunkwise prefill outputs — never entering
/// the decode loop (no sampling, no decode bucket slot).
#[derive(Debug, Clone)]
pub struct ScoreRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
}

/// A finished scoring request. `logprobs[i]` is the natural-log
/// probability `log P(tokens[i+1] | tokens[..=i])` — one entry per token
/// after the first (`tokens.len() − 1` total).
#[derive(Debug, Clone)]
pub struct ScoreResult {
    pub id: u64,
    pub logprobs: Vec<f32>,
    /// wall-clock seconds from submit to completion
    pub latency: f64,
    /// prefill chunks the scoring consumed (the budgeted work units)
    pub chunks: usize,
}

/// One incremental serving event, emitted by the engine loop as it
/// happens and drained by streaming consumers via
/// [`server::DecodeServer::take_stream_events`] — per-token delivery
/// without waiting for the request's [`GenResult`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StreamEvent {
    /// Request `id` sampled its `index`-th generated token (0-based).
    Token { id: u64, index: usize, token: i32 },
    /// Scoring request `id` produced `logprob` for target position
    /// `index + 1` (i.e. `ScoreResult::logprobs[index]`), emitted the
    /// moment its scoring chunk (or tail) lands — row-by-row score
    /// streaming, without waiting for the full [`ScoreResult`].
    Score { id: u64, index: usize, logprob: f32 },
    /// Request `id` completed; its [`GenResult`] is available.
    Finished { id: u64 },
    /// Request `id` was cancelled (mid-flight or still queued); it
    /// produces no [`GenResult`] and its backend resources are already
    /// released.
    Cancelled { id: u64 },
}

/// Why a request was refused at submit time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// No token to feed at position 0 — the engine cannot start an
    /// empty-prompt sequence (and would previously panic deep in
    /// `Seq::next_token`).
    EmptyPrompt,
    /// The backend has no prompt-scoring path
    /// ([`backend::DecodeBackend::supports_scoring`] is false).
    ScoringUnsupported,
    /// The id is already live (queued, running, or scoring). Stream
    /// events, timelines, and [`server::DecodeServer::cancel`] all key on
    /// the id, so a duplicate would make cancellation remove an arbitrary
    /// first match and per-request timeline reconstruction ambiguous.
    /// Finished ids may be reused — only *live* ids collide.
    DuplicateId,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::EmptyPrompt => write!(f, "empty prompt: nothing to decode from"),
            SubmitError::ScoringUnsupported => {
                write!(f, "this backend does not support prompt scoring")
            }
            SubmitError::DuplicateId => {
                write!(f, "request id is already live (queued, running, or scoring)")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// wall-clock seconds from submit to completion
    pub latency: f64,
    /// decode steps executed for this sequence
    pub steps: usize,
}
