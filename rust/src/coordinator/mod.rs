//! Layer-3 serving coordinator.
//!
//! The paper's decoding contribution is the O(log T) Fenwick state
//! recurrence; serving it looks like serving any recurrent LM — except
//! the per-sequence state is a *set of level states* instead of a KV
//! cache, so memory scales with `Σ_seq popcount(t_seq)` rather than
//! `Σ_seq t_seq`. The coordinator mirrors a vLLM-style layout:
//!
//! - [`batcher`]: queueing + bucketed dynamic batching (batch sizes are
//!   bound to AOT-compiled decode artifacts),
//! - [`server`]: the decode engine — gathers per-sequence states into the
//!   batched PJRT buffers, steps the compiled `decode_step`, scatters
//!   states back, samples, and retires finished sequences.
//!
//! Rust owns the event loop, queueing, metrics, and memory accounting;
//! Python never runs at serve time.

pub mod batcher;
pub mod server;

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// wall-clock seconds from submit to completion
    pub latency: f64,
    /// decode steps executed for this sequence
    pub steps: usize,
}
