//! Decode execution backends behind the serving engine.
//!
//! [`DecodeServer`](super::server::DecodeServer) owns queueing, batching,
//! sampling, and retirement; *how* a batch of (token, position) rows is
//! stepped — and how per-sequence state is held — is a [`DecodeBackend`]:
//!
//! - [`PjrtBackend`]: the AOT path. Per-sequence dense state stacks are
//!   gathered into batched PJRT buffers, the compiled `decode_step`
//!   executes, states scatter back. Admission never backpressures (dense
//!   stacks are host `Vec`s) and prompts are ingested token-by-token.
//! - [`PooledBackend`]: the pure-Rust pooled engine. An H-head
//!   single-layer log-linear attention LM whose per-(sequence, head)
//!   Fenwick states live in a shared [`StatePool`]; each decode step is
//!   matmul-rich — one [`BatchedDecoder::read_batch`] block-sparse GEMM
//!   over every live level of every (sequence, head) in the batch, then
//!   one `O_cat @ W_o^T` GEMM for the whole batch's logits. Prompts are
//!   ingested **chunkwise**: [`DecodeBackend::prefill_chunk`] streams full
//!   chunks through a per-sequence head-batched
//!   [`PrefillEngine`](crate::prefill::PrefillEngine) (state-only Alg. 1 —
//!   no logits until the prompt's final token), and the first decode row
//!   flips the sequence to pooled decode states via the export bridge
//!   ([`crate::prefill::bridge::export_prefill_head`]). Position-dependent
//!   gates come from one [`GateTable`] consulted by both paths, so
//!   chunkwise-prefilled and token-stepped sequences follow the same α/λ
//!   schedule. [`DecodeBackend::admit`] reserves
//!   `heads · blocks_for_steps(max_steps)` pool blocks per sequence and
//!   returns [`AdmitError::Exhausted`] when the pool can't hold another
//!   sequence — the backpressure signal the server's admission loop honors
//!   by leaving requests queued.

use anyhow::{bail, Result};

use crate::prefill::bridge::export_prefill_head;
use crate::prefill::PrefillEngine;
use crate::runtime::{ModelHandle, Runtime};
use crate::state::pool::StatePool;
use crate::state::pooled::{blocks_for_steps, BatchedDecoder, PooledFenwickState};
use crate::state::{GateTable, Transition};
use crate::tensor::{self, Mat};
use crate::util::Rng;

/// Backend-side handle for one admitted sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqSlot(pub usize);

/// Why admission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// No resources *right now* — retry once running sequences retire
    /// (the batcher keeps the request queued).
    Exhausted,
    /// The request can never fit this backend (e.g. needs more state
    /// blocks than the whole pool holds) — reject it.
    TooLarge,
}

/// One decode execution engine (state storage + step function).
pub trait DecodeBackend {
    /// Reserve resources for a sequence running at most `max_steps`
    /// decode steps; returns the slot to pass to [`DecodeBackend::step`].
    fn admit(&mut self, max_steps: usize) -> Result<SeqSlot, AdmitError>;

    /// Release a sequence's resources.
    fn retire(&mut self, slot: SeqSlot);

    /// Execute one decode step for `rows` of (slot, token, position) in a
    /// `bucket`-sized batch (`rows.len() <= bucket`; padding, if the
    /// backend needs fixed shapes, is backend-internal). Returns logits
    /// `(rows.len(), vocab)` row-major.
    fn step(&mut self, bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>>;

    /// Resident decode-state bytes right now (peak accounting).
    fn state_bytes(&self) -> usize;

    /// Chunk size for chunked prompt prefill; 0 = unsupported (the server
    /// then feeds prompts token-by-token through [`DecodeBackend::step`],
    /// the pre-prefill behavior).
    fn prefill_chunk_size(&self) -> usize {
        0
    }

    /// Ingest one full prompt chunk for `slot`: `tokens` are the prompt
    /// tokens at positions `pos .. pos + tokens.len()`, state-only (no
    /// logits — the prompt's final token goes through
    /// [`DecodeBackend::step`] to produce the first sample). Only valid
    /// before the sequence's first decode row, with
    /// `tokens.len() == prefill_chunk_size()` and chunk-aligned `pos`.
    fn prefill_chunk(&mut self, slot: SeqSlot, tokens: &[i32], pos: usize) -> Result<()> {
        let _ = (slot, tokens, pos);
        bail!("this backend does not support chunked prefill")
    }
}

// ---------------------------------------------------------------------------
// PJRT (AOT artifact) backend
// ---------------------------------------------------------------------------

/// The compiled-artifact backend: dense per-layer state stacks per
/// sequence, batched through the AOT `decode_step` executables.
pub struct PjrtBackend {
    model: ModelHandle,
    state_numels: Vec<usize>,
    dense_state_bytes_per_seq: usize,
    /// per-slot per-layer flat states (None = free slot)
    slots: Vec<Option<Vec<Vec<f32>>>>,
    free_slots: Vec<usize>,
}

impl PjrtBackend {
    /// Compile the decode executables for every bucket up front.
    pub fn new(rt: &Runtime, mut model: ModelHandle, buckets: &[usize]) -> Result<PjrtBackend> {
        for &b in buckets {
            model.ensure_decode(rt, b)?;
        }
        let state_numels: Vec<usize> = model
            .manifest
            .state_shapes
            .iter()
            .map(|s| s.iter().product())
            .collect();
        let dense = state_numels.iter().sum::<usize>() * 4;
        Ok(PjrtBackend {
            model,
            state_numels,
            dense_state_bytes_per_seq: dense,
            slots: Vec::new(),
            free_slots: Vec::new(),
        })
    }

    pub fn model(&self) -> &ModelHandle {
        &self.model
    }
}

impl DecodeBackend for PjrtBackend {
    fn admit(&mut self, _max_steps: usize) -> Result<SeqSlot, AdmitError> {
        let states: Vec<Vec<f32>> = self.state_numels.iter().map(|&n| vec![0.0f32; n]).collect();
        let idx = match self.free_slots.pop() {
            Some(i) => {
                self.slots[i] = Some(states);
                i
            }
            None => {
                self.slots.push(Some(states));
                self.slots.len() - 1
            }
        };
        Ok(SeqSlot(idx))
    }

    fn retire(&mut self, slot: SeqSlot) {
        assert!(self.slots[slot.0].take().is_some(), "retire of free slot");
        self.free_slots.push(slot.0);
    }

    fn step(&mut self, bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>> {
        let n = rows.len();
        if n == 0 || n > bucket {
            bail!("bad batch: {n} rows for bucket {bucket}");
        }
        let layers = self.state_numels.len();
        // gather into the fixed (bucket, ...) shapes the artifact expects
        let mut tokens = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        let mut batched: Vec<Vec<f32>> = self
            .state_numels
            .iter()
            .map(|&numel| vec![0.0f32; bucket * numel])
            .collect();
        for (i, &(slot, tok, p)) in rows.iter().enumerate() {
            tokens[i] = tok;
            pos[i] = p;
            let st = self.slots[slot.0].as_ref().expect("live slot");
            for (l, layer) in st.iter().enumerate() {
                let numel = self.state_numels[l];
                batched[l][i * numel..(i + 1) * numel].copy_from_slice(layer);
            }
        }
        let mut logits = self.model.decode_step(bucket, &mut batched, &tokens, &pos)?;
        // scatter back
        for (i, &(slot, _, _)) in rows.iter().enumerate() {
            let st = self.slots[slot.0].as_mut().expect("live slot");
            for l in 0..layers {
                let numel = self.state_numels[l];
                st[l].copy_from_slice(&batched[l][i * numel..(i + 1) * numel]);
            }
        }
        // drop padding rows in place — no copy in the full-bucket case
        let vocab = logits.len() / bucket;
        logits.truncate(n * vocab);
        Ok(logits)
    }

    fn state_bytes(&self) -> usize {
        self.slots.iter().flatten().count() * self.dense_state_bytes_per_seq
    }
}

// ---------------------------------------------------------------------------
// Pooled pure-Rust backend
// ---------------------------------------------------------------------------

/// One admitted sequence's backend-side state: a head-batched chunkwise
/// prefill engine while the prompt streams in, then per-head pool-backed
/// decode states (flipped by the export bridge on the first decode row).
enum SeqState {
    Prefilling(PrefillEngine),
    Decoding(Vec<PooledFenwickState>),
}

/// Pure-Rust pooled decode backend: a fixed-weight single-layer H-head
/// log-linear Mamba-2-style LM (random per-head embeddings + output head)
/// whose decode states live in a shared [`StatePool`] and whose prompts
/// ingest chunkwise through per-sequence [`PrefillEngine`]s. Exists to
/// serve real token traffic through the batched Fenwick engines without
/// PJRT — the scheduler/backpressure testbed and the bench engine for
/// `decode_batched` / `prefill_throughput`.
pub struct PooledBackend {
    pub dk: usize,
    pub dv: usize,
    pub vocab: usize,
    pub heads: usize,
    /// per-head query/key/value embeddings, (vocab, dk|dk|dv) each; keys
    /// L2-normalized
    eq: Vec<Mat>,
    ek: Vec<Mat>,
    ev: Vec<Mat>,
    /// output head, (vocab, heads·dv): logits = O_cat @ W_o^T
    wo: Mat,
    /// position-dependent α/λ — the one gate source for prefill AND decode
    gates: GateTable,
    /// chunked-prefill chunk size (power of two; 0 disables)
    prefill_chunk: usize,
    pool: StatePool,
    slots: Vec<Option<SeqState>>,
    free_slots: Vec<usize>,
    /// blocks reserved per live slot (admission accounting)
    reserved: Vec<usize>,
    reserved_total: usize,
    dec: BatchedDecoder,
    // step workspaces (reused across steps; logits are allocated per
    // step because the trait returns an owned Vec)
    q_buf: Vec<f32>,
    o_buf: Vec<f32>,
    // prefill gather workspaces (reused across chunks: the stacked
    // per-head (k, v) embeddings and the chunk's α schedule)
    kc_buf: Vec<f32>,
    vc_buf: Vec<f32>,
    alpha_buf: Vec<f32>,
}

impl PooledBackend {
    /// Single-head backend with the default gates and a 16-token prefill
    /// chunk. `pool_blocks` bounds resident decode memory: admission
    /// reserves `heads · blocks_for_steps(max_steps)` blocks per sequence
    /// against it.
    pub fn new(vocab: usize, dk: usize, dv: usize, pool_blocks: usize, seed: u64) -> PooledBackend {
        PooledBackend::with_config(vocab, 1, dk, dv, 16, pool_blocks, seed)
    }

    /// Fully-configured backend: `heads` attention heads and a
    /// `prefill_chunk`-token chunkwise prefill path (0 disables chunked
    /// prefill; the server then feeds prompts token-by-token).
    pub fn with_config(
        vocab: usize,
        heads: usize,
        dk: usize,
        dv: usize,
        prefill_chunk: usize,
        pool_blocks: usize,
        seed: u64,
    ) -> PooledBackend {
        assert!(heads >= 1, "at least one head");
        assert!(
            prefill_chunk == 0 || prefill_chunk.is_power_of_two(),
            "prefill chunk must be a power of two (or 0 to disable)"
        );
        let mut rng = Rng::new(seed);
        let mut eq = Vec::with_capacity(heads);
        let mut ek = Vec::with_capacity(heads);
        let mut ev = Vec::with_capacity(heads);
        for _ in 0..heads {
            eq.push(Mat::randn(vocab, dk, 1.0 / (dk as f32).sqrt(), &mut rng));
            let mut k = Mat::randn(vocab, dk, 1.0, &mut rng);
            for i in 0..vocab {
                let norm = crate::tensor::ops::l2_norm(k.row(i)).max(1e-6);
                for x in k.row_mut(i) {
                    *x /= norm;
                }
            }
            ek.push(k);
            ev.push(Mat::randn(vocab, dv, 1.0, &mut rng));
        }
        let wo = Mat::randn(vocab, heads * dv, 1.0 / ((heads * dv) as f32).sqrt(), &mut rng);
        // default schedule: fixed α, λ^(l) = 2^-l — coarser levels matter
        // less; wide enough for any practical position (clamped past the
        // table by level_weight)
        let gates = GateTable::fixed(0.97, (0..24).map(|l| 0.5f32.powi(l)).collect());
        PooledBackend {
            dk,
            dv,
            vocab,
            heads,
            eq,
            ek,
            ev,
            wo,
            gates,
            prefill_chunk,
            pool: StatePool::new(dk * dv, pool_blocks),
            slots: Vec::new(),
            free_slots: Vec::new(),
            reserved: Vec::new(),
            reserved_total: 0,
            dec: BatchedDecoder::new(),
            q_buf: Vec::new(),
            o_buf: Vec::new(),
            kc_buf: Vec::new(),
            vc_buf: Vec::new(),
            alpha_buf: Vec::new(),
        }
    }

    /// The shared state pool (inspection: in_use/peak/capacity).
    pub fn pool(&self) -> &StatePool {
        &self.pool
    }

    /// Install a position-dependent gate schedule (per-token α/λ). Both
    /// the chunkwise prefill path and the decode path read it, so the two
    /// ingestion paths cannot drift. Only meaningful before traffic runs.
    pub fn set_gates(&mut self, gates: GateTable) {
        self.gates = gates;
    }

    /// The gate schedule currently in force.
    pub fn gates(&self) -> &GateTable {
        &self.gates
    }

    /// Number of sequences currently mid-prefill (engine states resident
    /// outside the pool).
    pub fn prefilling(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .filter(|s| matches!(s, SeqState::Prefilling(_)))
            .count()
    }

    /// Flip a prefilling slot to decode mode: seal the engine at its
    /// chunk boundary and export every head into pool blocks through the
    /// bridge. No-op for slots already decoding.
    fn ensure_decoding(&mut self, slot: SeqSlot) -> Result<()> {
        if matches!(self.slots[slot.0], Some(SeqState::Decoding(_))) {
            return Ok(());
        }
        let Some(SeqState::Prefilling(mut eng)) = self.slots[slot.0].take() else {
            bail!("step row for a free slot");
        };
        eng.finish();
        let mut seqs = Vec::with_capacity(self.heads);
        for h in 0..self.heads {
            match export_prefill_head(&eng, h, &mut self.pool) {
                Ok(s) => seqs.push(s),
                Err(_) => {
                    // roll back the heads already exported; unreachable
                    // under admission reservation, so surface loudly
                    for mut s in seqs {
                        s.release(&mut self.pool);
                    }
                    bail!("state pool exhausted during prefill export (reservation bug?)");
                }
            }
        }
        self.slots[slot.0] = Some(SeqState::Decoding(seqs));
        Ok(())
    }
}

/// Clamp a sampled/user token into embedding range.
#[inline]
fn tok_index(tok: i32, vocab: usize) -> usize {
    (tok.max(0) as usize).min(vocab - 1)
}

impl DecodeBackend for PooledBackend {
    fn admit(&mut self, max_steps: usize) -> Result<SeqSlot, AdmitError> {
        let need = self.heads * blocks_for_steps(max_steps.max(1));
        if need > self.pool.capacity() {
            return Err(AdmitError::TooLarge);
        }
        if self.reserved_total + need > self.pool.capacity() {
            return Err(AdmitError::Exhausted);
        }
        self.reserved_total += need;
        let idx = match self.free_slots.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.reserved.push(0);
                self.slots.len() - 1
            }
        };
        // a fresh sequence starts in prefill mode when the backend has a
        // chunked-prefill path; with it disabled, decode states from step 0
        self.slots[idx] = Some(if self.prefill_chunk > 0 {
            SeqState::Prefilling(PrefillEngine::new(self.heads, self.dk, self.dv, self.prefill_chunk))
        } else {
            SeqState::Decoding((0..self.heads).map(|_| PooledFenwickState::new(self.dk, self.dv)).collect())
        });
        self.reserved[idx] = need;
        Ok(SeqSlot(idx))
    }

    fn retire(&mut self, slot: SeqSlot) {
        match self.slots[slot.0].take().expect("retire of free slot") {
            SeqState::Prefilling(_) => {} // engine states live outside the pool
            SeqState::Decoding(seqs) => {
                for mut seq in seqs {
                    seq.release(&mut self.pool);
                }
            }
        }
        self.reserved_total -= self.reserved[slot.0];
        self.reserved[slot.0] = 0;
        self.free_slots.push(slot.0);
    }

    fn prefill_chunk_size(&self) -> usize {
        self.prefill_chunk
    }

    fn prefill_chunk(&mut self, slot: SeqSlot, tokens: &[i32], pos: usize) -> Result<()> {
        let c = self.prefill_chunk;
        if c == 0 {
            bail!("chunked prefill disabled on this backend");
        }
        if tokens.len() != c {
            bail!("prefill chunk must be exactly {c} tokens, got {}", tokens.len());
        }
        let (heads, dk, dv, vocab) = (self.heads, self.dk, self.dv, self.vocab);
        // per-token gates from the shared schedule — the same source the
        // decode step reads
        self.alpha_buf.clear();
        self.alpha_buf.extend((0..c).map(|j| self.gates.alpha(pos + j)));
        // stacked per-head (k, v) for the chunk: (H, C, dk) / (H, C, dv),
        // gathered into persistent workspaces (this is the serving hot
        // path — no steady-state allocation)
        self.kc_buf.clear();
        self.vc_buf.clear();
        for h in 0..heads {
            for &tok in tokens {
                let ti = tok_index(tok, vocab);
                self.kc_buf.extend_from_slice(self.ek[h].row(ti));
                self.vc_buf.extend_from_slice(self.ev[h].row(ti));
            }
        }
        debug_assert_eq!(self.kc_buf.len(), heads * c * dk);
        debug_assert_eq!(self.vc_buf.len(), heads * c * dv);
        let state = self.slots[slot.0].as_mut().expect("prefill of free slot");
        let SeqState::Prefilling(eng) = state else {
            bail!("prefill_chunk after decode began");
        };
        if eng.tokens() != pos {
            bail!("prefill position desync: engine at {}, chunk at {pos}", eng.tokens());
        }
        eng.ingest_chunk_mamba2(&self.kc_buf, &self.vc_buf, &self.alpha_buf, None);
        Ok(())
    }

    fn step(&mut self, _bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>> {
        let n = rows.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let (heads, dv, vocab) = (self.heads, self.dv, self.vocab);
        // 0) rows arriving from chunked prefill flip to pooled decode
        //    states via the export bridge
        for &(slot, _, _) in rows {
            self.ensure_decoding(slot)?;
        }
        // 1) per-(sequence, head) state update (merge + decay + write)
        for &(slot, tok, pos) in rows {
            let ti = tok_index(tok, vocab);
            let alpha = self.gates.alpha(pos as usize);
            let state = self.slots[slot.0].as_mut().expect("live slot");
            let SeqState::Decoding(seqs) = state else { unreachable!("ensured above") };
            for (h, seq) in seqs.iter_mut().enumerate() {
                debug_assert_eq!(seq.t as i32, pos, "position desync (head {h})");
                if seq
                    .advance(&mut self.pool, self.ek[h].row(ti), self.ev[h].row(ti), 1.0, Transition::Decay(alpha))
                    .is_err()
                {
                    // unreachable under admission reservation; surface loudly
                    bail!("state pool exhausted mid-step (reservation bug?)");
                }
            }
        }
        // 2) the batched read: every live level of every (sequence, head)
        //    in the batch, one fused block-sparse GEMM over the pool slab.
        //    Entry order (seq-major, head-minor) makes o_buf row-major
        //    (n, H·dv) — the logits GEMM's left operand, no reshuffle.
        self.q_buf.clear();
        for &(_, tok, _) in rows {
            let ti = tok_index(tok, vocab);
            for h in 0..heads {
                self.q_buf.extend_from_slice(self.eq[h].row(ti));
            }
        }
        self.o_buf.clear();
        self.o_buf.resize(n * heads * dv, 0.0);
        {
            let mut seq_refs: Vec<&PooledFenwickState> = Vec::with_capacity(n * heads);
            let mut lambdas: Vec<&[f32]> = Vec::with_capacity(n * heads);
            for &(slot, _, pos) in rows {
                let Some(SeqState::Decoding(seqs)) = self.slots[slot.0].as_ref() else {
                    unreachable!("ensured above")
                };
                let lam = self.gates.lambda(pos as usize);
                for seq in seqs {
                    seq_refs.push(seq);
                    lambdas.push(lam);
                }
            }
            self.dec
                .read_batch(&self.pool, &seq_refs, &self.q_buf, &lambdas, &mut self.o_buf);
        }
        // 3) whole-batch logits in one GEMM: (n, H·dv) @ (vocab, H·dv)^T
        let mut logits = vec![0.0f32; n * vocab];
        tensor::gemm_nt_into(n, heads * dv, vocab, &self.o_buf, &self.wo.data, &mut logits, false);
        Ok(logits)
    }

    fn state_bytes(&self) -> usize {
        let prefill: usize = self
            .slots
            .iter()
            .flatten()
            .map(|s| match s {
                SeqState::Prefilling(eng) => eng.state_bytes(),
                SeqState::Decoding(_) => 0,
            })
            .sum();
        self.pool.in_use() * self.pool.block_elems() * 4 + prefill
    }
}
